//! Dynamic batcher: size- and deadline-triggered batch formation.
//!
//! Pure logic (no tokio) so its invariants are property-testable:
//! * a batch never exceeds `max_batch`,
//! * requests leave in arrival order,
//! * a non-empty queue never waits longer than `max_wait` — the deadline
//!   clock tracks the **true enqueue time** of the oldest pending request
//!   ([`InferenceRequest::enqueued_at`]), so a partial flush cannot reset
//!   a leftover request's wait back to zero,
//! * padding fills up to the executable's lowered batch size,
//! * `push` backpressures (`Err(request)`) once `queue_depth` requests
//!   are pending. A `queue_depth` below `max_batch` is allowed: the queue
//!   then fills before the size trigger ever fires (strict admission) and
//!   batches form via the deadline flush only.

use super::request::InferenceRequest;
use crate::util::PooledVec;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A formed batch, padded to the lowered batch size. The request vec is
/// pooled: dropping the batch after completion recycles it (and every
/// request's pixel buffer) instead of freeing.
#[derive(Debug)]
pub struct Batch {
    pub requests: PooledVec<InferenceRequest>,
    /// The batch dimension the executable expects (`>= requests.len()`).
    pub padded_to: usize,
}

impl Batch {
    /// Flattened `padded_to × dim` input matrix; padding rows are zeros.
    pub fn flatten_inputs(&self, dim: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.flatten_into(dim, self.padded_to, &mut out);
        out
    }

    /// Write the flattened `rows × dim` input matrix into `out`
    /// (cleared first). `rows >= requests.len()`; only rows beyond the
    /// real requests are zeroed — the real rows are copied straight in,
    /// with no dead pre-zeroing pass. Backends with a fixed lowered
    /// batch shape (PJRT) pass `padded_to` and get their zero tail; the
    /// native GEMM passes `requests.len()`, so the zero fill vanishes
    /// entirely. `out` drawn from the buffer pool makes this
    /// allocation-free after warmup.
    pub fn flatten_into(&self, dim: usize, rows: usize, out: &mut Vec<f32>) {
        assert!(rows >= self.requests.len(), "rows must cover every request");
        out.clear();
        out.reserve(rows * dim);
        for r in self.requests.iter() {
            assert_eq!(r.pixels.len(), dim, "request {} has wrong input dim", r.id);
            out.extend_from_slice(&r.pixels);
        }
        // padding tail only (PJRT's fixed shape); no-op at rows == len
        out.resize(rows * dim, 0.0);
    }
}

/// Deadline-based dynamic batcher.
///
/// The deadline clock is *derived*: it is always the enqueue time of
/// `queue.front()`, never cached — so no code path can desynchronize a
/// leftover request's wait from its true enqueue time.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    queue: VecDeque<InferenceRequest>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration, queue_depth: usize) -> Self {
        assert!(max_batch >= 1);
        assert!(queue_depth >= 1);
        Batcher { max_batch, max_wait, queue_depth, queue: VecDeque::new() }
    }

    pub fn from_config(cfg: &crate::config::BatcherConfig) -> Self {
        Batcher::new(cfg.max_batch, Duration::from_micros(cfg.max_wait_us), cfg.queue_depth)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// True when the queue is at capacity (callers should backpressure).
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.queue_depth
    }

    /// Enqueue a request. Returns a full batch if the size trigger fired.
    /// Returns `Err(request)` when the queue is full (backpressure).
    pub fn push(&mut self, req: InferenceRequest) -> Result<Option<Batch>, InferenceRequest> {
        if self.is_full() {
            return Err(req);
        }
        self.queue.push_back(req);
        if self.queue.len() >= self.max_batch {
            Ok(Some(self.form_batch()))
        } else {
            Ok(None)
        }
    }

    /// Flush if the oldest pending request has waited past the deadline.
    pub fn flush_due(&mut self, now: Instant) -> Option<Batch> {
        match self.queue.front() {
            Some(r) if now.duration_since(r.enqueued_at) >= self.max_wait => {
                Some(self.form_batch())
            }
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            out.push(self.form_batch());
        }
        out
    }

    /// Time until the current deadline fires, if any (scheduler hint).
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.queue
            .front()
            .map(|r| (r.enqueued_at + self.max_wait).saturating_duration_since(now))
    }

    /// Admission hint for a rejected request: an estimate (µs, always
    /// ≥ 1) of when capacity frees. `backlog` is how many requests sit
    /// ahead of the retrier — the pending queue when the batcher itself
    /// rejected, or the server's total outstanding count when admission
    /// failed above the batcher. The estimate assumes the backlog drains
    /// in `max_batch`-sized flushes one `max_wait` apart, starting at
    /// the oldest pending request's deadline (or a full `max_wait` when
    /// nothing is queued and the backlog is all in flight). It is a
    /// *hint*, not a promise: actual service time depends on worker
    /// speed and any simulated-latency gate.
    pub fn retry_after_us(&self, now: Instant, backlog: usize) -> u64 {
        let until_flush = self.next_deadline_in(now).unwrap_or(self.max_wait);
        let flushes_ahead = backlog.div_ceil(self.max_batch).max(1) as u32;
        let wait = until_flush + self.max_wait * (flushes_ahead - 1);
        (wait.as_micros() as u64).max(1)
    }

    fn form_batch(&mut self) -> Batch {
        let n = self.queue.len().min(self.max_batch);
        let mut requests = PooledVec::with_capacity(n);
        requests.extend(self.queue.drain(..n));
        Batch { requests, padded_to: self.max_batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0.5; 4])
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let mut b = Batcher::new(3, Duration::from_secs(10), 16);
        assert!(b.push(req(0)).unwrap().is_none());
        assert!(b.push(req(1)).unwrap().is_none());
        let batch = b.push(req(2)).unwrap().expect("size trigger");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padded_to, 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_trigger_flushes_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_micros(1), 16);
        b.push(req(0)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
        let batch = b.flush_due(Instant::now()).expect("deadline fired");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.padded_to, 8);
    }

    #[test]
    fn arrival_order_preserved() {
        let mut b = Batcher::new(4, Duration::from_secs(1), 16);
        for i in 0..3 {
            b.push(req(i)).unwrap();
        }
        let batch = b.push(req(3)).unwrap().unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backpressure_when_full() {
        // queue_depth below the size trigger, so the queue genuinely
        // fills: pushes 0..4 stay below max_batch=8 and accumulate.
        let mut b = Batcher::new(8, Duration::from_secs(10), 4);
        for i in 0..4 {
            assert!(b.push(req(i)).unwrap().is_none());
        }
        assert!(b.is_full());
        let rejected = b.push(req(99)).expect_err("queue at depth must reject");
        assert_eq!(rejected.id, 99, "the rejected request comes back to the caller");
        assert_eq!(b.pending(), 4);
        // draining via the deadline path frees capacity again
        let batch = b.flush_due(Instant::now() + Duration::from_secs(11)).expect("deadline");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(batch.padded_to, 8);
        assert!(b.push(req(100)).unwrap().is_none());
    }

    #[test]
    fn retry_hint_tracks_flush_deadline_and_backlog() {
        let max_wait = Duration::from_millis(10);
        let mut b = Batcher::new(4, max_wait, 8);
        let t0 = Instant::now();
        b.queue.push_back(InferenceRequest {
            id: 0,
            pixels: vec![0.0; 4].into(),
            enqueued_at: t0,
            trace: 0,
        });
        // one pending request: the hint is the remaining deadline budget
        let hint = b.retry_after_us(t0, 1);
        assert!(hint >= 9_000 && hint <= 10_000, "hint {hint}");
        // two max_batch-fulls of backlog: one extra max_wait of drain time
        let deep = b.retry_after_us(t0, 8);
        assert!(deep >= hint + 9_000, "deep {deep} vs {hint}");
        // past the deadline the hint saturates at the 1 µs floor, never 0
        assert_eq!(b.retry_after_us(t0 + Duration::from_secs(1), 1), 1);
    }

    #[test]
    fn retry_hint_without_pending_queue_uses_max_wait() {
        // all backlog in flight at the workers (nothing queued): the hint
        // falls back to one max_wait heartbeat per max_batch of backlog
        let b = Batcher::new(8, Duration::from_millis(5), 16);
        let now = Instant::now();
        let hint = b.retry_after_us(now, 16);
        assert_eq!(hint, 10_000, "2 flushes x 5 ms");
        assert!(b.retry_after_us(now, 1) >= 1);
    }

    #[test]
    fn leftover_request_keeps_true_deadline_after_partial_flush() {
        // Regression: form_batch used to reset a cached deadline clock to
        // `now`, letting a leftover request wait up to ~2x max_wait.
        let max_wait = Duration::from_millis(100);
        let mut b = Batcher::new(2, max_wait, 16);
        let t0 = Instant::now();
        // three requests enqueued at t0; max_batch 2 leaves one behind
        for id in 0..3 {
            b.queue.push_back(InferenceRequest {
                id,
                pixels: vec![0.0; 4].into(),
                enqueued_at: t0,
                trace: 0,
            });
        }
        let first = b.flush_due(t0 + max_wait).expect("deadline fired");
        assert_eq!(first.requests.len(), 2);
        assert_eq!(b.pending(), 1);
        // the leftover (id 2) enqueued at t0 — its deadline is t0+max_wait,
        // already due: it must NOT be made to wait another max_wait.
        assert_eq!(
            b.next_deadline_in(t0 + max_wait),
            Some(Duration::ZERO),
            "leftover deadline must reflect its true enqueue time"
        );
        let second = b.flush_due(t0 + max_wait).expect("leftover is already due");
        assert_eq!(second.requests[0].id, 2);
    }

    #[test]
    fn push_uses_request_enqueue_time_for_deadline() {
        let max_wait = Duration::from_millis(100);
        let mut b = Batcher::new(8, max_wait, 16);
        let Some(t0) = Instant::now().checked_sub(Duration::from_millis(60)) else {
            return; // clock too close to boot to backdate
        };
        b.push(InferenceRequest { id: 0, pixels: vec![0.0; 4].into(), enqueued_at: t0, trace: 0 })
            .unwrap();
        // 60ms of the budget already burned before push
        let left = b.next_deadline_in(Instant::now()).unwrap();
        assert!(left <= Duration::from_millis(40), "deadline ignored enqueue time: {left:?}");
        assert!(b.flush_due(t0 + max_wait).is_some());
    }

    #[test]
    fn next_deadline_counts_down_and_clears() {
        let max_wait = Duration::from_millis(500);
        let mut b = Batcher::new(4, max_wait, 16);
        assert_eq!(b.next_deadline_in(Instant::now()), None, "empty queue has no deadline");
        b.push(req(0)).unwrap();
        let now = Instant::now(); // after push, so enqueue time <= now
        let d = b.next_deadline_in(now).expect("pending request has a deadline");
        assert!(d <= max_wait);
        // past the deadline it saturates to zero rather than underflowing
        assert_eq!(b.next_deadline_in(now + Duration::from_secs(1)), Some(Duration::ZERO));
        let _ = b.flush_due(now + Duration::from_secs(1)).unwrap();
        assert_eq!(b.next_deadline_in(now), None, "drained queue has no deadline");
    }

    #[test]
    fn flatten_inputs_full_batch_has_no_padding() {
        let mut b = Batcher::new(3, Duration::from_secs(1), 16);
        b.push(InferenceRequest::new(0, vec![1.0, 2.0])).unwrap();
        b.push(InferenceRequest::new(1, vec![3.0, 4.0])).unwrap();
        let batch = b.push(InferenceRequest::new(2, vec![5.0, 6.0])).unwrap().unwrap();
        assert_eq!(batch.requests.len(), batch.padded_to);
        let flat = batch.flatten_inputs(2);
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn padding_rows_are_zero() {
        let mut b = Batcher::new(4, Duration::from_micros(0), 8);
        b.push(InferenceRequest::new(0, vec![1.0, 2.0])).unwrap();
        let batch = b.flush_due(Instant::now()).unwrap();
        let flat = batch.flatten_inputs(2);
        assert_eq!(flat, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn flush_all_drains_in_chunks() {
        let mut b = Batcher::new(2, Duration::from_secs(10), 16);
        // push 5 without triggering (push triggers at 2, so collect outputs)
        let mut formed = 0;
        for i in 0..5 {
            if b.push(req(i)).unwrap().is_some() {
                formed += 1;
            }
        }
        let rest = b.flush_all();
        let total: usize = rest.iter().map(|x| x.requests.len()).sum();
        assert_eq!(formed * 2 + total, 5);
        assert_eq!(b.pending(), 0);
    }
}
