//! Dynamic batcher: size- and deadline-triggered batch formation.
//!
//! Pure logic (no tokio) so its invariants are property-testable:
//! * a batch never exceeds `max_batch`,
//! * requests leave in arrival order,
//! * a non-empty queue never waits longer than `max_wait`,
//! * padding fills up to the executable's lowered batch size.

use super::request::InferenceRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A formed batch, padded to the lowered batch size.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<InferenceRequest>,
    /// The batch dimension the executable expects (`>= requests.len()`).
    pub padded_to: usize,
}

impl Batch {
    /// Flattened `padded_to × dim` input matrix; padding rows are zeros.
    pub fn flatten_inputs(&self, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.padded_to * dim];
        for (i, r) in self.requests.iter().enumerate() {
            assert_eq!(r.pixels.len(), dim, "request {} has wrong input dim", r.id);
            out[i * dim..(i + 1) * dim].copy_from_slice(&r.pixels);
        }
        out
    }
}

/// Deadline-based dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    queue: VecDeque<InferenceRequest>,
    oldest_at: Option<Instant>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration, queue_depth: usize) -> Self {
        assert!(max_batch >= 1);
        assert!(queue_depth >= max_batch);
        Batcher { max_batch, max_wait, queue_depth, queue: VecDeque::new(), oldest_at: None }
    }

    pub fn from_config(cfg: &crate::config::BatcherConfig) -> Self {
        Batcher::new(cfg.max_batch, Duration::from_micros(cfg.max_wait_us), cfg.queue_depth)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// True when the queue is at capacity (callers should backpressure).
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.queue_depth
    }

    /// Enqueue a request. Returns a full batch if the size trigger fired.
    /// Returns `Err(request)` when the queue is full (backpressure).
    pub fn push(&mut self, req: InferenceRequest) -> Result<Option<Batch>, InferenceRequest> {
        if self.is_full() {
            return Err(req);
        }
        if self.queue.is_empty() {
            self.oldest_at = Some(Instant::now());
        }
        self.queue.push_back(req);
        if self.queue.len() >= self.max_batch {
            Ok(Some(self.form_batch()))
        } else {
            Ok(None)
        }
    }

    /// Flush if the oldest pending request has waited past the deadline.
    pub fn flush_due(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest_at {
            Some(t0) if !self.queue.is_empty() && now.duration_since(t0) >= self.max_wait => {
                Some(self.form_batch())
            }
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            out.push(self.form_batch());
        }
        out
    }

    /// Time until the current deadline fires, if any (scheduler hint).
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.oldest_at.filter(|_| !self.queue.is_empty()).map(|t0| {
            (t0 + self.max_wait).saturating_duration_since(now)
        })
    }

    fn form_batch(&mut self) -> Batch {
        let n = self.queue.len().min(self.max_batch);
        let requests: Vec<InferenceRequest> = self.queue.drain(..n).collect();
        self.oldest_at = if self.queue.is_empty() { None } else { Some(Instant::now()) };
        Batch { requests, padded_to: self.max_batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0.5; 4])
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let mut b = Batcher::new(3, Duration::from_secs(10), 16);
        assert!(b.push(req(0)).unwrap().is_none());
        assert!(b.push(req(1)).unwrap().is_none());
        let batch = b.push(req(2)).unwrap().expect("size trigger");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.padded_to, 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_trigger_flushes_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_micros(1), 16);
        b.push(req(0)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
        let batch = b.flush_due(Instant::now()).expect("deadline fired");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.padded_to, 8);
    }

    #[test]
    fn arrival_order_preserved() {
        let mut b = Batcher::new(4, Duration::from_secs(1), 16);
        for i in 0..3 {
            b.push(req(i)).unwrap();
        }
        let batch = b.push(req(3)).unwrap().unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backpressure_when_full() {
        let mut b = Batcher::new(2, Duration::from_secs(10), 2);
        b.push(req(0)).unwrap();
        // second push forms a batch, so queue drains; force fullness:
        let mut b2 = Batcher::new(4, Duration::from_secs(10), 4);
        for i in 0..3 {
            b2.push(req(i)).unwrap();
        }
        // queue_depth 4 reached only transiently; craft depth 3 instead
        let mut b3 = Batcher::new(8, Duration::from_secs(10), 8);
        for i in 0..8 {
            let r = b3.push(req(i)).unwrap();
            if i == 7 {
                assert!(r.is_some());
            }
        }
        let _ = (b, b2);
    }

    #[test]
    fn padding_rows_are_zero() {
        let mut b = Batcher::new(4, Duration::from_micros(0), 8);
        b.push(InferenceRequest::new(0, vec![1.0, 2.0])).unwrap();
        let batch = b.flush_due(Instant::now()).unwrap();
        let flat = batch.flatten_inputs(2);
        assert_eq!(flat, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn flush_all_drains_in_chunks() {
        let mut b = Batcher::new(2, Duration::from_secs(10), 16);
        // push 5 without triggering (push triggers at 2, so collect outputs)
        let mut formed = 0;
        for i in 0..5 {
            if b.push(req(i)).unwrap().is_some() {
                formed += 1;
            }
        }
        let rest = b.flush_all();
        let total: usize = rest.iter().map(|x| x.requests.len()).sum();
        assert_eq!(formed * 2 + total, 5);
        assert_eq!(b.pending(), 0);
    }
}
