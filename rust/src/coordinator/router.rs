//! Round-robin router over the execution worker pool with in-flight
//! accounting (backend-agnostic: native LUT-GEMM or PJRT workers).

use super::worker::{BatchJob, WorkerPool};
use crate::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Routes batch jobs to workers. Round-robin with per-worker in-flight
/// counters; `dispatch` prefers the next worker in rotation but skips to
/// the least-loaded one when the rotation target is more than one job
/// deeper than the minimum (cheap least-loaded approximation without
/// locks).
pub struct Router {
    pool: WorkerPool,
    next: AtomicUsize,
    in_flight: Vec<Arc<AtomicU64>>,
    dispatched: AtomicU64,
}

impl Router {
    pub fn new(pool: WorkerPool) -> Self {
        let in_flight = (0..pool.size()).map(|_| Arc::new(AtomicU64::new(0))).collect();
        Router { pool, next: AtomicUsize::new(0), in_flight, dispatched: AtomicU64::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Pick a worker: rotation target unless it is clearly busier than the
    /// least-loaded worker.
    fn pick(&self) -> usize {
        let n = self.pool.size();
        let rot = self.next.fetch_add(1, Ordering::Relaxed) % n;
        let (mut best, mut best_load) = (rot, self.in_flight[rot].load(Ordering::Relaxed));
        for (i, c) in self.in_flight.iter().enumerate() {
            let load = c.load(Ordering::Relaxed);
            if load + 1 < best_load {
                best = i;
                best_load = load;
            }
        }
        let _ = best_load;
        best
    }

    /// Dispatch a job; the returned guard decrements the in-flight counter
    /// when dropped (call after the reply resolves).
    pub fn dispatch(&self, job: BatchJob) -> Result<InFlightGuard> {
        let idx = self.pick();
        self.in_flight[idx].fetch_add(1, Ordering::Relaxed);
        self.dispatched.fetch_add(1, Ordering::Relaxed);
        match self.pool.submit(idx, job) {
            Ok(()) => Ok(InFlightGuard { counter: self.in_flight[idx].clone(), worker: idx }),
            Err(e) => {
                self.in_flight[idx].fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    pub fn in_flight(&self, worker: usize) -> u64 {
        self.in_flight[worker].load(Ordering::Relaxed)
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

/// RAII in-flight token.
pub struct InFlightGuard {
    counter: Arc<AtomicU64>,
    /// Which worker the job went to (metrics/tests).
    pub worker: usize,
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BackendSpec;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::nn::QuantMlp;

    #[test]
    fn round_robin_spreads_work() {
        let mlp = QuantMlp::random_for_study(13);
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        let spec =
            BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::Ideal, threads: 1 };
        let router = Router::new(WorkerPool::spawn(2, spec).unwrap());
        let mut hit = [false; 2];
        for i in 0..6 {
            let (tx, rx) = crate::util::oneshot::channel();
            let inputs = vec![i as f32 / 8.0; 16];
            let guard = router
                .dispatch(BatchJob { inputs: inputs.clone(), batch: 1, dim: 16, reply: tx })
                .unwrap();
            hit[guard.worker] = true;
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.outputs[0], mlp.forward(&inputs, &model));
            drop(guard);
        }
        assert!(hit[0] && hit[1], "both workers used");
        assert_eq!(router.dispatched(), 6);
        assert_eq!(router.in_flight(0) + router.in_flight(1), 0);
        router.shutdown();
    }
}
