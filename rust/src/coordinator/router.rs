//! Round-robin router over the execution worker pool with in-flight
//! accounting (backend-agnostic: native LUT-GEMM or PJRT workers).

use super::worker::{BatchJob, WorkerPool};
use crate::Result;
// Ordering audit: every atomic here is Relaxed by design. The in-flight
// counters and the rotation cursor are load *estimates* — `pick_from`
// tolerates stale reads (it only biases placement), and no data is
// published through them (jobs travel over the worker queues, whose
// locks provide the happens-before).
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Routes batch jobs to workers. Round-robin with per-worker in-flight
/// counters; `dispatch` prefers the next worker in rotation but skips to
/// the least-loaded one when the rotation target is more than one job
/// deeper than the minimum (cheap least-loaded approximation without
/// locks).
pub struct Router {
    pool: WorkerPool,
    next: AtomicUsize,
    in_flight: Vec<Arc<AtomicU64>>,
    dispatched: AtomicU64,
}

impl Router {
    pub fn new(pool: WorkerPool) -> Self {
        let in_flight = (0..pool.size()).map(|_| Arc::new(AtomicU64::new(0))).collect();
        Router { pool, next: AtomicUsize::new(0), in_flight, dispatched: AtomicU64::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.pool.size()
    }

    /// Pick a worker starting from a rotation position: the rotation
    /// target unless it is clearly busier than the least-loaded worker.
    fn pick_from(&self, rot: usize) -> usize {
        let rot = rot % self.pool.size();
        let (mut best, mut best_load) = (rot, self.in_flight[rot].load(Ordering::Relaxed));
        for (i, c) in self.in_flight.iter().enumerate() {
            let load = c.load(Ordering::Relaxed);
            if load + 1 < best_load {
                best = i;
                best_load = load;
            }
        }
        let _ = best_load;
        best
    }

    /// Reserve a worker slot *before* the job exists: returns the chosen
    /// worker and the in-flight guard, so a caller can register
    /// completion state keyed on the batch first and only then submit
    /// ([`Router::submit_to`]) — a reply can never race its own context.
    /// `rot` seeds the rotation (sharded batcher lanes pass
    /// `shard + k·shards` so distinct shards prefer disjoint workers);
    /// [`Router::dispatch`] uses the internal rotation counter.
    pub fn begin(&self, rot: usize) -> (usize, InFlightGuard) {
        let idx = self.pick_from(rot);
        self.in_flight[idx].fetch_add(1, Ordering::Relaxed);
        self.dispatched.fetch_add(1, Ordering::Relaxed);
        (idx, InFlightGuard { counter: self.in_flight[idx].clone(), worker: idx })
    }

    /// Submit a job to the worker reserved by [`Router::begin`]. On
    /// error the caller still holds the guard; dropping it releases the
    /// in-flight slot.
    pub fn submit_to(&self, idx: usize, job: BatchJob) -> Result<()> {
        self.pool.submit(idx, job)
    }

    /// Dispatch a job; the returned guard decrements the in-flight counter
    /// when dropped (call after the reply resolves).
    pub fn dispatch(&self, job: BatchJob) -> Result<InFlightGuard> {
        let (idx, guard) = self.begin(self.next.fetch_add(1, Ordering::Relaxed));
        match self.submit_to(idx, job) {
            Ok(()) => Ok(guard),
            Err(e) => {
                drop(guard);
                Err(e)
            }
        }
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    pub fn in_flight(&self, worker: usize) -> u64 {
        self.in_flight[worker].load(Ordering::Relaxed)
    }

    /// Broadcast a model retire to every worker in the pool (each drops
    /// its per-model executor — see [`WorkerPool::retire`]).
    pub fn retire(&self, model: crate::net::protocol::ModelId) {
        self.pool.retire(model);
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

/// RAII in-flight token.
pub struct InFlightGuard {
    counter: Arc<AtomicU64>,
    /// Which worker the job went to (metrics/tests).
    pub worker: usize,
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BackendSpec;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::nn::{GemmOptions, QuantMlp};

    #[test]
    fn round_robin_spreads_work() {
        let mlp = QuantMlp::random_for_study(13);
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        let gemm = GemmOptions::default();
        let spec = BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::Ideal, gemm };
        let router = Router::new(WorkerPool::spawn(2, spec).unwrap());
        let mut hit = [false; 2];
        for i in 0..6 {
            let (tx, rx) = crate::util::oneshot::channel();
            let inputs = vec![i as f32 / 8.0; 16];
            let job = BatchJob::new(
                inputs.clone(),
                1,
                16,
                crate::coordinator::worker::ReplyTo::Oneshot(tx),
            );
            let guard = router.dispatch(job).unwrap();
            hit[guard.worker] = true;
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.logits, mlp.forward(&inputs, &model));
            drop(guard);
        }
        assert!(hit[0] && hit[1], "both workers used");
        assert_eq!(router.dispatched(), 6);
        assert_eq!(router.in_flight(0) + router.in_flight(1), 0);
        router.shutdown();
    }
}
