//! Request/response types on the serving path.

#[cfg(not(loom))]
use crate::util::pool::ClassPool;
use crate::util::pool::{PoolItem, PooledVec};
use std::time::Instant;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// One inference request: an 8×8 image flattened to 64 pixels in [0, 1].
///
/// The pixels live in a pooled buffer ([`PooledVec`]) so the wire path
/// can decode a request and carry it to the batcher without allocating;
/// the buffer recycles when the request is dropped after its batch
/// completes. `Vec<f32>` converts in via `Into`, so non-hot-path callers
/// keep passing plain vectors.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub pixels: PooledVec<f32>,
    pub enqueued_at: Instant,
    /// Flight-recorder trace id (`0` = untraced). Assigned at ingress —
    /// sampled locally or carried in on the wire — and threaded through
    /// the batch so completion can record per-stage spans under it.
    pub trace: u64,
}

impl InferenceRequest {
    pub fn new(id: RequestId, pixels: impl Into<PooledVec<f32>>) -> Self {
        InferenceRequest { id, pixels: pixels.into(), enqueued_at: Instant::now(), trace: 0 }
    }
}

/// The batcher's formed-batch request vecs recycle through their own
/// pool class; returning one drops its requests, which cascades each
/// pixel buffer back to the `f32` pool. (Gated off loom builds — loom
/// primitives cannot live in statics; see [`crate::util::sync`].)
#[cfg(not(loom))]
static REQUEST_VEC_POOL: ClassPool<InferenceRequest> = ClassPool::new();

impl PoolItem for InferenceRequest {
    #[cfg(not(loom))]
    fn pool() -> &'static ClassPool<InferenceRequest> {
        &REQUEST_VEC_POOL
    }
}

/// The served answer.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// Output logits (10 classes).
    pub logits: Vec<f32>,
    /// Argmax class.
    pub label: usize,
    /// Wall-clock time from enqueue to completion.
    pub latency_us: u64,
    /// Simulated CiM energy attributed to this request (fJ).
    pub sim_energy_fj: f64,
    /// Simulated CiM latency for the MAC schedule (ps).
    pub sim_latency_ps: u64,
    /// LUT (re)programming events of this request's batch schedule.
    pub sim_programs: u64,
    /// Programs avoided by weight-stationary reuse in this request's
    /// batch schedule.
    pub sim_stationary_hits: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_enqueue_time() {
        let r = InferenceRequest::new(7, vec![0.0; 64]);
        assert_eq!(r.id, 7);
        assert_eq!(r.pixels.len(), 64);
        assert!(r.enqueued_at.elapsed().as_secs() < 1);
    }

    #[test]
    fn request_accepts_pooled_pixels_directly() {
        let px = PooledVec::from_slice(&[0.25f32; 4]);
        let r = InferenceRequest::new(1, px);
        assert_eq!(r.pixels, vec![0.25f32; 4]);
    }
}
