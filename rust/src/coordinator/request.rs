//! Request/response types on the serving path.

use std::time::Instant;

/// Monotonically increasing request identifier.
pub type RequestId = u64;

/// One inference request: an 8×8 image flattened to 64 pixels in [0, 1].
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub pixels: Vec<f32>,
    pub enqueued_at: Instant,
}

impl InferenceRequest {
    pub fn new(id: RequestId, pixels: Vec<f32>) -> Self {
        InferenceRequest { id, pixels, enqueued_at: Instant::now() }
    }
}

/// The served answer.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// Output logits (10 classes).
    pub logits: Vec<f32>,
    /// Argmax class.
    pub label: usize,
    /// Wall-clock time from enqueue to completion.
    pub latency_us: u64,
    /// Simulated CiM energy attributed to this request (fJ).
    pub sim_energy_fj: f64,
    /// Simulated CiM latency for the MAC schedule (ps).
    pub sim_latency_ps: u64,
    /// LUT (re)programming events of this request's batch schedule.
    pub sim_programs: u64,
    /// Programs avoided by weight-stationary reuse in this request's
    /// batch schedule.
    pub sim_stationary_hits: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_enqueue_time() {
        let r = InferenceRequest::new(7, vec![0.0; 64]);
        assert_eq!(r.id, 7);
        assert!(r.enqueued_at.elapsed().as_secs() < 1);
    }
}
