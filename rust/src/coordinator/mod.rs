//! L3 coordinator: the serving runtime that turns LUNA-CiM into a system.
//!
//! The paper contributes a multiplier + array integration; to *use* it you
//! need what this module provides — the part a deployment would run:
//!
//! * [`admission`] — the global outstanding-count admission gate (one
//!   shared atomic bound across every batcher shard; its never-exceeds /
//!   never-leaks invariant is model-checked under loom — see the crate
//!   docs' `## Concurrency model`);
//! * [`batcher`] — dynamic batching with a max-batch/max-wait policy
//!   (batches are padded to the AOT-lowered batch size; deadlines track
//!   true enqueue times, and `push` backpressures at `queue_depth`).
//!   The server runs `batcher.shards` independent batcher lanes with
//!   request-id-affine dispatch and pooled, allocation-free request
//!   buffers (see the crate docs' `## Serving hot path`);
//! * [`worker`] — a pool of OS threads, each building its own execution
//!   backend from a [`crate::engine::BackendSpec`]: the native batched
//!   LUT-GEMM by default, or a PJRT client + compiled executable with the
//!   `pjrt` feature (PJRT handles are not `Send`);
//! * [`router`] — round-robin dispatch with in-flight accounting;
//! * [`tiler`] — maps every 4b×4b MAC of the model onto LUNA banks
//!   (weight-stationary scheduling) and prices the run in programming
//!   events, cycles and femtojoules using the gate-level cost model
//!   (calibration measured once per process; with `backend calibrated`
//!   each worker replays schedules on its own fabric and the simulated
//!   latency can gate replies — see [`crate::engine::CalibratedBackend`]);
//! * [`state`] — bank programming state (which weight each unit holds);
//! * [`metrics`] — latency/throughput/energy/failure counters, plus the
//!   per-backend routed/failed-over/quarantine counters the front-tier
//!   router ([`crate::net::router`]) reports, and the plan-cache
//!   hit/miss/eviction/compile gauges ([`metrics::PlanCacheCounters`]);
//! * [`server`] — the std-thread front-end tying it all together:
//!   multi-tenant model registry, per-model batching lanes, the shared
//!   compiled-plan cache ([`crate::engine::PlanCache`]) and hot
//!   load/retire of models under live traffic.

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod state;
pub mod tiler;
pub mod worker;

pub use admission::AdmissionGate;
pub use batcher::{Batch, Batcher};
pub use metrics::{
    BackendStats, LatencyHistogram, Metrics, MetricsSnapshot, RouterMetrics, RouterSnapshot,
    TenantLat, TenantStats,
};
pub use request::{InferenceRequest, InferenceResponse, RequestId};
pub use router::Router;
pub use server::{
    Backpressure, Completion, CoordinatorServer, ModelStats, ModelUnavailable, ServerHandle,
};
pub use state::BankState;
pub use tiler::{LayerSchedule, ModelSchedule, ScheduleCost, Tiler, UnitCosts};
pub use worker::{BatchJob, ReplyTicket, ReplyTo, WorkerPool, WorkerReply};
