//! Global admission gate: one shared atomic bounds total outstanding
//! requests (pending in any batcher shard + dispatched but not yet
//! completed) at `batcher.queue_depth`.
//!
//! Extracted from the server so the invariant is model-checkable in
//! isolation: under every interleaving of concurrent
//! admit/reject/release, the number of *held* permits never exceeds the
//! bound and no permit leaks (`tests/loom_models.rs` and the
//! `#[cfg(loom)]` model below pin both). The counter may transiently
//! overshoot the bound — a losing `try_admit` increments before it
//! checks, then backs out — but a permit is only ever *held* after the
//! check passes, so the held count stays exact.
//!
//! Memory-ordering contract: every access is `Relaxed`, which is
//! sufficient — and what the loom models verify — because the gate is a
//! pure counter protocol. Atomic read-modify-writes on one cell form a
//! single total modification order even at `Relaxed`, which is all the
//! bound needs; no other memory is published through this atomic (the
//! request data a permit guards travels through the shard mutexes and
//! the worker queue, whose lock/unlock edges provide the
//! happens-before).

use crate::util::sync::atomic::{AtomicUsize, Ordering};

/// Counting admission gate with a hard upper bound on held permits.
pub struct AdmissionGate {
    outstanding: AtomicUsize,
    max: usize,
}

impl AdmissionGate {
    /// A gate admitting at most `max` concurrently held permits.
    pub fn new(max: usize) -> Self {
        AdmissionGate { outstanding: AtomicUsize::new(0), max }
    }

    /// The bound (the server's `batcher.queue_depth`).
    pub fn max(&self) -> usize {
        self.max
    }

    /// Currently outstanding permits. May transiently read up to one
    /// over `max` per concurrently rejecting caller (see module docs);
    /// use only for monitoring and retry hints, never for decisions.
    pub fn outstanding(&self) -> usize {
        // ordering: Relaxed — monitoring read, no decision or
        // publication hangs off it (module docs).
        self.outstanding.load(Ordering::Relaxed)
    }

    /// Try to take one permit. `Err(observed)` when the gate is full,
    /// carrying the outstanding count the attempt observed (the
    /// backlog estimate behind `retry_after_us` hints).
    pub fn try_admit(&self) -> Result<(), usize> {
        // Increment-then-check: the RMW reserves a slot atomically, so
        // two racing admits can never both pass a `prev >= max` check
        // against the same prior value — at most `max` callers ever see
        // `prev < max` while their permits are held.
        // ordering: Relaxed — counter-only protocol; RMWs on one atomic
        // are totally ordered regardless (module docs).
        let prev = self.outstanding.fetch_add(1, Ordering::Relaxed);
        if prev >= self.max {
            // back out the reservation; the permit was never held
            self.outstanding.fetch_sub(1, Ordering::Relaxed);
            Err(prev)
        } else {
            Ok(())
        }
    }

    /// Return `n` permits (a completed or failed batch releases its
    /// whole batch at once).
    pub fn release(&self, n: usize) {
        // ordering: Relaxed — see module docs.
        let before = self.outstanding.fetch_sub(n, Ordering::Relaxed);
        debug_assert!(before >= n, "released more permits than were held");
    }
}

#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::Arc;
    use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as StdOrdering};

    /// Three admitters racing a bound of 1: in every interleaving the
    /// number of simultaneously *held* permits never exceeds the bound,
    /// and after everyone releases, nothing has leaked.
    #[test]
    fn bound_holds_and_permits_never_leak() {
        loom::model(|| {
            let gate = Arc::new(AdmissionGate::new(1));
            // std atomic: an observer ledger outside the model's memory
            // system, counting *held* permits exactly
            let held = std::sync::Arc::new(StdAtomicUsize::new(0));
            let mut threads = Vec::new();
            for _ in 0..2 {
                let g = gate.clone();
                let h = held.clone();
                threads.push(loom::thread::spawn(move || {
                    if g.try_admit().is_ok() {
                        let now = h.fetch_add(1, StdOrdering::Relaxed) + 1;
                        assert!(now <= 1, "{now} permits held past a bound of 1");
                        h.fetch_sub(1, StdOrdering::Relaxed);
                        g.release(1);
                    }
                }));
            }
            if gate.try_admit().is_ok() {
                let now = held.fetch_add(1, StdOrdering::Relaxed) + 1;
                assert!(now <= 1, "{now} permits held past a bound of 1");
                held.fetch_sub(1, StdOrdering::Relaxed);
                gate.release(1);
            }
            for t in threads {
                t.join().unwrap();
            }
            assert_eq!(gate.outstanding(), 0, "no permit leaked");
        });
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_bound_then_rejects_with_observation() {
        let gate = AdmissionGate::new(2);
        assert_eq!(gate.max(), 2);
        assert!(gate.try_admit().is_ok());
        assert!(gate.try_admit().is_ok());
        assert_eq!(gate.outstanding(), 2);
        assert_eq!(gate.try_admit(), Err(2), "full gate reports what it observed");
        assert_eq!(gate.outstanding(), 2, "rejection backs its reservation out");
        gate.release(1);
        assert!(gate.try_admit().is_ok(), "released capacity is reusable");
        gate.release(2);
        assert_eq!(gate.outstanding(), 0);
    }

    #[test]
    fn batch_release_returns_all_permits_at_once() {
        let gate = AdmissionGate::new(8);
        for _ in 0..5 {
            gate.try_admit().unwrap();
        }
        gate.release(5);
        assert_eq!(gate.outstanding(), 0);
    }

    #[test]
    fn zero_bound_rejects_everything() {
        let gate = AdmissionGate::new(0);
        assert_eq!(gate.try_admit(), Err(0));
        assert_eq!(gate.outstanding(), 0);
    }
}
