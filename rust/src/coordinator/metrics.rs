//! Serving metrics: latency histogram, throughput and energy counters.
//!
//! Lock-free on the hot path (atomics only); the histogram uses
//! fixed log-spaced buckets so recording is a couple of atomic adds.
//!
//! Ordering audit: every atomic access here is Relaxed by design. These
//! are monotonic monitoring counters — a snapshot tolerates tearing
//! across counters (it is a statistical view, not a consistent cut),
//! and nothing is published through them.

use super::tiler::ScheduleCost;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Log-spaced latency histogram (µs), 1 µs .. ~16 s.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^{i+1}) µs.
    buckets: [AtomicU64; 24],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(23);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from the bucket histogram (upper bound of the
    /// containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Compiled-plan cache counters, shared between the engine-level
/// [`crate::engine::PlanCache`] (which records) and the serving metrics
/// (which render). Same Relaxed monitoring-only audit as the module
/// header; `resident`/`resident_bytes` are gauges, the rest monotonic.
#[derive(Debug, Default)]
pub struct PlanCacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    compiles: AtomicU64,
    /// Gauge: models currently resident in the cache.
    resident: AtomicU64,
    /// Gauge: plan + model bytes currently resident.
    resident_bytes: AtomicU64,
    /// Per-compile wall time (µs).
    pub compile: LatencyHistogram,
    /// Per-request stall waiting on another thread's in-flight compile
    /// of the same model (µs) — the single-flight queueing cost.
    pub stall: LatencyHistogram,
}

impl PlanCacheCounters {
    /// The request found a ready compiled plan (the zero-alloc path).
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// The request missed: it either compiled the plan or waited on the
    /// thread that is compiling it.
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// An entry was evicted to make room under the byte budget.
    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// One cold compile completed (single-flight: concurrent misses on
    /// one model record exactly one compile).
    pub fn record_compile_us(&self, us: u64) {
        self.compiles.fetch_add(1, Ordering::Relaxed);
        self.compile.record_us(us.max(1));
    }

    /// One request stalled `us` µs behind an in-flight compile.
    pub fn record_stall_us(&self, us: u64) {
        self.stall.record_us(us.max(1));
    }

    /// Update the residency gauges after an insert/evict/retire.
    pub fn set_resident(&self, models: u64, bytes: u64) {
        self.resident.store(models, Ordering::Relaxed);
        self.resident_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    /// Simulated per-batch CiM latency. Values are recorded in
    /// **nanoseconds** (ps / 1000) — the log-bucket math is
    /// unit-agnostic, only the field names of [`LatencyHistogram`] say µs.
    pub sim_latency: LatencyHistogram,
    /// Host-side per-batch GEMM wall time (µs): what the backend spent
    /// computing each batch, excluding any simulated-latency gate. The
    /// counterpart of `sim_latency` — one report shows host speed next
    /// to CiM speed.
    pub host_gemm: LatencyHistogram,
    requests: AtomicU64,
    batches: AtomicU64,
    padded_slots: AtomicU64,
    /// Requests that passed admission (accepted into the batcher; they
    /// may still fail later — `requests` counts only *served* ones).
    accepted: AtomicU64,
    rejected: AtomicU64,
    /// Rejections that carried a structured `retry_after_us` hint
    /// (admission-control rejections do; a connection-limit turn-away
    /// at the TCP front-end has no batcher state to derive one from).
    retry_hints: AtomicU64,
    failed_batches: AtomicU64,
    failed_requests: AtomicU64,
    /// Simulated CiM energy total, in femtojoules (stored as fJ integer).
    sim_energy_fj: AtomicU64,
    /// LUT (re)programming events across all served batches.
    sim_programs: AtomicU64,
    /// Programs avoided by weight-stationary reuse.
    sim_stationary_hits: AtomicU64,
    /// Compiled-plan cache counters, shared with the engine's
    /// `PlanCache` (the coordinator hands it a clone of this `Arc`).
    pub plan_cache: Arc<PlanCacheCounters>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn record_batch(&self, batch_size: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.padded_slots.fetch_add((padded_to - batch_size) as u64, Ordering::Relaxed);
    }

    /// A request passed admission control.
    pub fn record_admission(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was rejected at admission. `retry_after_us > 0` means a
    /// structured retry hint was issued with the rejection (429-style);
    /// `0` records a hint-less turn-away (e.g. the TCP front-end's
    /// connection cap).
    pub fn record_rejection(&self, retry_after_us: u64) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        if retry_after_us > 0 {
            self.retry_hints.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A dispatched batch failed (worker error or dropped reply); its
    /// `requests` waiters were dropped and will surface "request dropped".
    pub fn record_batch_failure(&self, requests: usize) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(requests as u64, Ordering::Relaxed);
    }

    pub fn record_sim_energy_fj(&self, fj: f64) {
        self.sim_energy_fj.fetch_add(fj.round() as u64, Ordering::Relaxed);
    }

    /// Record one served batch's host-side GEMM wall time. Sub-µs
    /// batches clamp to 1 µs (the histogram's resolution floor).
    pub fn record_host_gemm_us(&self, us: u64) {
        self.host_gemm.record_us(us.max(1));
    }

    /// Record one served batch's simulated CiM cost (energy, modelled
    /// latency, programming events, weight-stationary hits).
    pub fn record_sim_cost(&self, cost: &ScheduleCost) {
        self.record_sim_energy_fj(cost.energy_fj);
        if cost.latency_ps > 0 {
            self.sim_latency.record_us((cost.latency_ps / 1000).max(1));
        }
        self.sim_programs.fetch_add(cost.programs, Ordering::Relaxed);
        self.sim_stationary_hits.fetch_add(cost.stationary_hits, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let pool = crate::util::pool::stats();
        MetricsSnapshot {
            pool,
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            retry_hints: self.retry_hints.load(Ordering::Relaxed),
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            failed_requests: self.failed_requests.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_latency_us: self.latency.quantile_us(0.50),
            p99_latency_us: self.latency.quantile_us(0.99),
            max_latency_us: self.latency.max_us(),
            throughput_rps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            sim_energy_fj: self.sim_energy_fj.load(Ordering::Relaxed) as f64,
            sim_p50_latency_ns: self.sim_latency.quantile_us(0.50),
            sim_p99_latency_ns: self.sim_latency.quantile_us(0.99),
            sim_programs: self.sim_programs.load(Ordering::Relaxed),
            sim_stationary_hits: self.sim_stationary_hits.load(Ordering::Relaxed),
            host_gemm_mean_us: self.host_gemm.mean_us(),
            host_gemm_p50_us: self.host_gemm.quantile_us(0.50),
            host_gemm_p99_us: self.host_gemm.quantile_us(0.99),
            plan_hits: self.plan_cache.hits(),
            plan_misses: self.plan_cache.misses(),
            plan_evictions: self.plan_cache.evictions.load(Ordering::Relaxed),
            plan_compiles: self.plan_cache.compiles(),
            plan_resident: self.plan_cache.resident.load(Ordering::Relaxed),
            plan_resident_bytes: self.plan_cache.resident_bytes.load(Ordering::Relaxed),
            plan_compile_p99_us: self.plan_cache.compile.quantile_us(0.99),
            plan_stall_p99_us: self.plan_cache.stall.quantile_us(0.99),
        }
    }
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Requests admitted by admission control (`requests` counts served).
    pub accepted: u64,
    pub rejected: u64,
    /// Rejections that carried a `retry_after_us` hint.
    pub retry_hints: u64,
    pub failed_batches: u64,
    pub failed_requests: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
    pub throughput_rps: f64,
    pub sim_energy_fj: f64,
    /// Simulated per-batch CiM latency percentiles (ns; bucket upper
    /// bounds of the sim-latency histogram).
    pub sim_p50_latency_ns: u64,
    pub sim_p99_latency_ns: u64,
    /// LUT (re)programming events across all served batches.
    pub sim_programs: u64,
    /// Programs avoided by weight-stationary reuse.
    pub sim_stationary_hits: u64,
    /// Host-side per-batch GEMM wall time (µs) — the backend's compute
    /// cost, next to the simulated CiM latency above.
    pub host_gemm_mean_us: f64,
    pub host_gemm_p50_us: u64,
    pub host_gemm_p99_us: u64,
    /// Compiled-plan cache: lookups that found a ready plan.
    pub plan_hits: u64,
    /// Lookups that compiled, or stalled behind an in-flight compile.
    pub plan_misses: u64,
    pub plan_evictions: u64,
    /// Cold compiles actually run (single-flight: ≤ one per miss burst).
    pub plan_compiles: u64,
    /// Gauge: models resident at snapshot time.
    pub plan_resident: u64,
    /// Gauge: plan + model bytes resident at snapshot time.
    pub plan_resident_bytes: u64,
    pub plan_compile_p99_us: u64,
    /// p99 time a request spent stalled behind another thread's compile.
    pub plan_stall_p99_us: u64,
    /// Buffer-pool counters at snapshot time (process-wide — the pool
    /// is shared by every server in the process; see
    /// [`crate::util::pool`]). A healthy steady state shows the hit
    /// rate converging to ~1.0: the serving hot path stops allocating.
    pub pool: crate::util::PoolStats,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (1.0 = always full batches).
    pub fn batch_occupancy(&self) -> f64 {
        let slots = self.requests + self.padded_slots;
        if slots == 0 {
            0.0
        } else {
            self.requests as f64 / slots as f64
        }
    }

    /// Fraction of LUT writes avoided by weight-stationary scheduling
    /// (0.0 when nothing has been scheduled yet).
    pub fn stationary_hit_rate(&self) -> f64 {
        let total = self.sim_programs + self.sim_stationary_hits;
        if total == 0 {
            0.0
        } else {
            self.sim_stationary_hits as f64 / total as f64
        }
    }

    /// Fraction of plan-cache lookups that found a ready plan (0.0
    /// before any lookup). Single-model serving converges to ~1.0 after
    /// the startup compile; multi-tenant serving under eviction pressure
    /// is exactly what this measures.
    pub fn plan_hit_rate(&self) -> f64 {
        let lookups = self.plan_hits + self.plan_misses;
        if lookups == 0 {
            0.0
        } else {
            self.plan_hits as f64 / lookups as f64
        }
    }

    /// Fraction of admission decisions that rejected (0.0 before any
    /// decision) — the serving-level overload signal next to latency.
    pub fn reject_rate(&self) -> f64 {
        let decisions = self.accepted + self.rejected;
        if decisions == 0 {
            0.0
        } else {
            self.rejected as f64 / decisions as f64
        }
    }

    /// Simulated CiM energy per served request (fJ).
    pub fn sim_energy_per_request_fj(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sim_energy_fj / self.requests as f64
        }
    }

    /// Multi-line human-readable report (the serve CLI prints this).
    pub fn render(&self) -> String {
        format!(
            "requests {} | batches {} (occupancy {:.2}) | \
             failed batches {} ({} requests)\n\
             admission accepted {} rejected {} (hints {}) | reject rate {:.3}\n\
             latency mean {:.0} us p50 {} us p99 {} us max {} us | \
             throughput {:.0} req/s\n\
             host gemm mean {:.0} us p50 {} us p99 {} us\n\
             pool hits {} misses {} recycled {} (hit rate {:.3})\n\
             plan cache hits {} misses {} (hit rate {:.3}) evictions {} compiles {} | \
             resident {} ({} KiB) | compile p99 {} us stall p99 {} us\n\
             sim energy {:.2} nJ ({:.1} fJ/req) | \
             sim latency p50 {} ns p99 {} ns | \
             programs {} stationary hits {} (hit-rate {:.2})\n",
            self.requests,
            self.batches,
            self.batch_occupancy(),
            self.failed_batches,
            self.failed_requests,
            self.accepted,
            self.rejected,
            self.retry_hints,
            self.reject_rate(),
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us,
            self.throughput_rps,
            self.host_gemm_mean_us,
            self.host_gemm_p50_us,
            self.host_gemm_p99_us,
            self.pool.hits,
            self.pool.misses,
            self.pool.recycled,
            self.pool.hit_rate(),
            self.plan_hits,
            self.plan_misses,
            self.plan_hit_rate(),
            self.plan_evictions,
            self.plan_compiles,
            self.plan_resident,
            self.plan_resident_bytes / 1024,
            self.plan_compile_p99_us,
            self.plan_stall_p99_us,
            self.sim_energy_fj / 1e6,
            self.sim_energy_per_request_fj(),
            self.sim_p50_latency_ns,
            self.sim_p99_latency_ns,
            self.sim_programs,
            self.sim_stationary_hits,
            self.stationary_hit_rate(),
        )
    }
}

/// Per-backend counters for one router endpoint (see
/// [`crate::net::router`]). All Relaxed — same monitoring-only audit as
/// the module header.
#[derive(Debug)]
struct BackendCounters {
    addr: String,
    /// Requests successfully written to this backend.
    routed: AtomicU64,
    /// `Rejected` replies this backend returned (admission pushback).
    rejected: AtomicU64,
    /// In-flight requests resolved with a retryable `Rejected` frame
    /// because this backend's link died under them.
    failed_over: AtomicU64,
    /// Healthy→quarantined transitions (a live link died, or the first
    /// probe of an unreachable endpoint failed).
    quarantines: AtomicU64,
    /// Quarantined→healthy transitions (a health probe's Hello/Info
    /// handshake succeeded again).
    recoveries: AtomicU64,
}

/// Router-tier metrics: one counter block per configured backend plus
/// fleet-level terminal rejections (requests no backend would take).
#[derive(Debug)]
pub struct RouterMetrics {
    backends: Vec<BackendCounters>,
    terminal_rejections: AtomicU64,
}

impl RouterMetrics {
    pub fn new(addrs: &[String]) -> Self {
        RouterMetrics {
            backends: addrs
                .iter()
                .map(|addr| BackendCounters {
                    addr: addr.clone(),
                    routed: AtomicU64::new(0),
                    rejected: AtomicU64::new(0),
                    failed_over: AtomicU64::new(0),
                    quarantines: AtomicU64::new(0),
                    recoveries: AtomicU64::new(0),
                })
                .collect(),
            terminal_rejections: AtomicU64::new(0),
        }
    }

    pub fn record_routed(&self, backend: usize) {
        self.backends[backend].routed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_backend_rejection(&self, backend: usize) {
        self.backends[backend].rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failed_over(&self, backend: usize) {
        self.backends[backend].failed_over.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantine(&self, backend: usize) {
        self.backends[backend].quarantines.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_recovery(&self, backend: usize) {
        self.backends[backend].recoveries.fetch_add(1, Ordering::Relaxed);
    }

    /// A request the router rejected back to the client because no
    /// backend would take it (all rejected / none healthy).
    pub fn record_terminal_rejection(&self) {
        self.terminal_rejections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            backends: self
                .backends
                .iter()
                .map(|b| BackendStats {
                    addr: b.addr.clone(),
                    routed: b.routed.load(Ordering::Relaxed),
                    rejected: b.rejected.load(Ordering::Relaxed),
                    failed_over: b.failed_over.load(Ordering::Relaxed),
                    quarantines: b.quarantines.load(Ordering::Relaxed),
                    recoveries: b.recoveries.load(Ordering::Relaxed),
                })
                .collect(),
            terminal_rejections: self.terminal_rejections.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one backend's router counters.
#[derive(Debug, Clone)]
pub struct BackendStats {
    pub addr: String,
    pub routed: u64,
    pub rejected: u64,
    pub failed_over: u64,
    pub quarantines: u64,
    pub recoveries: u64,
}

/// Point-in-time view of [`RouterMetrics`].
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    pub backends: Vec<BackendStats>,
    pub terminal_rejections: u64,
}

impl RouterSnapshot {
    pub fn routed_total(&self) -> u64 {
        self.backends.iter().map(|b| b.routed).sum()
    }

    pub fn failed_over_total(&self) -> u64 {
        self.backends.iter().map(|b| b.failed_over).sum()
    }

    pub fn quarantines_total(&self) -> u64 {
        self.backends.iter().map(|b| b.quarantines).sum()
    }

    /// Multi-line human-readable report (the route CLI prints this): a
    /// fleet summary line, then one line per backend.
    pub fn render(&self) -> String {
        let mut out = format!(
            "router routed {} failed-over {} quarantines {} terminal rejections {}\n",
            self.routed_total(),
            self.failed_over_total(),
            self.quarantines_total(),
            self.terminal_rejections,
        );
        for (i, b) in self.backends.iter().enumerate() {
            out.push_str(&format!(
                "backend {} {} routed {} rejected {} failed-over {} \
                 quarantined {} recovered {}\n",
                i, b.addr, b.routed, b.rejected, b.failed_over, b.quarantines, b.recoveries,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 5000);
    }

    #[test]
    fn batch_occupancy_accounts_padding() {
        let m = Metrics::new();
        m.record_batch(6, 8);
        m.record_batch(8, 8);
        let snap = m.snapshot();
        assert_eq!(snap.requests, 14);
        assert_eq!(snap.padded_slots, 2);
        assert!((snap.batch_occupancy() - 14.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn batch_failures_are_counted_and_rendered() {
        let m = Metrics::new();
        m.record_batch(8, 8);
        m.record_batch_failure(8);
        m.record_batch_failure(3);
        let snap = m.snapshot();
        assert_eq!(snap.failed_batches, 2);
        assert_eq!(snap.failed_requests, 11);
        let report = snap.render();
        assert!(report.contains("failed batches 2 (11 requests)"), "{report}");
    }

    #[test]
    fn admission_counters_and_reject_rate_render() {
        let m = Metrics::new();
        for _ in 0..6 {
            m.record_admission();
        }
        m.record_rejection(1500); // hinted 429
        m.record_rejection(0); // hint-less turn-away (connection cap)
        let snap = m.snapshot();
        assert_eq!(snap.accepted, 6);
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.retry_hints, 1);
        assert!((snap.reject_rate() - 2.0 / 8.0).abs() < 1e-12);
        let report = snap.render();
        assert!(report.contains("admission accepted 6 rejected 2 (hints 1)"), "{report}");
        assert!(report.contains("reject rate 0.250"), "{report}");
    }

    #[test]
    fn reject_rate_is_zero_without_decisions() {
        assert_eq!(Metrics::new().snapshot().reject_rate(), 0.0);
    }

    #[test]
    fn energy_accumulates() {
        let m = Metrics::new();
        m.record_sim_energy_fj(100.4);
        m.record_sim_energy_fj(50.3);
        assert!((m.snapshot().sim_energy_fj - 150.0).abs() <= 1.0);
    }

    #[test]
    fn sim_cost_aggregates_and_renders() {
        let m = Metrics::new();
        m.record_batch(8, 8);
        m.record_sim_cost(&ScheduleCost {
            latency_ps: 2_000_000, // 2000 ns
            energy_fj: 1000.0,
            programs: 90,
            stationary_hits: 10,
        });
        m.record_sim_cost(&ScheduleCost {
            latency_ps: 500_000, // 500 ns
            energy_fj: 500.0,
            programs: 0,
            stationary_hits: 100,
        });
        let snap = m.snapshot();
        assert_eq!(snap.sim_programs, 90);
        assert_eq!(snap.sim_stationary_hits, 110);
        assert!((snap.stationary_hit_rate() - 110.0 / 200.0).abs() < 1e-12);
        assert!((snap.sim_energy_fj - 1500.0).abs() <= 1.0);
        assert!(snap.sim_p50_latency_ns >= 500);
        assert!(snap.sim_p50_latency_ns <= snap.sim_p99_latency_ns);
        // 2000 ns falls in the [1024, 2048) bucket → p99 upper bound 2048
        assert!(snap.sim_p99_latency_ns >= 2000);
        let report = snap.render();
        assert!(report.contains("sim latency p50"), "{report}");
        assert!(report.contains("hit-rate 0.55"), "{report}");
        assert!(report.contains("fJ/req"), "{report}");
    }

    #[test]
    fn hit_rate_is_zero_without_sim_data() {
        let snap = Metrics::new().snapshot();
        assert_eq!(snap.stationary_hit_rate(), 0.0);
        assert_eq!(snap.sim_energy_per_request_fj(), 0.0);
        assert_eq!(snap.sim_p50_latency_ns, 0);
        assert_eq!(snap.host_gemm_p50_us, 0);
        assert_eq!(snap.host_gemm_mean_us, 0.0);
    }

    #[test]
    fn host_gemm_time_aggregates_and_renders() {
        let m = Metrics::new();
        m.record_host_gemm_us(0); // sub-µs batch clamps to the 1 µs floor
        m.record_host_gemm_us(40);
        m.record_host_gemm_us(900);
        let snap = m.snapshot();
        assert_eq!(m.host_gemm.count(), 3);
        assert!(snap.host_gemm_mean_us > 0.0);
        assert!(snap.host_gemm_p50_us <= snap.host_gemm_p99_us);
        assert!(snap.host_gemm_p99_us >= 900, "p99 bucket bound covers the max sample");
        let report = snap.render();
        assert!(report.contains("host gemm mean"), "{report}");
    }

    #[test]
    fn router_counters_aggregate_per_backend_and_render() {
        let m = RouterMetrics::new(&["127.0.0.1:7071".to_string(), "127.0.0.1:7072".to_string()]);
        m.record_routed(0);
        m.record_routed(0);
        m.record_routed(1);
        m.record_backend_rejection(1);
        m.record_failed_over(1);
        m.record_quarantine(1);
        m.record_recovery(1);
        m.record_terminal_rejection();
        let snap = m.snapshot();
        assert_eq!(snap.backends.len(), 2);
        assert_eq!(snap.backends[0].routed, 2);
        assert_eq!(snap.backends[0].failed_over, 0);
        assert_eq!(snap.backends[1].routed, 1);
        assert_eq!(snap.backends[1].rejected, 1);
        assert_eq!(snap.backends[1].failed_over, 1);
        assert_eq!(snap.backends[1].quarantines, 1);
        assert_eq!(snap.backends[1].recoveries, 1);
        assert_eq!(snap.routed_total(), 3);
        assert_eq!(snap.failed_over_total(), 1);
        assert_eq!(snap.quarantines_total(), 1);
        assert_eq!(snap.terminal_rejections, 1);
        let report = snap.render();
        assert!(
            report.contains("router routed 3 failed-over 1 quarantines 1 terminal rejections 1"),
            "{report}"
        );
        assert!(report.contains("backend 0 127.0.0.1:7071 routed 2"), "{report}");
        assert!(
            report.contains("backend 1 127.0.0.1:7072 routed 1 rejected 1 failed-over 1"),
            "{report}"
        );
    }

    #[test]
    fn plan_cache_counters_aggregate_and_render() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.plan_cache.record_hit();
        }
        m.plan_cache.record_miss();
        m.plan_cache.record_compile_us(1800);
        m.plan_cache.record_stall_us(250);
        m.plan_cache.record_eviction();
        m.plan_cache.set_resident(2, 64 * 1024);
        let snap = m.snapshot();
        assert_eq!(snap.plan_hits, 3);
        assert_eq!(snap.plan_misses, 1);
        assert_eq!(snap.plan_compiles, 1);
        assert_eq!(snap.plan_evictions, 1);
        assert_eq!(snap.plan_resident, 2);
        assert_eq!(snap.plan_resident_bytes, 64 * 1024);
        assert!((snap.plan_hit_rate() - 0.75).abs() < 1e-12);
        assert!(snap.plan_compile_p99_us >= 1800);
        assert!(snap.plan_stall_p99_us >= 250);
        let report = snap.render();
        assert!(report.contains("plan cache hits 3 misses 1 (hit rate 0.750)"), "{report}");
        assert!(report.contains("resident 2 (64 KiB)"), "{report}");
    }

    #[test]
    fn plan_hit_rate_is_zero_without_lookups() {
        assert_eq!(Metrics::new().snapshot().plan_hit_rate(), 0.0);
    }

    #[test]
    fn pool_line_renders_with_bounded_hit_rate() {
        // exercise the pool so the process-wide counters move
        let v = crate::util::PooledVec::<f32>::with_capacity(64);
        drop(v);
        let _again = crate::util::PooledVec::<f32>::with_capacity(64);
        let snap = Metrics::new().snapshot();
        assert!(snap.pool.hits + snap.pool.misses > 0);
        let r = snap.pool.hit_rate();
        assert!((0.0..=1.0).contains(&r), "hit rate {r}");
        let report = snap.render();
        assert!(report.contains("pool hits"), "{report}");
        assert!(report.contains("hit rate"), "{report}");
    }
}
