//! Serving metrics: latency histograms, throughput and energy counters.
//!
//! Lock-free on the hot path (atomics only); every duration metric
//! records into the shared fixed-bucket log₂ histogram
//! ([`crate::util::hist::LatencyHistogram`]), so recording is a couple
//! of atomic adds. Snapshots render three ways: human text
//! ([`MetricsSnapshot::render`]), JSON ([`MetricsSnapshot::render_json`])
//! and Prometheus text exposition ([`MetricsSnapshot::render_prom`]) —
//! the latter two back the `GetStats` wire scrape (`repro stats`).
//!
//! Ordering audit: every atomic access here is Relaxed by design. These
//! are monotonic monitoring counters — a snapshot tolerates tearing
//! across counters (it is a statistical view, not a consistent cut),
//! and nothing is published through them. The same tearing caveat
//! applies to a wire-scraped snapshot versus an in-process one taken
//! concurrently: individual counters are exact, cross-counter sums may
//! disagree transiently.

use super::tiler::ScheduleCost;
use crate::net::ModelId;
use crate::util::trace::{Stage, N_STAGES};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use crate::util::hist::LatencyHistogram;

/// Compiled-plan cache counters, shared between the engine-level
/// [`crate::engine::PlanCache`] (which records) and the serving metrics
/// (which render). Same Relaxed monitoring-only audit as the module
/// header; `resident`/`resident_bytes` are gauges, the rest monotonic.
#[derive(Debug, Default)]
pub struct PlanCacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    compiles: AtomicU64,
    /// Gauge: models currently resident in the cache.
    resident: AtomicU64,
    /// Gauge: plan + model bytes currently resident.
    resident_bytes: AtomicU64,
    /// Per-compile wall time (µs).
    pub compile: LatencyHistogram,
    /// Per-request stall waiting on another thread's in-flight compile
    /// of the same model (µs) — the single-flight queueing cost.
    pub stall: LatencyHistogram,
}

impl PlanCacheCounters {
    /// The request found a ready compiled plan (the zero-alloc path).
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// The request missed: it either compiled the plan or waited on the
    /// thread that is compiling it.
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// An entry was evicted to make room under the byte budget.
    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// One cold compile completed (single-flight: concurrent misses on
    /// one model record exactly one compile).
    pub fn record_compile_us(&self, us: u64) {
        self.compiles.fetch_add(1, Ordering::Relaxed);
        self.compile.record_us(us.max(1));
    }

    /// One request stalled `us` µs behind an in-flight compile.
    pub fn record_stall_us(&self, us: u64) {
        self.stall.record_us(us.max(1));
    }

    /// Update the residency gauges after an insert/evict/retire.
    pub fn set_resident(&self, models: u64, bytes: u64) {
        self.resident.store(models, Ordering::Relaxed);
        self.resident_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Per-tenant latency breakdown: end-to-end request latency plus the
/// queue-wait component, one pair of histograms per resident model.
/// Registered once per model (cold path) and cached as an `Arc` on the
/// model slot, so hot-path recording stays lock-free.
#[derive(Debug, Default)]
pub struct TenantLat {
    /// End-to-end enqueue→completion latency (µs).
    pub latency: LatencyHistogram,
    /// Time-in-queue component (enqueue→batch formation, µs).
    pub queue: LatencyHistogram,
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    /// Simulated per-batch CiM latency. Values are recorded in
    /// **nanoseconds** (ps / 1000) — the log-bucket math is
    /// unit-agnostic, only the field names of [`LatencyHistogram`] say µs.
    pub sim_latency: LatencyHistogram,
    /// Host-side per-batch GEMM wall time (µs): what the backend spent
    /// computing each batch, excluding any simulated-latency gate. The
    /// counterpart of `sim_latency` — one report shows host speed next
    /// to CiM speed.
    pub host_gemm: LatencyHistogram,
    /// Per-stage time-in-stage histograms (µs), indexed by
    /// [`Stage`] — the latency *breakdown* next to the end-to-end
    /// histogram above. Recorded for every request (spans additionally
    /// go to the flight recorder for sampled ones).
    pub stages: [LatencyHistogram; N_STAGES],
    requests: AtomicU64,
    batches: AtomicU64,
    padded_slots: AtomicU64,
    /// Requests that passed admission (accepted into the batcher; they
    /// may still fail later — `requests` counts only *served* ones).
    accepted: AtomicU64,
    rejected: AtomicU64,
    /// Rejections that carried a structured `retry_after_us` hint
    /// (admission-control rejections do; a connection-limit turn-away
    /// at the TCP front-end has no batcher state to derive one from).
    retry_hints: AtomicU64,
    failed_batches: AtomicU64,
    failed_requests: AtomicU64,
    /// Simulated CiM energy total, in femtojoules (stored as fJ integer).
    sim_energy_fj: AtomicU64,
    /// LUT (re)programming events across all served batches.
    sim_programs: AtomicU64,
    /// Programs avoided by weight-stationary reuse.
    sim_stationary_hits: AtomicU64,
    /// Compiled-plan cache counters, shared with the engine's
    /// `PlanCache` (the coordinator hands it a clone of this `Arc`).
    pub plan_cache: Arc<PlanCacheCounters>,
    /// Per-tenant histogram registry (cold path: mutated only at model
    /// registration; the hot path records through cached `Arc`s).
    tenants: Mutex<Vec<(ModelId, Arc<TenantLat>)>>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn record_batch(&self, batch_size: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.padded_slots.fetch_add((padded_to - batch_size) as u64, Ordering::Relaxed);
    }

    /// A request passed admission control.
    pub fn record_admission(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was rejected at admission. `retry_after_us > 0` means a
    /// structured retry hint was issued with the rejection (429-style);
    /// `0` records a hint-less turn-away (e.g. the TCP front-end's
    /// connection cap).
    pub fn record_rejection(&self, retry_after_us: u64) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        if retry_after_us > 0 {
            self.retry_hints.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A dispatched batch failed (worker error or dropped reply); its
    /// `requests` waiters were dropped and will surface "request dropped".
    pub fn record_batch_failure(&self, requests: usize) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(requests as u64, Ordering::Relaxed);
    }

    pub fn record_sim_energy_fj(&self, fj: f64) {
        self.sim_energy_fj.fetch_add(fj.round() as u64, Ordering::Relaxed);
    }

    /// Record one served batch's host-side GEMM wall time. Sub-µs
    /// batches clamp to 1 µs (the histogram's resolution floor).
    pub fn record_host_gemm_us(&self, us: u64) {
        self.host_gemm.record_us(us.max(1));
    }

    /// Record time spent in one pipeline stage (µs, clamped to the
    /// histogram's 1 µs floor). Lock-free, allocation-free.
    pub fn record_stage_us(&self, stage: Stage, us: u64) {
        self.stages[stage as usize].record_us(us.max(1));
    }

    /// Fetch (registering on first use) the per-tenant histograms for
    /// `model`. Takes the registry lock — cold path only; callers cache
    /// the returned `Arc` (the coordinator stores it on the model slot).
    pub fn tenant(&self, model: ModelId) -> Arc<TenantLat> {
        let mut reg = self.tenants.lock().expect("tenant registry lock");
        if let Some((_, lat)) = reg.iter().find(|(m, _)| *m == model) {
            return lat.clone();
        }
        let lat = Arc::new(TenantLat::default());
        reg.push((model, lat.clone()));
        lat
    }

    /// Record one served batch's simulated CiM cost (energy, modelled
    /// latency, programming events, weight-stationary hits).
    pub fn record_sim_cost(&self, cost: &ScheduleCost) {
        self.record_sim_energy_fj(cost.energy_fj);
        if cost.latency_ps > 0 {
            self.sim_latency.record_us((cost.latency_ps / 1000).max(1));
        }
        self.sim_programs.fetch_add(cost.programs, Ordering::Relaxed);
        self.sim_stationary_hits.fetch_add(cost.stationary_hits, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let pool = crate::util::pool::stats();
        let mut stage_count = [0u64; N_STAGES];
        let mut stage_p50_us = [0u64; N_STAGES];
        let mut stage_p99_us = [0u64; N_STAGES];
        for (i, h) in self.stages.iter().enumerate() {
            stage_count[i] = h.count();
            stage_p50_us[i] = h.quantile_us(0.50);
            stage_p99_us[i] = h.quantile_us(0.99);
        }
        let mut tenants: Vec<TenantStats> = self
            .tenants
            .lock()
            .expect("tenant registry lock")
            .iter()
            .map(|(model, lat)| TenantStats {
                name: tenant_label(model),
                requests: lat.latency.count(),
                p50_latency_us: lat.latency.quantile_us(0.50),
                p99_latency_us: lat.latency.quantile_us(0.99),
                p50_queue_us: lat.queue.quantile_us(0.50),
                p99_queue_us: lat.queue.quantile_us(0.99),
            })
            .collect();
        tenants.sort_by(|a, b| a.name.cmp(&b.name));
        MetricsSnapshot {
            pool,
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            retry_hints: self.retry_hints.load(Ordering::Relaxed),
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            failed_requests: self.failed_requests.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_latency_us: self.latency.quantile_us(0.50),
            p99_latency_us: self.latency.quantile_us(0.99),
            max_latency_us: self.latency.max_us(),
            throughput_rps: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            sim_energy_fj: self.sim_energy_fj.load(Ordering::Relaxed) as f64,
            sim_p50_latency_ns: self.sim_latency.quantile_us(0.50),
            sim_p99_latency_ns: self.sim_latency.quantile_us(0.99),
            sim_programs: self.sim_programs.load(Ordering::Relaxed),
            sim_stationary_hits: self.sim_stationary_hits.load(Ordering::Relaxed),
            host_gemm_mean_us: self.host_gemm.mean_us(),
            host_gemm_p50_us: self.host_gemm.quantile_us(0.50),
            host_gemm_p99_us: self.host_gemm.quantile_us(0.99),
            plan_hits: self.plan_cache.hits(),
            plan_misses: self.plan_cache.misses(),
            plan_evictions: self.plan_cache.evictions.load(Ordering::Relaxed),
            plan_compiles: self.plan_cache.compiles(),
            plan_resident: self.plan_cache.resident.load(Ordering::Relaxed),
            plan_resident_bytes: self.plan_cache.resident_bytes.load(Ordering::Relaxed),
            plan_compile_p99_us: self.plan_cache.compile.quantile_us(0.99),
            plan_stall_p99_us: self.plan_cache.stall.quantile_us(0.99),
            stage_count,
            stage_p50_us,
            stage_p99_us,
            tenants,
        }
    }
}

/// The render/scrape label for a model id (`"default"` for the default
/// model — the empty id has to name itself somehow in a report).
fn tenant_label(model: &ModelId) -> String {
    if model.is_default() {
        "default".to_string()
    } else {
        model.as_str().to_string()
    }
}

/// Point-in-time per-tenant latency view (one per resident model).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStats {
    /// Model id, or `"default"` for the default model.
    pub name: String,
    /// Requests served for this tenant (latency histogram count).
    pub requests: u64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub p50_queue_us: u64,
    pub p99_queue_us: u64,
}

/// Point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Requests admitted by admission control (`requests` counts served).
    pub accepted: u64,
    pub rejected: u64,
    /// Rejections that carried a `retry_after_us` hint.
    pub retry_hints: u64,
    pub failed_batches: u64,
    pub failed_requests: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
    pub throughput_rps: f64,
    pub sim_energy_fj: f64,
    /// Simulated per-batch CiM latency percentiles (ns; bucket upper
    /// bounds of the sim-latency histogram).
    pub sim_p50_latency_ns: u64,
    pub sim_p99_latency_ns: u64,
    /// LUT (re)programming events across all served batches.
    pub sim_programs: u64,
    /// Programs avoided by weight-stationary reuse.
    pub sim_stationary_hits: u64,
    /// Host-side per-batch GEMM wall time (µs) — the backend's compute
    /// cost, next to the simulated CiM latency above.
    pub host_gemm_mean_us: f64,
    pub host_gemm_p50_us: u64,
    pub host_gemm_p99_us: u64,
    /// Compiled-plan cache: lookups that found a ready plan.
    pub plan_hits: u64,
    /// Lookups that compiled, or stalled behind an in-flight compile.
    pub plan_misses: u64,
    pub plan_evictions: u64,
    /// Cold compiles actually run (single-flight: ≤ one per miss burst).
    pub plan_compiles: u64,
    /// Gauge: models resident at snapshot time.
    pub plan_resident: u64,
    /// Gauge: plan + model bytes resident at snapshot time.
    pub plan_resident_bytes: u64,
    pub plan_compile_p99_us: u64,
    /// p99 time a request spent stalled behind another thread's compile.
    pub plan_stall_p99_us: u64,
    /// Per-stage time-in-stage sample counts, indexed by
    /// [`Stage`] pipeline order.
    pub stage_count: [u64; N_STAGES],
    pub stage_p50_us: [u64; N_STAGES],
    pub stage_p99_us: [u64; N_STAGES],
    /// Per-tenant latency breakdown, sorted by tenant name.
    pub tenants: Vec<TenantStats>,
    /// Buffer-pool counters at snapshot time (process-wide — the pool
    /// is shared by every server in the process; see
    /// [`crate::util::pool`]). A healthy steady state shows the hit
    /// rate converging to ~1.0: the serving hot path stops allocating.
    pub pool: crate::util::PoolStats,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (1.0 = always full batches).
    pub fn batch_occupancy(&self) -> f64 {
        let slots = self.requests + self.padded_slots;
        if slots == 0 {
            0.0
        } else {
            self.requests as f64 / slots as f64
        }
    }

    /// Fraction of LUT writes avoided by weight-stationary scheduling
    /// (0.0 when nothing has been scheduled yet).
    pub fn stationary_hit_rate(&self) -> f64 {
        let total = self.sim_programs + self.sim_stationary_hits;
        if total == 0 {
            0.0
        } else {
            self.sim_stationary_hits as f64 / total as f64
        }
    }

    /// Fraction of plan-cache lookups that found a ready plan (0.0
    /// before any lookup). Single-model serving converges to ~1.0 after
    /// the startup compile; multi-tenant serving under eviction pressure
    /// is exactly what this measures.
    pub fn plan_hit_rate(&self) -> f64 {
        let lookups = self.plan_hits + self.plan_misses;
        if lookups == 0 {
            0.0
        } else {
            self.plan_hits as f64 / lookups as f64
        }
    }

    /// Fraction of admission decisions that rejected (0.0 before any
    /// decision) — the serving-level overload signal next to latency.
    pub fn reject_rate(&self) -> f64 {
        let decisions = self.accepted + self.rejected;
        if decisions == 0 {
            0.0
        } else {
            self.rejected as f64 / decisions as f64
        }
    }

    /// Simulated CiM energy per served request (fJ).
    pub fn sim_energy_per_request_fj(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sim_energy_fj / self.requests as f64
        }
    }

    /// Multi-line human-readable report (the serve CLI prints this).
    pub fn render(&self) -> String {
        let mut out = format!(
            "requests {} | batches {} (occupancy {:.2}) | \
             failed batches {} ({} requests)\n\
             admission accepted {} rejected {} (hints {}) | reject rate {:.3}\n\
             latency mean {:.0} us p50 {} us p99 {} us max {} us | \
             throughput {:.0} req/s\n\
             host gemm mean {:.0} us p50 {} us p99 {} us\n\
             pool hits {} misses {} recycled {} (hit rate {:.3})\n\
             plan cache hits {} misses {} (hit rate {:.3}) evictions {} compiles {} | \
             resident {} ({} KiB) | compile p99 {} us stall p99 {} us\n\
             sim energy {:.2} nJ ({:.1} fJ/req) | \
             sim latency p50 {} ns p99 {} ns | \
             programs {} stationary hits {} (hit-rate {:.2})\n",
            self.requests,
            self.batches,
            self.batch_occupancy(),
            self.failed_batches,
            self.failed_requests,
            self.accepted,
            self.rejected,
            self.retry_hints,
            self.reject_rate(),
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us,
            self.throughput_rps,
            self.host_gemm_mean_us,
            self.host_gemm_p50_us,
            self.host_gemm_p99_us,
            self.pool.hits,
            self.pool.misses,
            self.pool.recycled,
            self.pool.hit_rate(),
            self.plan_hits,
            self.plan_misses,
            self.plan_hit_rate(),
            self.plan_evictions,
            self.plan_compiles,
            self.plan_resident,
            self.plan_resident_bytes / 1024,
            self.plan_compile_p99_us,
            self.plan_stall_p99_us,
            self.sim_energy_fj / 1e6,
            self.sim_energy_per_request_fj(),
            self.sim_p50_latency_ns,
            self.sim_p99_latency_ns,
            self.sim_programs,
            self.sim_stationary_hits,
            self.stationary_hit_rate(),
        );
        out.push_str("stage p99 us:");
        for (i, s) in Stage::ALL.iter().enumerate() {
            let _ = write!(out, " {} {}", s.name(), self.stage_p99_us[i]);
        }
        out.push('\n');
        for t in &self.tenants {
            let _ = writeln!(
                out,
                "tenant {} requests {} latency p50 {} us p99 {} us | \
                 queue p50 {} us p99 {} us",
                t.name,
                t.requests,
                t.p50_latency_us,
                t.p99_latency_us,
                t.p50_queue_us,
                t.p99_queue_us,
            );
        }
        out
    }

    /// JSON object form of the snapshot (hand-rolled — no serde in this
    /// offline image). Field names are stable; additions are
    /// append-only like the wire codec's.
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        out.push('{');
        let _ = write!(
            out,
            "\"requests\":{},\"batches\":{},\"padded_slots\":{},\"accepted\":{},\
             \"rejected\":{},\"retry_hints\":{},\"failed_batches\":{},\"failed_requests\":{},\
             \"mean_latency_us\":{:.1},\"p50_latency_us\":{},\"p99_latency_us\":{},\
             \"max_latency_us\":{},\"throughput_rps\":{:.1},\"sim_energy_fj\":{:.1},\
             \"sim_p50_latency_ns\":{},\"sim_p99_latency_ns\":{},\"sim_programs\":{},\
             \"sim_stationary_hits\":{},\"host_gemm_mean_us\":{:.1},\"host_gemm_p50_us\":{},\
             \"host_gemm_p99_us\":{},\"plan_hits\":{},\"plan_misses\":{},\"plan_evictions\":{},\
             \"plan_compiles\":{},\"plan_resident\":{},\"plan_resident_bytes\":{},\
             \"plan_compile_p99_us\":{},\"plan_stall_p99_us\":{},\
             \"pool_hits\":{},\"pool_misses\":{},\"pool_recycled\":{}",
            self.requests,
            self.batches,
            self.padded_slots,
            self.accepted,
            self.rejected,
            self.retry_hints,
            self.failed_batches,
            self.failed_requests,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.max_latency_us,
            self.throughput_rps,
            self.sim_energy_fj,
            self.sim_p50_latency_ns,
            self.sim_p99_latency_ns,
            self.sim_programs,
            self.sim_stationary_hits,
            self.host_gemm_mean_us,
            self.host_gemm_p50_us,
            self.host_gemm_p99_us,
            self.plan_hits,
            self.plan_misses,
            self.plan_evictions,
            self.plan_compiles,
            self.plan_resident,
            self.plan_resident_bytes,
            self.plan_compile_p99_us,
            self.plan_stall_p99_us,
            self.pool.hits,
            self.pool.misses,
            self.pool.recycled,
        );
        out.push_str(",\"stages\":{");
        for (i, s) in Stage::ALL.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\"{}\":{{\"count\":{},\"p50_us\":{},\"p99_us\":{}}}",
                s.name(),
                self.stage_count[i],
                self.stage_p50_us[i],
                self.stage_p99_us[i],
            );
        }
        out.push_str("},\"tenants\":[");
        for (i, t) in self.tenants.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"requests\":{},\"p50_latency_us\":{},\
                 \"p99_latency_us\":{},\"p50_queue_us\":{},\"p99_queue_us\":{}}}",
                t.name,
                t.requests,
                t.p50_latency_us,
                t.p99_latency_us,
                t.p50_queue_us,
                t.p99_queue_us,
            );
        }
        out.push_str("]}");
        out
    }

    /// Prometheus text exposition, all metrics prefixed `luna_`.
    pub fn render_prom(&self) -> String {
        let mut out = String::new();
        self.render_prom_into(&mut out, "", true);
        out
    }

    /// [`Self::render_prom`] into a caller buffer. `labels` (e.g.
    /// `backend="127.0.0.1:7071"`) is folded into every sample's label
    /// set; `headers` controls the `# TYPE` lines (emit them once when
    /// rendering several backends' snapshots into one document — note
    /// that multi-backend documents interleave metric groups, which
    /// scrapers accept but `promtool check metrics` flags as a style
    /// warning).
    pub fn render_prom_into(&self, out: &mut String, labels: &str, headers: bool) {
        let sample = |out: &mut String, name: &str, extra: &str, v: &str| {
            out.push_str(name);
            match (labels.is_empty(), extra.is_empty()) {
                (true, true) => {}
                (false, true) => {
                    let _ = write!(out, "{{{labels}}}");
                }
                (true, false) => {
                    let _ = write!(out, "{{{extra}}}");
                }
                (false, false) => {
                    let _ = write!(out, "{{{labels},{extra}}}");
                }
            }
            let _ = writeln!(out, " {v}");
        };
        let counter = |out: &mut String, name: &str, v: u64| {
            if headers {
                let _ = writeln!(out, "# TYPE {name} counter");
            }
            sample(out, name, "", &v.to_string());
        };
        let gauge = |out: &mut String, name: &str, v: f64| {
            if headers {
                let _ = writeln!(out, "# TYPE {name} gauge");
            }
            sample(out, name, "", &format!("{v:.1}"));
        };
        counter(out, "luna_requests_total", self.requests);
        counter(out, "luna_batches_total", self.batches);
        counter(out, "luna_accepted_total", self.accepted);
        counter(out, "luna_rejected_total", self.rejected);
        counter(out, "luna_retry_hints_total", self.retry_hints);
        counter(out, "luna_failed_batches_total", self.failed_batches);
        counter(out, "luna_failed_requests_total", self.failed_requests);
        gauge(out, "luna_latency_mean_us", self.mean_latency_us);
        if headers {
            let _ = writeln!(out, "# TYPE luna_latency_us gauge");
        }
        sample(out, "luna_latency_us", "quantile=\"0.5\"", &self.p50_latency_us.to_string());
        sample(out, "luna_latency_us", "quantile=\"0.99\"", &self.p99_latency_us.to_string());
        gauge(out, "luna_throughput_rps", self.throughput_rps);
        counter(out, "luna_sim_energy_fj_total", self.sim_energy_fj as u64);
        counter(out, "luna_sim_programs_total", self.sim_programs);
        counter(out, "luna_sim_stationary_hits_total", self.sim_stationary_hits);
        gauge(out, "luna_host_gemm_p99_us", self.host_gemm_p99_us as f64);
        counter(out, "luna_plan_cache_hits_total", self.plan_hits);
        counter(out, "luna_plan_cache_misses_total", self.plan_misses);
        counter(out, "luna_plan_cache_evictions_total", self.plan_evictions);
        counter(out, "luna_plan_cache_compiles_total", self.plan_compiles);
        gauge(out, "luna_plan_cache_resident", self.plan_resident as f64);
        gauge(out, "luna_plan_cache_resident_bytes", self.plan_resident_bytes as f64);
        counter(out, "luna_pool_hits_total", self.pool.hits);
        counter(out, "luna_pool_misses_total", self.pool.misses);
        if headers {
            let _ = writeln!(out, "# TYPE luna_stage_count_total counter");
            let _ = writeln!(out, "# TYPE luna_stage_p50_us gauge");
            let _ = writeln!(out, "# TYPE luna_stage_p99_us gauge");
        }
        for (i, s) in Stage::ALL.iter().enumerate() {
            let label = format!("stage=\"{}\"", s.name());
            sample(out, "luna_stage_count_total", &label, &self.stage_count[i].to_string());
            sample(out, "luna_stage_p50_us", &label, &self.stage_p50_us[i].to_string());
            sample(out, "luna_stage_p99_us", &label, &self.stage_p99_us[i].to_string());
        }
        if headers && !self.tenants.is_empty() {
            let _ = writeln!(out, "# TYPE luna_tenant_requests_total counter");
            let _ = writeln!(out, "# TYPE luna_tenant_p99_latency_us gauge");
            let _ = writeln!(out, "# TYPE luna_tenant_p99_queue_us gauge");
        }
        for t in &self.tenants {
            let label = format!("tenant=\"{}\"", t.name);
            sample(out, "luna_tenant_requests_total", &label, &t.requests.to_string());
            sample(out, "luna_tenant_p99_latency_us", &label, &t.p99_latency_us.to_string());
            sample(out, "luna_tenant_p99_queue_us", &label, &t.p99_queue_us.to_string());
        }
    }
}

/// Per-backend counters for one router endpoint (see
/// [`crate::net::router`]). All Relaxed — same monitoring-only audit as
/// the module header.
#[derive(Debug)]
struct BackendCounters {
    addr: String,
    /// Requests successfully written to this backend.
    routed: AtomicU64,
    /// `Rejected` replies this backend returned (admission pushback).
    rejected: AtomicU64,
    /// In-flight requests resolved with a retryable `Rejected` frame
    /// because this backend's link died under them.
    failed_over: AtomicU64,
    /// Healthy→quarantined transitions (a live link died, or the first
    /// probe of an unreachable endpoint failed).
    quarantines: AtomicU64,
    /// Quarantined→healthy transitions (a health probe's Hello/Info
    /// handshake succeeded again).
    recoveries: AtomicU64,
}

/// Router-tier metrics: one counter block per configured backend plus
/// fleet-level terminal rejections (requests no backend would take).
#[derive(Debug)]
pub struct RouterMetrics {
    backends: Vec<BackendCounters>,
    terminal_rejections: AtomicU64,
}

impl RouterMetrics {
    pub fn new(addrs: &[String]) -> Self {
        RouterMetrics {
            backends: addrs
                .iter()
                .map(|addr| BackendCounters {
                    addr: addr.clone(),
                    routed: AtomicU64::new(0),
                    rejected: AtomicU64::new(0),
                    failed_over: AtomicU64::new(0),
                    quarantines: AtomicU64::new(0),
                    recoveries: AtomicU64::new(0),
                })
                .collect(),
            terminal_rejections: AtomicU64::new(0),
        }
    }

    pub fn record_routed(&self, backend: usize) {
        self.backends[backend].routed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_backend_rejection(&self, backend: usize) {
        self.backends[backend].rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failed_over(&self, backend: usize) {
        self.backends[backend].failed_over.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantine(&self, backend: usize) {
        self.backends[backend].quarantines.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_recovery(&self, backend: usize) {
        self.backends[backend].recoveries.fetch_add(1, Ordering::Relaxed);
    }

    /// A request the router rejected back to the client because no
    /// backend would take it (all rejected / none healthy).
    pub fn record_terminal_rejection(&self) {
        self.terminal_rejections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            backends: self
                .backends
                .iter()
                .map(|b| BackendStats {
                    addr: b.addr.clone(),
                    routed: b.routed.load(Ordering::Relaxed),
                    rejected: b.rejected.load(Ordering::Relaxed),
                    failed_over: b.failed_over.load(Ordering::Relaxed),
                    quarantines: b.quarantines.load(Ordering::Relaxed),
                    recoveries: b.recoveries.load(Ordering::Relaxed),
                })
                .collect(),
            terminal_rejections: self.terminal_rejections.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one backend's router counters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendStats {
    pub addr: String,
    pub routed: u64,
    pub rejected: u64,
    pub failed_over: u64,
    pub quarantines: u64,
    pub recoveries: u64,
}

/// Point-in-time view of [`RouterMetrics`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouterSnapshot {
    pub backends: Vec<BackendStats>,
    pub terminal_rejections: u64,
}

impl RouterSnapshot {
    pub fn routed_total(&self) -> u64 {
        self.backends.iter().map(|b| b.routed).sum()
    }

    pub fn failed_over_total(&self) -> u64 {
        self.backends.iter().map(|b| b.failed_over).sum()
    }

    pub fn quarantines_total(&self) -> u64 {
        self.backends.iter().map(|b| b.quarantines).sum()
    }

    /// Multi-line human-readable report (the route CLI prints this): a
    /// fleet summary line, then one line per backend.
    pub fn render(&self) -> String {
        let mut out = format!(
            "router routed {} failed-over {} quarantines {} terminal rejections {}\n",
            self.routed_total(),
            self.failed_over_total(),
            self.quarantines_total(),
            self.terminal_rejections,
        );
        for (i, b) in self.backends.iter().enumerate() {
            out.push_str(&format!(
                "backend {} {} routed {} rejected {} failed-over {} \
                 quarantined {} recovered {}\n",
                i, b.addr, b.routed, b.rejected, b.failed_over, b.quarantines, b.recoveries,
            ));
        }
        out
    }

    /// JSON object form (stable field names, hand-rolled).
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"terminal_rejections\":{},\"backends\":[",
            self.terminal_rejections
        );
        for (i, b) in self.backends.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"addr\":\"{}\",\"routed\":{},\"rejected\":{},\"failed_over\":{},\
                 \"quarantines\":{},\"recoveries\":{}}}",
                b.addr, b.routed, b.rejected, b.failed_over, b.quarantines, b.recoveries,
            );
        }
        out.push_str("]}");
        out
    }

    /// Prometheus text exposition for the router tier (`luna_router_`
    /// prefix, one labelled sample per backend).
    pub fn render_prom(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# TYPE luna_router_terminal_rejections_total counter");
        let _ = writeln!(
            out,
            "luna_router_terminal_rejections_total {}",
            self.terminal_rejections
        );
        for (name, get) in [
            ("routed", 0usize),
            ("rejected", 1),
            ("failed_over", 2),
            ("quarantines", 3),
            ("recoveries", 4),
        ] {
            let _ = writeln!(out, "# TYPE luna_router_{name}_total counter");
            for b in &self.backends {
                let v = match get {
                    0 => b.routed,
                    1 => b.rejected,
                    2 => b.failed_over,
                    3 => b.quarantines,
                    _ => b.recoveries,
                };
                let _ = writeln!(out, "luna_router_{name}_total{{backend=\"{}\"}} {v}", b.addr);
            }
        }
        out
    }
}

/// A fully populated snapshot with fixed values — the golden-render
/// fixture, also reused by the wire-codec roundtrip tests in
/// `net::protocol`.
#[cfg(test)]
pub(crate) fn sample_snapshot() -> MetricsSnapshot {
    MetricsSnapshot {
        requests: 14,
        batches: 2,
        padded_slots: 2,
        accepted: 16,
        rejected: 2,
        retry_hints: 1,
        failed_batches: 1,
        failed_requests: 2,
        mean_latency_us: 250.0,
        p50_latency_us: 256,
        p99_latency_us: 1024,
        max_latency_us: 900,
        throughput_rps: 140.0,
        sim_energy_fj: 1500.0,
        sim_p50_latency_ns: 512,
        sim_p99_latency_ns: 2048,
        sim_programs: 90,
        sim_stationary_hits: 110,
        host_gemm_mean_us: 33.0,
        host_gemm_p50_us: 32,
        host_gemm_p99_us: 64,
        plan_hits: 3,
        plan_misses: 1,
        plan_evictions: 1,
        plan_compiles: 1,
        plan_resident: 2,
        plan_resident_bytes: 64 * 1024,
        plan_compile_p99_us: 2048,
        plan_stall_p99_us: 256,
        stage_count: [14, 14, 14, 2, 2, 2, 14],
        stage_p50_us: [2, 2, 64, 4, 16, 8, 2],
        stage_p99_us: [4, 4, 256, 8, 64, 16, 4],
        tenants: vec![
            TenantStats {
                name: "default".into(),
                requests: 10,
                p50_latency_us: 256,
                p99_latency_us: 1024,
                p50_queue_us: 64,
                p99_queue_us: 256,
            },
            TenantStats {
                name: "m1".into(),
                requests: 4,
                p50_latency_us: 128,
                p99_latency_us: 512,
                p50_queue_us: 32,
                p99_queue_us: 128,
            },
        ],
        pool: crate::util::PoolStats { hits: 100, misses: 5, recycled: 99 },
    }
}

/// A two-backend router fixture for the router golden tests and the
/// wire-codec roundtrip tests.
#[cfg(test)]
pub(crate) fn sample_router_snapshot() -> RouterSnapshot {
    RouterSnapshot {
        backends: vec![
            BackendStats {
                addr: "127.0.0.1:7071".into(),
                routed: 2,
                rejected: 0,
                failed_over: 0,
                quarantines: 0,
                recoveries: 0,
            },
            BackendStats {
                addr: "127.0.0.1:7072".into(),
                routed: 1,
                rejected: 1,
                failed_over: 1,
                quarantines: 1,
                recoveries: 1,
            },
        ],
        terminal_rejections: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_render_is_byte_stable() {
        let got = sample_snapshot().render();
        let want = "\
requests 14 | batches 2 (occupancy 0.88) | failed batches 1 (2 requests)
admission accepted 16 rejected 2 (hints 1) | reject rate 0.111
latency mean 250 us p50 256 us p99 1024 us max 900 us | throughput 140 req/s
host gemm mean 33 us p50 32 us p99 64 us
pool hits 100 misses 5 recycled 99 (hit rate 0.952)
plan cache hits 3 misses 1 (hit rate 0.750) evictions 1 compiles 1 | \
resident 2 (64 KiB) | compile p99 2048 us stall p99 256 us
sim energy 0.00 nJ (107.1 fJ/req) | sim latency p50 512 ns p99 2048 ns | \
programs 90 stationary hits 110 (hit-rate 0.55)
stage p99 us: ingress 4 admission 4 queue_wait 256 batch_form 8 gemm 64 \
calibrated_gate 16 write_back 4
tenant default requests 10 latency p50 256 us p99 1024 us | queue p50 64 us p99 256 us
tenant m1 requests 4 latency p50 128 us p99 512 us | queue p50 32 us p99 128 us
";
        assert_eq!(got, want, "---got---\n{got}\n---want---\n{want}");
    }

    #[test]
    fn golden_router_render_is_byte_stable() {
        let got = sample_router_snapshot().render();
        let want = "\
router routed 3 failed-over 1 quarantines 1 terminal rejections 1
backend 0 127.0.0.1:7071 routed 2 rejected 0 failed-over 0 quarantined 0 recovered 0
backend 1 127.0.0.1:7072 routed 1 rejected 1 failed-over 1 quarantined 1 recovered 1
";
        assert_eq!(got, want, "---got---\n{got}\n---want---\n{want}");
    }

    #[test]
    fn json_render_carries_stages_and_tenants() {
        let json = sample_snapshot().render_json();
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        assert!(json.contains("\"requests\":14"), "{json}");
        assert!(
            json.contains("\"queue_wait\":{\"count\":14,\"p50_us\":64,\"p99_us\":256}"),
            "{json}"
        );
        assert!(json.contains("\"name\":\"m1\",\"requests\":4"), "{json}");
        let router = sample_router_snapshot().render_json();
        assert!(router.contains("\"terminal_rejections\":1"), "{router}");
        assert!(router.contains("\"addr\":\"127.0.0.1:7072\",\"routed\":1"), "{router}");
    }

    #[test]
    fn prom_render_is_labelled_exposition() {
        let prom = sample_snapshot().render_prom();
        assert!(
            prom.contains("# TYPE luna_requests_total counter\nluna_requests_total 14\n"),
            "{prom}"
        );
        assert!(prom.contains("luna_latency_us{quantile=\"0.99\"} 1024\n"), "{prom}");
        assert!(prom.contains("luna_stage_p99_us{stage=\"gemm\"} 64\n"), "{prom}");
        assert!(prom.contains("luna_tenant_requests_total{tenant=\"m1\"} 4\n"), "{prom}");
        // base labels fold into every sample, headers suppressible
        let mut labelled = String::new();
        sample_snapshot().render_prom_into(&mut labelled, "backend=\"b0\"", false);
        assert!(!labelled.contains("# TYPE"), "{labelled}");
        assert!(labelled.contains("luna_requests_total{backend=\"b0\"} 14\n"), "{labelled}");
        assert!(
            labelled.contains("luna_stage_p99_us{backend=\"b0\",stage=\"gemm\"} 64\n"),
            "{labelled}"
        );
        let rprom = sample_router_snapshot().render_prom();
        assert!(
            rprom.contains("luna_router_routed_total{backend=\"127.0.0.1:7071\"} 2\n"),
            "{rprom}"
        );
        assert!(rprom.contains("luna_router_terminal_rejections_total 1\n"), "{rprom}");
    }

    #[test]
    fn stage_histograms_aggregate_into_the_snapshot() {
        let m = Metrics::new();
        m.record_stage_us(Stage::QueueWait, 100);
        m.record_stage_us(Stage::QueueWait, 200);
        m.record_stage_us(Stage::Gemm, 0); // clamps to the 1 µs floor
        let snap = m.snapshot();
        assert_eq!(snap.stage_count[Stage::QueueWait as usize], 2);
        assert_eq!(snap.stage_count[Stage::Gemm as usize], 1);
        assert_eq!(snap.stage_count[Stage::Ingress as usize], 0);
        assert!(snap.stage_p99_us[Stage::QueueWait as usize] >= 200);
        assert!(
            snap.stage_p50_us[Stage::QueueWait as usize]
                <= snap.stage_p99_us[Stage::QueueWait as usize]
        );
        let report = snap.render();
        assert!(report.contains("stage p99 us: ingress 0"), "{report}");
    }

    #[test]
    fn tenant_histograms_register_once_and_render_sorted() {
        let m = Metrics::new();
        let t1 = m.tenant(ModelId::new("m1").unwrap());
        let td = m.tenant(ModelId::DEFAULT);
        let t1_again = m.tenant(ModelId::new("m1").unwrap());
        assert!(Arc::ptr_eq(&t1, &t1_again), "one registry entry per model");
        t1.latency.record_us(100);
        t1.queue.record_us(10);
        td.latency.record_us(400);
        let snap = m.snapshot();
        assert_eq!(snap.tenants.len(), 2);
        assert_eq!(snap.tenants[0].name, "default", "sorted by name");
        assert_eq!(snap.tenants[1].name, "m1");
        assert_eq!(snap.tenants[1].requests, 1);
        assert!(snap.tenants[1].p99_queue_us >= 10);
        let report = snap.render();
        assert!(report.contains("tenant m1 requests 1"), "{report}");
    }

    #[test]
    fn batch_occupancy_accounts_padding() {
        let m = Metrics::new();
        m.record_batch(6, 8);
        m.record_batch(8, 8);
        let snap = m.snapshot();
        assert_eq!(snap.requests, 14);
        assert_eq!(snap.padded_slots, 2);
        assert!((snap.batch_occupancy() - 14.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn batch_failures_are_counted_and_rendered() {
        let m = Metrics::new();
        m.record_batch(8, 8);
        m.record_batch_failure(8);
        m.record_batch_failure(3);
        let snap = m.snapshot();
        assert_eq!(snap.failed_batches, 2);
        assert_eq!(snap.failed_requests, 11);
        let report = snap.render();
        assert!(report.contains("failed batches 2 (11 requests)"), "{report}");
    }

    #[test]
    fn admission_counters_and_reject_rate_render() {
        let m = Metrics::new();
        for _ in 0..6 {
            m.record_admission();
        }
        m.record_rejection(1500); // hinted 429
        m.record_rejection(0); // hint-less turn-away (connection cap)
        let snap = m.snapshot();
        assert_eq!(snap.accepted, 6);
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.retry_hints, 1);
        assert!((snap.reject_rate() - 2.0 / 8.0).abs() < 1e-12);
        let report = snap.render();
        assert!(report.contains("admission accepted 6 rejected 2 (hints 1)"), "{report}");
        assert!(report.contains("reject rate 0.250"), "{report}");
    }

    #[test]
    fn reject_rate_is_zero_without_decisions() {
        assert_eq!(Metrics::new().snapshot().reject_rate(), 0.0);
    }

    #[test]
    fn energy_accumulates() {
        let m = Metrics::new();
        m.record_sim_energy_fj(100.4);
        m.record_sim_energy_fj(50.3);
        assert!((m.snapshot().sim_energy_fj - 150.0).abs() <= 1.0);
    }

    #[test]
    fn sim_cost_aggregates_and_renders() {
        let m = Metrics::new();
        m.record_batch(8, 8);
        m.record_sim_cost(&ScheduleCost {
            latency_ps: 2_000_000, // 2000 ns
            energy_fj: 1000.0,
            programs: 90,
            stationary_hits: 10,
        });
        m.record_sim_cost(&ScheduleCost {
            latency_ps: 500_000, // 500 ns
            energy_fj: 500.0,
            programs: 0,
            stationary_hits: 100,
        });
        let snap = m.snapshot();
        assert_eq!(snap.sim_programs, 90);
        assert_eq!(snap.sim_stationary_hits, 110);
        assert!((snap.stationary_hit_rate() - 110.0 / 200.0).abs() < 1e-12);
        assert!((snap.sim_energy_fj - 1500.0).abs() <= 1.0);
        assert!(snap.sim_p50_latency_ns >= 500);
        assert!(snap.sim_p50_latency_ns <= snap.sim_p99_latency_ns);
        // 2000 ns falls in the [1024, 2048) bucket → p99 upper bound 2048
        assert!(snap.sim_p99_latency_ns >= 2000);
        let report = snap.render();
        assert!(report.contains("sim latency p50"), "{report}");
        assert!(report.contains("hit-rate 0.55"), "{report}");
        assert!(report.contains("fJ/req"), "{report}");
    }

    #[test]
    fn hit_rate_is_zero_without_sim_data() {
        let snap = Metrics::new().snapshot();
        assert_eq!(snap.stationary_hit_rate(), 0.0);
        assert_eq!(snap.sim_energy_per_request_fj(), 0.0);
        assert_eq!(snap.sim_p50_latency_ns, 0);
        assert_eq!(snap.host_gemm_p50_us, 0);
        assert_eq!(snap.host_gemm_mean_us, 0.0);
    }

    #[test]
    fn host_gemm_time_aggregates_and_renders() {
        let m = Metrics::new();
        m.record_host_gemm_us(0); // sub-µs batch clamps to the 1 µs floor
        m.record_host_gemm_us(40);
        m.record_host_gemm_us(900);
        let snap = m.snapshot();
        assert_eq!(m.host_gemm.count(), 3);
        assert!(snap.host_gemm_mean_us > 0.0);
        assert!(snap.host_gemm_p50_us <= snap.host_gemm_p99_us);
        assert!(snap.host_gemm_p99_us >= 900, "p99 bucket bound covers the max sample");
        let report = snap.render();
        assert!(report.contains("host gemm mean"), "{report}");
    }

    #[test]
    fn router_counters_aggregate_per_backend_and_render() {
        let m = RouterMetrics::new(&["127.0.0.1:7071".to_string(), "127.0.0.1:7072".to_string()]);
        m.record_routed(0);
        m.record_routed(0);
        m.record_routed(1);
        m.record_backend_rejection(1);
        m.record_failed_over(1);
        m.record_quarantine(1);
        m.record_recovery(1);
        m.record_terminal_rejection();
        let snap = m.snapshot();
        assert_eq!(snap, sample_router_snapshot(), "fixture mirrors the live counters");
        assert_eq!(snap.routed_total(), 3);
        assert_eq!(snap.failed_over_total(), 1);
        assert_eq!(snap.quarantines_total(), 1);
        assert_eq!(snap.terminal_rejections, 1);
        let report = snap.render();
        assert!(
            report.contains("router routed 3 failed-over 1 quarantines 1 terminal rejections 1"),
            "{report}"
        );
        assert!(report.contains("backend 0 127.0.0.1:7071 routed 2"), "{report}");
        assert!(
            report.contains("backend 1 127.0.0.1:7072 routed 1 rejected 1 failed-over 1"),
            "{report}"
        );
    }

    #[test]
    fn plan_cache_counters_aggregate_and_render() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.plan_cache.record_hit();
        }
        m.plan_cache.record_miss();
        m.plan_cache.record_compile_us(1800);
        m.plan_cache.record_stall_us(250);
        m.plan_cache.record_eviction();
        m.plan_cache.set_resident(2, 64 * 1024);
        let snap = m.snapshot();
        assert_eq!(snap.plan_hits, 3);
        assert_eq!(snap.plan_misses, 1);
        assert_eq!(snap.plan_compiles, 1);
        assert_eq!(snap.plan_evictions, 1);
        assert_eq!(snap.plan_resident, 2);
        assert_eq!(snap.plan_resident_bytes, 64 * 1024);
        assert!((snap.plan_hit_rate() - 0.75).abs() < 1e-12);
        assert!(snap.plan_compile_p99_us >= 1800);
        assert!(snap.plan_stall_p99_us >= 250);
        let report = snap.render();
        assert!(report.contains("plan cache hits 3 misses 1 (hit rate 0.750)"), "{report}");
        assert!(report.contains("resident 2 (64 KiB)"), "{report}");
    }

    #[test]
    fn plan_hit_rate_is_zero_without_lookups() {
        assert_eq!(Metrics::new().snapshot().plan_hit_rate(), 0.0);
    }

    #[test]
    fn pool_line_renders_with_bounded_hit_rate() {
        // exercise the pool so the process-wide counters move
        let v = crate::util::PooledVec::<f32>::with_capacity(64);
        drop(v);
        let _again = crate::util::PooledVec::<f32>::with_capacity(64);
        let snap = Metrics::new().snapshot();
        assert!(snap.pool.hits + snap.pool.misses > 0);
        let r = snap.pool.hit_rate();
        assert!((0.0..=1.0).contains(&r), "hit rate {r}");
        let report = snap.render();
        assert!(report.contains("pool hits"), "{report}");
        assert!(report.contains("hit rate"), "{report}");
    }
}
