//! Execution worker pool.
//!
//! Each worker is an OS thread that builds its **own** backend from a
//! [`BackendSpec`] — PJRT handles are not `Send`, and the native LUT-GEMM
//! backend owns per-thread scratch buffers — then serves batch jobs from
//! an allocation-free [`crate::util::queue`]. Replies go one of two
//! ways ([`ReplyTo`]): standalone callers (tests, benches) block on an
//! in-tree oneshot; the serving coordinator instead has the worker push
//! a [`WorkerReply`] straight onto the shared completion queue, so the
//! steady-state batch path allocates nothing — no per-batch oneshot, no
//! mpsc node.

use crate::engine::{BackendSpec, BatchOutput};
use crate::util::{oneshot, queue, PooledVec};
use crate::Result;
use anyhow::{anyhow, ensure};
use std::thread::JoinHandle;

/// One unit of work: an already-flattened batch. `inputs` is pooled and
/// recycles as soon as the worker finishes the batch.
pub struct BatchJob {
    /// Row-major `batch × dim` inputs.
    pub inputs: PooledVec<f32>,
    pub batch: usize,
    pub dim: usize,
    /// Where the result goes.
    pub reply: ReplyTo,
}

/// Reply route for a [`BatchJob`].
pub enum ReplyTo {
    /// Block-and-wait callers: one oneshot per job (tests, benches —
    /// allocates, off the serving hot path).
    Oneshot(oneshot::Sender<Result<BatchOutput>>),
    /// The serving path: a drop-guarded ticket that pushes a
    /// [`WorkerReply`] onto the coordinator's completion queue
    /// (allocation-free on the happy path).
    Queue(ReplyTicket),
}

/// A finished batch on its way to the completion pool.
pub struct WorkerReply {
    /// Matches the [`BatchJob`]'s ticket (keys the coordinator's
    /// pending-batch context; the shard index rides in the low bits).
    pub batch_id: u64,
    pub result: Result<BatchOutput>,
}

/// One-shot completion-queue reply handle. [`ReplyTicket::send`]
/// delivers the worker's result; a ticket dropped *without* sending —
/// a worker panic unwinding mid-batch, or a queued job discarded when
/// its worker's queue died — delivers a "worker dropped reply" error
/// instead, so a dispatched batch context can never be stranded. (The
/// old per-batch oneshot gave the same guarantee via `recv() == None`,
/// at the cost of an allocation per batch.)
pub struct ReplyTicket {
    tx: Option<queue::Sender<WorkerReply>>,
    batch_id: u64,
}

impl ReplyTicket {
    pub fn new(tx: queue::Sender<WorkerReply>, batch_id: u64) -> Self {
        ReplyTicket { tx: Some(tx), batch_id }
    }

    /// Deliver the result (consumes the ticket; the drop guard disarms).
    pub fn send(mut self, result: Result<BatchOutput>) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(WorkerReply { batch_id: self.batch_id, result });
        }
    }
}

impl Drop for ReplyTicket {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let reply = WorkerReply {
                batch_id: self.batch_id,
                result: Err(anyhow!("worker dropped reply")),
            };
            let _ = tx.send(reply);
        }
    }
}

/// A pool of execution worker threads.
pub struct WorkerPool {
    senders: Vec<queue::Sender<BatchJob>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `count` workers, each building its own backend from `spec`.
    /// Blocks until every worker reports successful construction (or
    /// fails fast with the first error).
    pub fn spawn(count: usize, spec: BackendSpec) -> Result<Self> {
        ensure!(count >= 1, "need at least one worker");
        // lint: allow(alloc): spawn-time bookkeeping, once per pool.
        let mut senders = Vec::with_capacity(count);
        // lint: allow(alloc): spawn-time bookkeeping, once per pool.
        let mut handles = Vec::with_capacity(count);
        let (ready_tx, ready_rx) = queue::channel::<std::result::Result<(), String>>();
        for worker_id in 0..count {
            let (tx, rx) = queue::channel::<BatchJob>();
            let spec = spec.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("luna-worker-{worker_id}"))
                .spawn(move || worker_main(spec, rx, ready))
                .expect("spawn worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..count {
            match ready_rx.recv() {
                Some(Ok(())) => {}
                Some(Err(msg)) => return Err(anyhow!("worker failed to initialize: {msg}")),
                None => return Err(anyhow!("worker exited before reporting readiness")),
            }
        }
        Ok(WorkerPool { senders, handles })
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to worker `idx`.
    pub fn submit(&self, idx: usize, job: BatchJob) -> Result<()> {
        self.senders[idx % self.senders.len()]
            .send(job)
            .map_err(|_| anyhow!("worker {idx} has shut down"))
    }

    /// Drop the queues and join every worker.
    pub fn shutdown(self) {
        drop(self.senders);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_main(
    spec: BackendSpec,
    rx: queue::Receiver<BatchJob>,
    ready: queue::Sender<std::result::Result<(), String>>,
) {
    let mut backend = match spec.build() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    while let Some(job) = rx.recv() {
        let BatchJob { inputs, batch, dim, reply } = job;
        let res = backend.run_batch(&inputs, batch, dim);
        // recycle the flat input buffer before waking the reply path
        drop(inputs);
        match reply {
            ReplyTo::Oneshot(tx) => {
                let _ = tx.send(res);
            }
            ReplyTo::Queue(ticket) => ticket.send(res),
        }
    }
}

// Real-thread worker pools have no place under loom's scheduler; the
// ticket/queue protocol models live in `tests/loom_models.rs`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::nn::QuantMlp;

    fn job(
        inputs: Vec<f32>,
        batch: usize,
        dim: usize,
    ) -> (BatchJob, oneshot::Receiver<Result<BatchOutput>>) {
        let (tx, rx) = oneshot::channel();
        (BatchJob { inputs: inputs.into(), batch, dim, reply: ReplyTo::Oneshot(tx) }, rx)
    }

    fn native_spec() -> (BackendSpec, QuantMlp) {
        let mlp = QuantMlp::random_for_study(11);
        (BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::DncOpt, threads: 1 }, mlp)
    }

    #[test]
    fn pool_executes_jobs_on_all_workers() {
        let (spec, mlp) = native_spec();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(2, spec).unwrap();
        for i in 0..4 {
            let inputs: Vec<f32> = (0..32).map(|j| ((i * 32 + j) % 16) as f32 / 16.0).collect();
            let (j, rx) = job(inputs.clone(), 2, 16);
            pool.submit(i, j).unwrap();
            let out = rx.recv().unwrap().unwrap();
            let expect = mlp.forward_batch(&inputs, 2, &model);
            assert_eq!(out.logits, expect);
        }
        pool.shutdown();
    }

    #[test]
    fn queue_reply_routes_through_completion_channel() {
        let (spec, mlp) = native_spec();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let inputs = vec![0.25f32; 2 * 16];
        pool.submit(
            0,
            BatchJob {
                inputs: inputs.clone().into(),
                batch: 2,
                dim: 16,
                reply: ReplyTo::Queue(ReplyTicket::new(ctx, 42)),
            },
        )
        .unwrap();
        let reply = crx.recv().expect("worker pushes onto the completion queue");
        assert_eq!(reply.batch_id, 42);
        assert_eq!(reply.result.unwrap().logits, mlp.forward_batch(&inputs, 2, &model));
        pool.shutdown();
    }

    #[test]
    fn dropped_ticket_delivers_a_worker_death_error() {
        // A ticket dropped without sending (panic unwind, discarded job)
        // must still resolve its batch — the stranded-context guard.
        let (ctx, crx) = queue::channel::<WorkerReply>();
        drop(ReplyTicket::new(ctx, 7));
        let reply = crx.recv().expect("drop guard delivers");
        assert_eq!(reply.batch_id, 7);
        let err = reply.result.expect_err("drop guard reports worker death");
        assert!(format!("{err:#}").contains("worker dropped reply"), "{err:#}");

        // and a consumed ticket's guard is disarmed: exactly one reply
        let (ctx, crx) = queue::channel::<WorkerReply>();
        ReplyTicket::new(ctx, 8).send(Ok(BatchOutput::plain(vec![1.0f32])));
        assert_eq!(crx.recv().unwrap().batch_id, 8);
        assert!(crx.try_recv().is_none(), "no double delivery");
    }

    #[test]
    fn calibrated_worker_keeps_fabric_state_across_jobs() {
        let mlp = QuantMlp::random_for_study(12);
        let lib = crate::cells::tsmc65_library();
        // 288-unit fabric = every weight element of the study model
        let spec = BackendSpec::Calibrated {
            mlp: mlp.clone(),
            kind: MultiplierKind::DncOpt,
            costs: crate::coordinator::tiler::UnitCosts::measure_cached(
                MultiplierKind::DncOpt,
                &lib,
            ),
            banks: 288,
            units_per_bank: 1,
            time_scale: 0.0,
            threads: 1,
        };
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let mut costs = Vec::new();
        for _ in 0..2 {
            let (j, rx) = job(vec![0.5f32; 2 * 16], 2, 16);
            pool.submit(0, j).unwrap();
            costs.push(rx.recv().unwrap().unwrap().cost.expect("calibrated cost"));
        }
        assert!(costs[0].programs > 0);
        assert_eq!(costs[1].programs, 0, "same worker, second batch fully stationary");
        assert!(costs[1].energy_fj < costs[0].energy_fj);
        pool.shutdown();
    }

    #[test]
    fn worker_surfaces_bad_batch_shape_as_error() {
        let (spec, _) = native_spec();
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let (j, rx) = job(vec![0.0; 5], 1, 16);
        pool.submit(0, j).unwrap();
        assert!(rx.recv().unwrap().is_err());
        pool.shutdown();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_fast_without_feature() {
        let spec = BackendSpec::Pjrt { hlo: std::path::PathBuf::from("/no/such/file.hlo.txt") };
        assert!(WorkerPool::spawn(1, spec).is_err());
    }

    #[cfg(feature = "pjrt")]
    mod pjrt {
        use crate::coordinator::worker::{BatchJob, ReplyTo, WorkerPool};
        use crate::engine::BackendSpec;
        use crate::util::oneshot;
        use std::path::PathBuf;

        const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  add = f32[2,3]{1,0} add(p0, p0)
  ROOT t = (f32[2,3]{1,0}) tuple(add)
}
"#;

        fn hlo_file(tag: &str) -> PathBuf {
            let dir = crate::util::test_dir(tag);
            let path = dir.join("double.hlo.txt");
            std::fs::write(&path, DOUBLE_HLO).unwrap();
            path
        }

        #[test]
        fn pjrt_pool_executes_jobs() {
            let pool = WorkerPool::spawn(2, BackendSpec::Pjrt { hlo: hlo_file("pool") }).unwrap();
            for i in 0..4 {
                let (tx, rx) = oneshot::channel();
                let inputs: Vec<f32> = (0..6).map(|j| (i * 6 + j) as f32).collect();
                pool.submit(
                    i,
                    BatchJob {
                        inputs: inputs.clone().into(),
                        batch: 2,
                        dim: 3,
                        reply: ReplyTo::Oneshot(tx),
                    },
                )
                .unwrap();
                let out = rx.recv().unwrap().unwrap();
                let expect: Vec<f32> = inputs.iter().map(|v| v * 2.0).collect();
                assert_eq!(out.logits, expect);
            }
            pool.shutdown();
        }

        #[test]
        fn bad_artifact_fails_fast() {
            let dir = crate::util::test_dir("badhlo");
            let path = dir.join("broken.hlo.txt");
            std::fs::write(&path, "not hlo at all").unwrap();
            assert!(WorkerPool::spawn(1, BackendSpec::Pjrt { hlo: path }).is_err());
        }

        #[test]
        fn missing_artifact_fails_fast() {
            let spec = BackendSpec::Pjrt { hlo: PathBuf::from("/no/such/file.hlo.txt") };
            assert!(WorkerPool::spawn(1, spec).is_err());
        }
    }
}
