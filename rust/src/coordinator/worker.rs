//! Execution worker pool.
//!
//! Each worker is an OS thread that builds its **own** backend from a
//! [`BackendSpec`] — PJRT handles are not `Send`, and the native LUT-GEMM
//! backend owns per-thread scratch buffers — then serves batch jobs from
//! an allocation-free [`crate::util::queue`]. Replies go one of two
//! ways ([`ReplyTo`]): standalone callers (tests, benches) block on an
//! in-tree oneshot; the serving coordinator instead has the worker push
//! a [`WorkerReply`] straight onto the shared completion queue, so the
//! steady-state batch path allocates nothing — no per-batch oneshot, no
//! mpsc node.
//!
//! **Multi-tenant execution**: a [`BatchJob`] names its model. The
//! default model runs on the backend built at spawn (from the spec, or
//! from a plan-cache entry the pool was seeded with); any other model's
//! first batch on a worker builds a per-model executor from the job's
//! shared [`ModelEntry`] — no recompile, the compiled plan rides in by
//! `Arc` — and keeps it (including the calibrated backend's per-model
//! weight-stationary fabric) until a [`WorkerPool::retire`] broadcast
//! drops it. Retire messages travel the same queue as jobs, so a
//! retiring model's already-queued batches still execute first.

use crate::engine::{BackendSpec, BatchOutput, ExecBackend, ModelEntry};
use crate::net::protocol::ModelId;
use crate::util::{oneshot, queue, PooledVec};
use crate::Result;
use anyhow::{anyhow, ensure};
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;

/// One unit of work: an already-flattened batch. `inputs` is pooled and
/// recycles as soon as the worker finishes the batch.
pub struct BatchJob {
    /// Row-major `batch × dim` inputs.
    pub inputs: PooledVec<f32>,
    pub batch: usize,
    pub dim: usize,
    /// The model these rows belong to (batches never mix models).
    pub model: ModelId,
    /// The compiled plan for `model`, shared from the plan cache. The
    /// worker needs it only for its *first* batch of a non-default
    /// model (to build the per-model executor); `None` is fine for the
    /// default model.
    pub entry: Option<Arc<ModelEntry>>,
    /// Where the result goes.
    pub reply: ReplyTo,
}

impl BatchJob {
    /// A default-model job (the single-tenant form tests and benches
    /// use; the coordinator fills `model`/`entry` itself).
    pub fn new(
        inputs: impl Into<PooledVec<f32>>,
        batch: usize,
        dim: usize,
        reply: ReplyTo,
    ) -> Self {
        BatchJob {
            inputs: inputs.into(),
            batch,
            dim,
            model: ModelId::DEFAULT,
            entry: None,
            reply,
        }
    }
}

/// What travels the worker queue: batch work, or a retire broadcast
/// telling the worker to drop a model's per-worker executor state.
enum WorkerMsg {
    Job(BatchJob),
    Retire(ModelId),
}

/// Reply route for a [`BatchJob`].
pub enum ReplyTo {
    /// Block-and-wait callers: one oneshot per job (tests, benches —
    /// allocates, off the serving hot path).
    Oneshot(oneshot::Sender<Result<BatchOutput>>),
    /// The serving path: a drop-guarded ticket that pushes a
    /// [`WorkerReply`] onto the coordinator's completion queue
    /// (allocation-free on the happy path).
    Queue(ReplyTicket),
}

/// A finished batch on its way to the completion pool.
pub struct WorkerReply {
    /// Matches the [`BatchJob`]'s ticket (keys the coordinator's
    /// pending-batch context; the shard index rides in the low bits).
    pub batch_id: u64,
    pub result: Result<BatchOutput>,
    /// Wall time the worker spent executing the batch (µs) — dispatch
    /// to done, measured worker-side so the coordinator can split the
    /// GEMM and calibrated-gate trace spans out of it. `0` when the
    /// reply came from the drop guard (no batch ran).
    pub wall_us: u64,
}

/// One-shot completion-queue reply handle. [`ReplyTicket::send`]
/// delivers the worker's result; a ticket dropped *without* sending —
/// a worker panic unwinding mid-batch, or a queued job discarded when
/// its worker's queue died — delivers a "worker dropped reply" error
/// instead, so a dispatched batch context can never be stranded. (The
/// old per-batch oneshot gave the same guarantee via `recv() == None`,
/// at the cost of an allocation per batch.)
pub struct ReplyTicket {
    tx: Option<queue::Sender<WorkerReply>>,
    batch_id: u64,
}

impl ReplyTicket {
    pub fn new(tx: queue::Sender<WorkerReply>, batch_id: u64) -> Self {
        ReplyTicket { tx: Some(tx), batch_id }
    }

    /// Deliver the result (consumes the ticket; the drop guard disarms).
    /// `wall_us` is the worker-measured batch execution time.
    pub fn send(mut self, result: Result<BatchOutput>, wall_us: u64) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(WorkerReply { batch_id: self.batch_id, result, wall_us });
        }
    }
}

impl Drop for ReplyTicket {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let reply = WorkerReply {
                batch_id: self.batch_id,
                result: Err(anyhow!("worker dropped reply")),
                wall_us: 0,
            };
            let _ = tx.send(reply);
        }
    }
}

/// A pool of execution worker threads.
pub struct WorkerPool {
    senders: Vec<queue::Sender<WorkerMsg>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `count` workers, each building its own backend from `spec`.
    /// Blocks until every worker reports successful construction (or
    /// fails fast with the first error).
    pub fn spawn(count: usize, spec: BackendSpec) -> Result<Self> {
        Self::spawn_seeded(count, spec, None)
    }

    /// [`WorkerPool::spawn`], optionally seeding every worker's
    /// default-model backend from an already-compiled plan-cache entry
    /// (so N workers share one compiled plan instead of compiling N
    /// copies). `None` keeps the classic behaviour: each worker builds
    /// from the spec's own model.
    pub fn spawn_seeded(
        count: usize,
        spec: BackendSpec,
        default_entry: Option<Arc<ModelEntry>>,
    ) -> Result<Self> {
        ensure!(count >= 1, "need at least one worker");
        // lint: allow(alloc): spawn-time bookkeeping, once per pool.
        let mut senders = Vec::with_capacity(count);
        // lint: allow(alloc): spawn-time bookkeeping, once per pool.
        let mut handles = Vec::with_capacity(count);
        let (ready_tx, ready_rx) = queue::channel::<std::result::Result<(), String>>();
        for worker_id in 0..count {
            let (tx, rx) = queue::channel::<WorkerMsg>();
            let spec = spec.clone();
            let seed = default_entry.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("luna-worker-{worker_id}"))
                .spawn(move || worker_main(spec, seed, rx, ready))
                .expect("spawn worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..count {
            match ready_rx.recv() {
                Some(Ok(())) => {}
                Some(Err(msg)) => return Err(anyhow!("worker failed to initialize: {msg}")),
                None => return Err(anyhow!("worker exited before reporting readiness")),
            }
        }
        Ok(WorkerPool { senders, handles })
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to worker `idx`.
    pub fn submit(&self, idx: usize, job: BatchJob) -> Result<()> {
        self.senders[idx % self.senders.len()]
            .send(WorkerMsg::Job(job))
            .map_err(|_| anyhow!("worker {idx} has shut down"))
    }

    /// Broadcast a retire to every worker: each drops its per-model
    /// executor for `model` (freeing the plan `Arc` and any calibrated
    /// fabric state). Queued jobs for the model submitted *before* this
    /// call still execute — the message rides the same FIFO queue.
    pub fn retire(&self, model: ModelId) {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Retire(model));
        }
    }

    /// Drop the queues and join every worker.
    pub fn shutdown(self) {
        drop(self.senders);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// The executor a job runs on: the spawn-time default backend for the
/// default model, otherwise a lazily-built per-model backend shared
/// nothing across workers but sharing the compiled plan by `Arc`.
fn backend_for<'a>(
    spec: &BackendSpec,
    default: &'a mut Box<dyn ExecBackend>,
    extras: &'a mut HashMap<ModelId, Box<dyn ExecBackend>>,
    model: ModelId,
    entry: Option<&Arc<ModelEntry>>,
) -> Result<&'a mut dyn ExecBackend> {
    if model.is_default() {
        return Ok(default.as_mut());
    }
    if !extras.contains_key(&model) {
        // first batch of this model on this worker: build its executor
        // from the shared compiled plan (cold path — the coordinator
        // always attaches the entry for non-default models)
        let entry = entry.ok_or_else(|| anyhow!("no compiled plan attached for model {model}"))?;
        let backend = spec.build_for(Arc::clone(&entry.mlp), Arc::clone(&entry.plan))?;
        extras.insert(model, backend);
    }
    Ok(extras.get_mut(&model).expect("just ensured present").as_mut())
}

fn worker_main(
    spec: BackendSpec,
    default_entry: Option<Arc<ModelEntry>>,
    rx: queue::Receiver<WorkerMsg>,
    ready: queue::Sender<std::result::Result<(), String>>,
) {
    let built = match &default_entry {
        Some(e) => spec.build_for(Arc::clone(&e.mlp), Arc::clone(&e.plan)),
        None => spec.build(),
    };
    let mut backend = match built {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // per-model executors for non-default tenants (lazy; retire drops)
    let mut extras: HashMap<ModelId, Box<dyn ExecBackend>> = HashMap::new();
    while let Some(msg) = rx.recv() {
        let job = match msg {
            WorkerMsg::Job(job) => job,
            WorkerMsg::Retire(model) => {
                extras.remove(&model);
                continue;
            }
        };
        let BatchJob { inputs, batch, dim, model, entry, reply } = job;
        let started = std::time::Instant::now();
        let res = backend_for(&spec, &mut backend, &mut extras, model, entry.as_ref())
            .and_then(|b| b.run_batch(&inputs, batch, dim));
        let wall_us = started.elapsed().as_micros() as u64;
        // recycle the flat input buffer before waking the reply path
        drop(inputs);
        match reply {
            ReplyTo::Oneshot(tx) => {
                let _ = tx.send(res);
            }
            ReplyTo::Queue(ticket) => ticket.send(res, wall_us),
        }
    }
}

// Real-thread worker pools have no place under loom's scheduler; the
// ticket/queue protocol models live in `tests/loom_models.rs`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::nn::{GemmOptions, QuantMlp};

    fn job(
        inputs: Vec<f32>,
        batch: usize,
        dim: usize,
    ) -> (BatchJob, oneshot::Receiver<Result<BatchOutput>>) {
        let (tx, rx) = oneshot::channel();
        (BatchJob::new(inputs, batch, dim, ReplyTo::Oneshot(tx)), rx)
    }

    fn native_spec() -> (BackendSpec, QuantMlp) {
        let mlp = QuantMlp::random_for_study(11);
        let gemm = GemmOptions::default();
        (BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::DncOpt, gemm }, mlp)
    }

    #[test]
    fn pool_executes_jobs_on_all_workers() {
        let (spec, mlp) = native_spec();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(2, spec).unwrap();
        for i in 0..4 {
            let inputs: Vec<f32> = (0..32).map(|j| ((i * 32 + j) % 16) as f32 / 16.0).collect();
            let (j, rx) = job(inputs.clone(), 2, 16);
            pool.submit(i, j).unwrap();
            let out = rx.recv().unwrap().unwrap();
            let expect = mlp.forward_batch(&inputs, 2, &model);
            assert_eq!(out.logits, expect);
        }
        pool.shutdown();
    }

    #[test]
    fn queue_reply_routes_through_completion_channel() {
        let (spec, mlp) = native_spec();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let inputs = vec![0.25f32; 2 * 16];
        pool.submit(
            0,
            BatchJob::new(inputs.clone(), 2, 16, ReplyTo::Queue(ReplyTicket::new(ctx, 42))),
        )
        .unwrap();
        let reply = crx.recv().expect("worker pushes onto the completion queue");
        assert_eq!(reply.batch_id, 42);
        assert_eq!(reply.result.unwrap().logits, mlp.forward_batch(&inputs, 2, &model));
        pool.shutdown();
    }

    #[test]
    fn dropped_ticket_delivers_a_worker_death_error() {
        // A ticket dropped without sending (panic unwind, discarded job)
        // must still resolve its batch — the stranded-context guard.
        let (ctx, crx) = queue::channel::<WorkerReply>();
        drop(ReplyTicket::new(ctx, 7));
        let reply = crx.recv().expect("drop guard delivers");
        assert_eq!(reply.batch_id, 7);
        let err = reply.result.expect_err("drop guard reports worker death");
        assert!(format!("{err:#}").contains("worker dropped reply"), "{err:#}");

        // and a consumed ticket's guard is disarmed: exactly one reply
        let (ctx, crx) = queue::channel::<WorkerReply>();
        ReplyTicket::new(ctx, 8).send(Ok(BatchOutput::plain(vec![1.0f32])), 12);
        let reply = crx.recv().unwrap();
        assert_eq!(reply.batch_id, 8);
        assert_eq!(reply.wall_us, 12);
        assert!(crx.try_recv().is_none(), "no double delivery");
    }

    #[test]
    fn calibrated_worker_keeps_fabric_state_across_jobs() {
        let mlp = QuantMlp::random_for_study(12);
        let lib = crate::cells::tsmc65_library();
        // 288-unit fabric = every weight element of the study model
        let spec = BackendSpec::Calibrated {
            mlp: mlp.clone(),
            kind: MultiplierKind::DncOpt,
            costs: crate::coordinator::tiler::UnitCosts::measure_cached(
                MultiplierKind::DncOpt,
                &lib,
            ),
            banks: 288,
            units_per_bank: 1,
            time_scale: 0.0,
            gemm: GemmOptions::default(),
        };
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let mut costs = Vec::new();
        for _ in 0..2 {
            let (j, rx) = job(vec![0.5f32; 2 * 16], 2, 16);
            pool.submit(0, j).unwrap();
            costs.push(rx.recv().unwrap().unwrap().cost.expect("calibrated cost"));
        }
        assert!(costs[0].programs > 0);
        assert_eq!(costs[1].programs, 0, "same worker, second batch fully stationary");
        assert!(costs[1].energy_fj < costs[0].energy_fj);
        pool.shutdown();
    }

    #[test]
    fn multi_model_jobs_execute_on_their_own_backends() {
        let (spec, default_mlp) = native_spec();
        let other_mlp = QuantMlp::random_for_study(99);
        let entry = Arc::new(ModelEntry::compile(
            ModelId::new("other").unwrap(),
            other_mlp.clone(),
            GemmOptions::default(),
        ));
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let inputs = vec![0.3f32; 16];

        // default-model job runs on the spawn-time backend
        let (j, rx) = job(inputs.clone(), 1, 16);
        pool.submit(0, j).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.logits, default_mlp.forward(&inputs, &model));

        // tagged job builds its executor from the shared entry and
        // computes with the *other* model's weights
        let (tx, rx) = oneshot::channel();
        let mut j = BatchJob::new(inputs.clone(), 1, 16, ReplyTo::Oneshot(tx));
        j.model = entry.model;
        j.entry = Some(Arc::clone(&entry));
        pool.submit(0, j).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.logits, other_mlp.forward(&inputs, &model));

        // a tagged job with no entry (and no cached executor) errors
        pool.retire(entry.model);
        let (tx, rx) = oneshot::channel();
        let mut j = BatchJob::new(inputs.clone(), 1, 16, ReplyTo::Oneshot(tx));
        j.model = entry.model;
        pool.submit(0, j).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("no compiled plan"), "{err:#}");

        // re-attaching the entry rebuilds the executor after retire
        let (tx, rx) = oneshot::channel();
        let mut j = BatchJob::new(inputs.clone(), 1, 16, ReplyTo::Oneshot(tx));
        j.model = entry.model;
        j.entry = Some(Arc::clone(&entry));
        pool.submit(0, j).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().logits, other_mlp.forward(&inputs, &model));
        pool.shutdown();
    }

    #[test]
    fn worker_surfaces_bad_batch_shape_as_error() {
        let (spec, _) = native_spec();
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let (j, rx) = job(vec![0.0; 5], 1, 16);
        pool.submit(0, j).unwrap();
        assert!(rx.recv().unwrap().is_err());
        pool.shutdown();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_fast_without_feature() {
        let spec = BackendSpec::Pjrt { hlo: std::path::PathBuf::from("/no/such/file.hlo.txt") };
        assert!(WorkerPool::spawn(1, spec).is_err());
    }

    #[cfg(feature = "pjrt")]
    mod pjrt {
        use crate::coordinator::worker::{BatchJob, ReplyTo, WorkerPool};
        use crate::engine::BackendSpec;
        use crate::util::oneshot;
        use std::path::PathBuf;

        const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  add = f32[2,3]{1,0} add(p0, p0)
  ROOT t = (f32[2,3]{1,0}) tuple(add)
}
"#;

        fn hlo_file(tag: &str) -> PathBuf {
            let dir = crate::util::test_dir(tag);
            let path = dir.join("double.hlo.txt");
            std::fs::write(&path, DOUBLE_HLO).unwrap();
            path
        }

        #[test]
        fn pjrt_pool_executes_jobs() {
            let pool = WorkerPool::spawn(2, BackendSpec::Pjrt { hlo: hlo_file("pool") }).unwrap();
            for i in 0..4 {
                let (tx, rx) = oneshot::channel();
                let inputs: Vec<f32> = (0..6).map(|j| (i * 6 + j) as f32).collect();
                pool.submit(i, BatchJob::new(inputs.clone(), 2, 3, ReplyTo::Oneshot(tx)))
                    .unwrap();
                let out = rx.recv().unwrap().unwrap();
                let expect: Vec<f32> = inputs.iter().map(|v| v * 2.0).collect();
                assert_eq!(out.logits, expect);
            }
            pool.shutdown();
        }

        #[test]
        fn bad_artifact_fails_fast() {
            let dir = crate::util::test_dir("badhlo");
            let path = dir.join("broken.hlo.txt");
            std::fs::write(&path, "not hlo at all").unwrap();
            assert!(WorkerPool::spawn(1, BackendSpec::Pjrt { hlo: path }).is_err());
        }

        #[test]
        fn missing_artifact_fails_fast() {
            let spec = BackendSpec::Pjrt { hlo: PathBuf::from("/no/such/file.hlo.txt") };
            assert!(WorkerPool::spawn(1, spec).is_err());
        }
    }
}
