//! Execution worker pool.
//!
//! Each worker is an OS thread that builds its **own** backend from a
//! [`BackendSpec`] — PJRT handles are not `Send`, and the native LUT-GEMM
//! backend owns per-thread scratch buffers — then serves batch jobs from
//! an mpsc queue. Replies travel over in-tree oneshot channels
//! ([`crate::util::oneshot`]); the submitting client thread blocks on the
//! receiver — the concurrency model of this std-thread coordinator.

use crate::engine::{BackendSpec, BatchOutput};
use crate::util::oneshot;
use crate::Result;
use anyhow::{anyhow, ensure};
use std::sync::mpsc;
use std::thread::JoinHandle;

/// One unit of work: an already-padded batch.
pub struct BatchJob {
    /// Row-major `batch × dim` inputs.
    pub inputs: Vec<f32>,
    pub batch: usize,
    pub dim: usize,
    /// Reply channel: outputs plus the simulated CiM cost when the
    /// backend models one (`backend calibrated`).
    pub reply: oneshot::Sender<Result<BatchOutput>>,
}

/// A pool of execution worker threads.
pub struct WorkerPool {
    senders: Vec<mpsc::Sender<BatchJob>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `count` workers, each building its own backend from `spec`.
    /// Blocks until every worker reports successful construction (or
    /// fails fast with the first error).
    pub fn spawn(count: usize, spec: BackendSpec) -> Result<Self> {
        ensure!(count >= 1, "need at least one worker");
        let mut senders = Vec::with_capacity(count);
        let mut handles = Vec::with_capacity(count);
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        for worker_id in 0..count {
            let (tx, rx) = mpsc::channel::<BatchJob>();
            let spec = spec.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("luna-worker-{worker_id}"))
                .spawn(move || worker_main(spec, rx, ready))
                .expect("spawn worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..count {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => return Err(anyhow!("worker failed to initialize: {msg}")),
                Err(_) => return Err(anyhow!("worker exited before reporting readiness")),
            }
        }
        Ok(WorkerPool { senders, handles })
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to worker `idx`.
    pub fn submit(&self, idx: usize, job: BatchJob) -> Result<()> {
        self.senders[idx % self.senders.len()]
            .send(job)
            .map_err(|_| anyhow!("worker {idx} has shut down"))
    }

    /// Drop the queues and join every worker.
    pub fn shutdown(self) {
        drop(self.senders);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_main(
    spec: BackendSpec,
    rx: mpsc::Receiver<BatchJob>,
    ready: mpsc::Sender<std::result::Result<(), String>>,
) {
    let mut backend = match spec.build() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    while let Ok(job) = rx.recv() {
        let res = backend.run_batch(&job.inputs, job.batch, job.dim);
        let _ = job.reply.send(res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::nn::QuantMlp;

    fn native_spec() -> (BackendSpec, QuantMlp) {
        let mlp = QuantMlp::random_for_study(11);
        (BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::DncOpt, threads: 1 }, mlp)
    }

    #[test]
    fn pool_executes_jobs_on_all_workers() {
        let (spec, mlp) = native_spec();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let pool = WorkerPool::spawn(2, spec).unwrap();
        for i in 0..4 {
            let (tx, rx) = oneshot::channel();
            let inputs: Vec<f32> = (0..32).map(|j| ((i * 32 + j) % 16) as f32 / 16.0).collect();
            pool.submit(i, BatchJob { inputs: inputs.clone(), batch: 2, dim: 16, reply: tx })
                .unwrap();
            let out = rx.recv().unwrap().unwrap();
            let expect = mlp.forward_batch(&inputs, 2, &model);
            assert_eq!(out.outputs[0], expect);
        }
        pool.shutdown();
    }

    #[test]
    fn calibrated_worker_keeps_fabric_state_across_jobs() {
        let mlp = QuantMlp::random_for_study(12);
        let lib = crate::cells::tsmc65_library();
        // 288-unit fabric = every weight element of the study model
        let spec = BackendSpec::Calibrated {
            mlp: mlp.clone(),
            kind: MultiplierKind::DncOpt,
            costs: crate::coordinator::tiler::UnitCosts::measure_cached(
                MultiplierKind::DncOpt,
                &lib,
            ),
            banks: 288,
            units_per_bank: 1,
            time_scale: 0.0,
            threads: 1,
        };
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let mut costs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = oneshot::channel();
            let inputs = vec![0.5f32; 2 * 16];
            pool.submit(0, BatchJob { inputs, batch: 2, dim: 16, reply: tx }).unwrap();
            costs.push(rx.recv().unwrap().unwrap().cost.expect("calibrated cost"));
        }
        assert!(costs[0].programs > 0);
        assert_eq!(costs[1].programs, 0, "same worker, second batch fully stationary");
        assert!(costs[1].energy_fj < costs[0].energy_fj);
        pool.shutdown();
    }

    #[test]
    fn worker_surfaces_bad_batch_shape_as_error() {
        let (spec, _) = native_spec();
        let pool = WorkerPool::spawn(1, spec).unwrap();
        let (tx, rx) = oneshot::channel();
        pool.submit(0, BatchJob { inputs: vec![0.0; 5], batch: 1, dim: 16, reply: tx }).unwrap();
        assert!(rx.recv().unwrap().is_err());
        pool.shutdown();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_fast_without_feature() {
        let spec = BackendSpec::Pjrt { hlo: std::path::PathBuf::from("/no/such/file.hlo.txt") };
        assert!(WorkerPool::spawn(1, spec).is_err());
    }

    #[cfg(feature = "pjrt")]
    mod pjrt {
        use crate::coordinator::worker::{BatchJob, WorkerPool};
        use crate::engine::BackendSpec;
        use crate::util::oneshot;
        use std::path::PathBuf;

        const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  add = f32[2,3]{1,0} add(p0, p0)
  ROOT t = (f32[2,3]{1,0}) tuple(add)
}
"#;

        fn hlo_file(tag: &str) -> PathBuf {
            let dir = crate::util::test_dir(tag);
            let path = dir.join("double.hlo.txt");
            std::fs::write(&path, DOUBLE_HLO).unwrap();
            path
        }

        #[test]
        fn pjrt_pool_executes_jobs() {
            let pool = WorkerPool::spawn(2, BackendSpec::Pjrt { hlo: hlo_file("pool") }).unwrap();
            for i in 0..4 {
                let (tx, rx) = oneshot::channel();
                let inputs: Vec<f32> = (0..6).map(|j| (i * 6 + j) as f32).collect();
                pool.submit(i, BatchJob { inputs: inputs.clone(), batch: 2, dim: 3, reply: tx })
                    .unwrap();
                let out = rx.recv().unwrap().unwrap();
                let expect: Vec<f32> = inputs.iter().map(|v| v * 2.0).collect();
                assert_eq!(out.outputs[0], expect);
            }
            pool.shutdown();
        }

        #[test]
        fn bad_artifact_fails_fast() {
            let dir = crate::util::test_dir("badhlo");
            let path = dir.join("broken.hlo.txt");
            std::fs::write(&path, "not hlo at all").unwrap();
            assert!(WorkerPool::spawn(1, BackendSpec::Pjrt { hlo: path }).is_err());
        }

        #[test]
        fn missing_artifact_fails_fast() {
            let spec = BackendSpec::Pjrt { hlo: PathBuf::from("/no/such/file.hlo.txt") };
            assert!(WorkerPool::spawn(1, spec).is_err());
        }
    }
}
