//! Weight-stationary tiling of quantized matmuls onto the LUNA fabric.
//!
//! Every layer's `out×in` weight matrix is a grid of 4-bit codes; each
//! code is one LUT programming. The tiler assigns codes to units in
//! round-robin **waves** (`⌈elements / units⌉` of them): during a wave
//! every unit is programmed once (skipped on a weight-stationary hit) and
//! then performs one multiply per batch sample. Costs are priced with the
//! gate-level [`UnitCosts`] calibration — measured switching energy and
//! critical-path settle time, not hand-waved constants.

use super::state::BankState;
use crate::cells::CellLibrary;
use crate::luna::LunaUnit;
use crate::multiplier::MultiplierKind;
use crate::nn::QuantMlp;
use std::collections::HashMap;
use std::sync::{Mutex, Once, OnceLock};

/// Measured per-operation costs of one LUNA unit configuration.
#[derive(Debug, Clone, Copy)]
pub struct UnitCosts {
    pub kind: MultiplierKind,
    /// Average dynamic energy per multiply (fJ), measured by running the
    /// gate-level model over a pseudo-random operand stream.
    pub mac_energy_fj: f64,
    /// Energy of one LUT (re)programming (fJ): bits × write energy.
    pub program_energy_fj: f64,
    /// Critical-path settle time of one multiply (ps), from the
    /// event-driven simulator (worst observed over the operand stream).
    pub cycle_ps: u64,
    /// LUT bits written per programming.
    pub lut_bits: u64,
}

/// Process-wide calibration cache. The gate-level event-sim measurement
/// behind [`UnitCosts::measure`] is far too expensive to repeat per worker
/// thread; one measurement per (multiplier kind, library name) serves the
/// process.
static COSTS_CACHE: OnceLock<Mutex<HashMap<(MultiplierKind, String), UnitCosts>>> = OnceLock::new();

impl UnitCosts {
    /// [`UnitCosts::measure`], memoized per process. The cache is keyed by
    /// `(kind, lib.name)` — two libraries with the same name are assumed to
    /// hold the same parameters (true of the singleton [`crate::cells::tsmc65_library`]
    /// every call site uses). The serving stack goes through this so
    /// calibration runs once, not once per worker thread.
    pub fn measure_cached(kind: MultiplierKind, lib: &CellLibrary) -> Self {
        let cache = COSTS_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut cache = cache.lock().unwrap();
        *cache.entry((kind, lib.name.clone())).or_insert_with(|| Self::measure(kind, lib))
    }

    /// Calibrate by direct measurement of the gate-level model.
    pub fn measure(kind: MultiplierKind, lib: &CellLibrary) -> Self {
        let mut unit = LunaUnit::new(kind);
        let lut_bits = kind.program_image(0).expect("hardware kind").len() as u64;
        // Deterministic operand stream with good toggle coverage.
        let ws = [6u8, 9, 3, 15, 1, 12, 7, 10];
        let ys = [10u8, 5, 11, 0, 3, 12, 15, 6, 1, 9, 4, 13];
        for &w in &ws {
            unit.program(lib, w);
            for &y in &ys {
                let _ = unit.multiply(lib, y);
            }
        }
        let mac_energy_fj = unit.avg_multiply_energy_fj();

        // Critical path from the event-driven sim over the same stream.
        let netlist = kind.netlist().expect("hardware kind");
        let mut sim = crate::logic::EventSim::new(&netlist);
        sim.program(&kind.program_image(ws[0]).unwrap());
        let mut worst = 0u64;
        for &y in &ys {
            let dt = sim.apply(&crate::logic::to_bits(y as u64, 4));
            worst = worst.max(dt);
        }
        let write_fj = crate::cells::tsmc65::PAPER_WRITE_ENERGY_PJ_PER_BIT * 1000.0;
        UnitCosts {
            kind,
            mac_energy_fj,
            program_energy_fj: lut_bits as f64 * write_fj,
            cycle_ps: worst.max(1),
            lut_bits,
        }
    }
}

/// Schedule and cost of one layer for one batch.
#[derive(Debug, Clone, Copy)]
pub struct LayerSchedule {
    pub layer: usize,
    pub elements: usize,
    pub waves: usize,
    pub macs: u64,
    pub programs: u64,
    pub stationary_hits: u64,
    pub cycles: u64,
    pub energy_fj: f64,
}

/// Whole-model schedule (per batch).
#[derive(Debug, Clone)]
pub struct ModelSchedule {
    pub layers: Vec<LayerSchedule>,
    pub total_macs: u64,
    pub total_programs: u64,
    pub total_stationary_hits: u64,
    pub total_cycles: u64,
    pub total_energy_fj: f64,
    pub latency_ps: u64,
}

impl ModelSchedule {
    /// Flatten to the cost summary the serving path threads through
    /// replies and metrics.
    pub fn cost(&self) -> ScheduleCost {
        ScheduleCost {
            latency_ps: self.latency_ps,
            energy_fj: self.total_energy_fj,
            programs: self.total_programs,
            stationary_hits: self.total_stationary_hits,
        }
    }
}

/// Simulated CiM cost of one batch: what the calibrated serving path
/// attaches to worker replies and aggregates into the metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScheduleCost {
    /// Modelled in-array latency: cycles × measured critical path (ps).
    pub latency_ps: u64,
    /// Programming + MAC energy for the batch (fJ).
    pub energy_fj: f64,
    /// LUT (re)programming events.
    pub programs: u64,
    /// Programs avoided by weight-stationary reuse.
    pub stationary_hits: u64,
}

/// The tiler: owns fabric state, the unit cost calibration, and a
/// reusable per-schedule layer buffer.
#[derive(Debug, Clone)]
pub struct Tiler {
    state: BankState,
    costs: UnitCosts,
    /// Arena for the schedule walk: grows to the model's layer count on
    /// the first schedule and is reused (cleared, refilled in place)
    /// ever after, so steady-state pricing via [`Tiler::schedule_cost`]
    /// allocates nothing — the calibrated backend's zero-allocation
    /// guarantee rides on this (`tests/hot_path_allocs.rs`).
    scratch: Vec<LayerSchedule>,
}

impl Tiler {
    pub fn new(banks: usize, units_per_bank: usize, costs: UnitCosts) -> Self {
        Tiler { state: BankState::new(banks, units_per_bank), costs, scratch: Vec::new() }
    }

    /// Build from `banks.*` config, pricing with the process-cached
    /// calibration of [`Tiler::pricing_kind`]`(cfg.multiplier)`.
    pub fn from_config(cfg: &crate::config::Config, lib: &CellLibrary) -> Self {
        let kind = Self::pricing_kind(cfg.multiplier);
        Tiler::new(cfg.banks.count, cfg.banks.units_per_bank, UnitCosts::measure_cached(kind, lib))
    }

    /// The hardware configuration used to *price* `kind` on the fabric.
    /// IDEAL is a behavioural model with no netlist, so its schedules are
    /// silently priced as the optimized D&C unit — the exact configuration
    /// the paper builds. The substitution is logged once per process so a
    /// `multiplier ideal` serving run doesn't mistake the numbers for free.
    pub fn pricing_kind(kind: MultiplierKind) -> MultiplierKind {
        if kind == MultiplierKind::Ideal {
            static LOGGED: Once = Once::new();
            LOGGED.call_once(|| {
                eprintln!(
                    "tiler: multiplier `ideal` has no hardware netlist — \
                     pricing schedules with `dnc-opt` unit costs"
                );
            });
            MultiplierKind::DncOpt
        } else {
            kind
        }
    }

    pub fn costs(&self) -> UnitCosts {
        self.costs
    }

    pub fn state(&self) -> &BankState {
        &self.state
    }

    /// Walk one batched forward pass into the reusable scratch buffer,
    /// mutating fabric state. `schedule`/`schedule_cost` read it back;
    /// after the first call the walk performs no allocation.
    fn schedule_into_scratch(&mut self, mlp: &QuantMlp, batch: usize) {
        assert!(batch >= 1);
        let units = self.state.total_units();
        self.scratch.clear();
        // Deterministic placement cursor: layers occupy consecutive unit
        // ranges (mod capacity), so a fabric large enough for the whole
        // model is fully weight-stationary across batches.
        let mut cursor = 0usize;
        for (li, layer) in mlp.layers.iter().enumerate() {
            let elements = layer.wq.len();
            let waves = elements.div_ceil(units);
            let mut programs = 0u64;
            let mut hits = 0u64;
            for (e, &code) in layer.wq.iter().enumerate() {
                let unit = (cursor + e) % units;
                if self.state.program(unit, code) {
                    programs += 1;
                } else {
                    hits += 1;
                }
            }
            cursor = (cursor + elements) % units;
            let macs = elements as u64 * batch as u64;
            // Each wave: program (pipelined with compute) then one multiply
            // per sample on every active unit.
            let cycles = waves as u64 * batch as u64;
            let energy_fj = programs as f64 * self.costs.program_energy_fj
                + macs as f64 * self.costs.mac_energy_fj;
            self.scratch.push(LayerSchedule {
                layer: li,
                elements,
                waves,
                macs,
                programs,
                stationary_hits: hits,
                cycles,
                energy_fj,
            });
        }
    }

    /// Schedule one batched forward pass of `mlp` (batch size `batch`).
    /// Mutates fabric state (weight-stationary across calls: a second
    /// identical batch reprograms nothing). Materializes the per-layer
    /// vec — offline callers (eval, benches); the serving path uses the
    /// allocation-free [`Tiler::schedule_cost`].
    pub fn schedule(&mut self, mlp: &QuantMlp, batch: usize) -> ModelSchedule {
        self.schedule_into_scratch(mlp, batch);
        let layers = self.scratch.clone();
        let total_macs = layers.iter().map(|l| l.macs).sum();
        let total_programs = layers.iter().map(|l| l.programs).sum();
        let total_stationary_hits = layers.iter().map(|l| l.stationary_hits).sum();
        let total_cycles: u64 = layers.iter().map(|l| l.cycles).sum();
        let total_energy_fj = layers.iter().map(|l| l.energy_fj).sum();
        ModelSchedule {
            layers,
            total_macs,
            total_programs,
            total_stationary_hits,
            total_cycles,
            latency_ps: total_cycles * self.costs.cycle_ps,
            total_energy_fj,
        }
    }

    /// [`Tiler::schedule`] flattened to its [`ScheduleCost`] without
    /// materializing a [`ModelSchedule`]: totals accumulate straight off
    /// the reusable scratch, so a warm tiler prices a batch with zero
    /// heap allocations (identical fabric mutation and totals —
    /// `schedule_cost(m, b) == schedule(m, b).cost()` from equal state).
    pub fn schedule_cost(&mut self, mlp: &QuantMlp, batch: usize) -> ScheduleCost {
        self.schedule_into_scratch(mlp, batch);
        let total_cycles: u64 = self.scratch.iter().map(|l| l.cycles).sum();
        ScheduleCost {
            latency_ps: total_cycles * self.costs.cycle_ps,
            energy_fj: self.scratch.iter().map(|l| l.energy_fj).sum(),
            programs: self.scratch.iter().map(|l| l.programs).sum(),
            stationary_hits: self.scratch.iter().map(|l| l.stationary_hits).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;

    fn tiler(units: usize) -> Tiler {
        let lib = tsmc65_library();
        Tiler::new(units, 1, UnitCosts::measure(MultiplierKind::DncOpt, &lib))
    }

    #[test]
    fn unit_costs_are_sane() {
        let lib = tsmc65_library();
        let c = UnitCosts::measure(MultiplierKind::DncOpt, &lib);
        assert!(c.mac_energy_fj > 1.0 && c.mac_energy_fj < 500.0, "{}", c.mac_energy_fj);
        assert_eq!(c.lut_bits, 10);
        assert!(c.cycle_ps > 50 && c.cycle_ps < 2000, "{}", c.cycle_ps);
        // programming is orders of magnitude costlier than a multiply —
        // the reason weight-stationary scheduling matters.
        assert!(c.program_energy_fj > 100.0 * c.mac_energy_fj);
    }

    #[test]
    fn schedule_covers_all_macs() {
        let mlp = QuantMlp::random_for_study(5);
        let mut t = tiler(16);
        let s = t.schedule(&mlp, 4);
        assert_eq!(s.total_macs, mlp.macs() * 4);
        for l in &s.layers {
            assert_eq!(l.programs + l.stationary_hits, l.elements as u64);
            assert!(l.cycles >= (l.macs.div_ceil(16)));
        }
    }

    #[test]
    fn second_identical_batch_is_fully_stationary() {
        let mlp = QuantMlp::random_for_study(6);
        // fabric big enough to hold every element simultaneously
        let total_elems: usize = mlp.layers.iter().map(|l| l.wq.len()).sum();
        let mut t = tiler(total_elems);
        let s1 = t.schedule(&mlp, 2);
        let s2 = t.schedule(&mlp, 2);
        assert!(s1.total_programs > 0);
        assert_eq!(s2.total_programs, 0, "all hits on the second pass");
        assert!(s2.total_energy_fj < s1.total_energy_fj);
    }

    #[test]
    fn small_fabric_needs_more_waves() {
        let mlp = QuantMlp::random_for_study(7);
        let mut small = tiler(4);
        let mut big = tiler(64);
        let ss = small.schedule(&mlp, 1);
        let sb = big.schedule(&mlp, 1);
        assert!(ss.total_cycles > sb.total_cycles);
        assert_eq!(ss.total_macs, sb.total_macs);
    }

    #[test]
    fn from_config_substitutes_dnc_opt_costs_for_ideal() {
        let lib = tsmc65_library();
        let mut cfg = crate::config::Config::default();
        cfg.multiplier = MultiplierKind::Ideal;
        let t = Tiler::from_config(&cfg, &lib);
        // IDEAL has no netlist: priced as the optimized D&C unit.
        assert_eq!(t.costs().kind, MultiplierKind::DncOpt);
        assert_eq!(Tiler::pricing_kind(MultiplierKind::Ideal), MultiplierKind::DncOpt);
        // hardware kinds price as themselves
        cfg.multiplier = MultiplierKind::Approx;
        assert_eq!(Tiler::from_config(&cfg, &lib).costs().kind, MultiplierKind::Approx);
        assert_eq!(Tiler::pricing_kind(MultiplierKind::Approx), MultiplierKind::Approx);
    }

    #[test]
    fn measure_cached_matches_direct_measurement() {
        let lib = tsmc65_library();
        let direct = UnitCosts::measure(MultiplierKind::Approx2, &lib);
        let cached = UnitCosts::measure_cached(MultiplierKind::Approx2, &lib);
        let again = UnitCosts::measure_cached(MultiplierKind::Approx2, &lib);
        assert_eq!(direct.mac_energy_fj, cached.mac_energy_fj);
        assert_eq!(direct.cycle_ps, cached.cycle_ps);
        assert_eq!(cached.program_energy_fj, again.program_energy_fj);
    }

    #[test]
    fn schedule_cost_flattens_totals() {
        let mlp = QuantMlp::random_for_study(8);
        let mut t = tiler(32);
        let s = t.schedule(&mlp, 3);
        let c = s.cost();
        assert_eq!(c.latency_ps, s.latency_ps);
        assert_eq!(c.programs, s.total_programs);
        assert_eq!(c.stationary_hits, s.total_stationary_hits);
        assert_eq!(c.energy_fj, s.total_energy_fj);
        assert_eq!(
            c.programs + c.stationary_hits,
            s.layers.iter().map(|l| l.elements as u64).sum::<u64>()
        );
    }

    #[test]
    fn schedule_cost_matches_schedule_and_reuses_scratch() {
        let mlp = QuantMlp::random_for_study(9);
        // two tilers from identical state walk the same schedule
        let mut a = tiler(32);
        let mut b = tiler(32);
        for batch in [1usize, 3, 8] {
            assert_eq!(a.schedule_cost(&mlp, batch), b.schedule(&mlp, batch).cost());
        }
        // the arena stabilizes at the model's layer count: repeated
        // pricing neither grows nor reallocates it, and every warm walk
        // prices identically (deterministic post-model fabric state)
        let cap = a.scratch.capacity();
        let ptr = a.scratch.as_ptr();
        let warm = a.schedule_cost(&mlp, 4);
        for _ in 0..3 {
            assert_eq!(a.schedule_cost(&mlp, 4), warm, "warm walks price identically");
        }
        assert_eq!(a.scratch.capacity(), cap);
        assert_eq!(a.scratch.as_ptr(), ptr, "scratch buffer reused in place");
        assert_eq!(a.scratch.len(), mlp.layers.len());
    }

    #[test]
    fn approx_unit_is_cheaper_per_mac_than_dnc_opt() {
        let lib = tsmc65_library();
        let opt = UnitCosts::measure(MultiplierKind::DncOpt, &lib);
        let approx = UnitCosts::measure(MultiplierKind::Approx, &lib);
        // Fig 9 halves the mux count and drops the adders entirely.
        assert!(approx.mac_energy_fj < opt.mac_energy_fj);
        assert!(approx.cycle_ps <= opt.cycle_ps);
    }
}
