//! Bank programming state: which weight code each LUNA unit holds.


/// Address of one LUNA unit in the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct UnitAddr {
    pub bank: usize,
    pub unit: usize,
}

/// Tracks the weight code programmed into every unit of the fabric, and
/// counts (re)programming events — the coordinator's weight-stationary
/// bookkeeping.
#[derive(Debug, Clone)]
pub struct BankState {
    banks: usize,
    units_per_bank: usize,
    /// `None` = never programmed.
    codes: Vec<Option<u8>>,
    programs: u64,
    hits: u64,
}

impl BankState {
    pub fn new(banks: usize, units_per_bank: usize) -> Self {
        assert!(banks >= 1 && units_per_bank >= 1);
        BankState {
            banks,
            units_per_bank,
            // lint: allow(alloc): fabric-state construction, once per
            // worker at startup — the per-batch walk mutates in place.
            codes: vec![None; banks * units_per_bank],
            programs: 0,
            hits: 0,
        }
    }

    pub fn total_units(&self) -> usize {
        self.banks * self.units_per_bank
    }

    /// Linear unit index -> address.
    pub fn addr(&self, linear: usize) -> UnitAddr {
        assert!(linear < self.total_units());
        UnitAddr { bank: linear / self.units_per_bank, unit: linear % self.units_per_bank }
    }

    /// Program unit `linear` with `code`. Returns `true` if an actual
    /// (re)program happened, `false` on a weight-stationary hit.
    pub fn program(&mut self, linear: usize, code: u8) -> bool {
        assert!(code < 16);
        let slot = &mut self.codes[linear];
        if *slot == Some(code) {
            self.hits += 1;
            false
        } else {
            *slot = Some(code);
            self.programs += 1;
            true
        }
    }

    pub fn programmed_code(&self, linear: usize) -> Option<u8> {
        self.codes[linear]
    }

    /// Total programming events so far.
    pub fn programs(&self) -> u64 {
        self.programs
    }

    /// Weight-stationary hits (programs avoided).
    pub fn hits(&self) -> u64 {
        self.hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn programming_counts_distinct_codes() {
        let mut s = BankState::new(2, 4);
        assert!(s.program(0, 5));
        assert!(!s.program(0, 5)); // stationary hit
        assert!(s.program(0, 6));
        assert_eq!(s.programs(), 2);
        assert_eq!(s.hits(), 1);
    }

    #[test]
    fn addresses_are_bijective() {
        let s = BankState::new(3, 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..s.total_units() {
            assert!(seen.insert(s.addr(i)));
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(s.addr(5), UnitAddr { bank: 1, unit: 1 });
    }

    #[test]
    #[should_panic]
    fn code_out_of_range_panics() {
        let mut s = BankState::new(1, 1);
        s.program(0, 16);
    }
}
