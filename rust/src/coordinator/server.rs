//! The serving front-end: accepts single requests, batches them, executes
//! on the worker pool (native LUT-GEMM by default, calibrated schedule
//! replay with `backend calibrated`, PJRT with the `pjrt` feature — see
//! [`crate::engine`]), prices the CiM work with the tiler (coordinator-
//! side, or inside each calibrated worker), and fans per-request
//! responses back out.
//!
//! Concurrency model (std threads; no async runtime in this offline
//! image): every admitted request registers a [`Completion`] — blocking
//! callers ([`ServerHandle::submit`]) wrap a oneshot in a callback, the
//! TCP front-end ([`crate::net`]) registers its connection's reply queue
//! via [`ServerHandle::submit_with`]; a background flusher thread
//! enforces the batching deadline; a small **persistent completion
//! pool** receives worker replies and fans them out (a thread-per-batch
//! design measured ~25% slower at 4 workers — EXPERIMENTS.md §Perf).
//!
//! **Sharded batching** (`batcher.shards`, default 1): requests dispatch
//! onto independent batcher lanes — each shard owns its own batcher
//! mutex and waiter map, so connections landing on different shards
//! never contend on one lock. The lane is chosen by `batcher.affinity`:
//! `request` (default) round-robins on the request id, `connection`
//! pins every request from one connection to `conn % shards` (the TCP
//! front-end passes its connection id through
//! [`ServerHandle::submit_from`]), keeping that lane — and the worker
//! rotation it seeds — warm for the connection. Admission stays
//! globally correct through one shared atomic outstanding count, and
//! distinct shards seed the router at disjoint worker rotations.
//! Per-request numerics are batch-composition-independent (integer
//! accumulation is order-exact per row), so replies are bit-identical
//! for every shard count and either affinity (`tests/net_serving.rs`).
//!
//! **Zero-allocation hot path**: pixels, flat batch inputs, logits and
//! reply frames all live in pooled buffers ([`crate::util::pool`]),
//! worker jobs and replies travel over the allocation-free
//! [`crate::util::queue`], and the steady-state coordinator-side
//! schedule cost is memoized per batch size — after warmup a request
//! performs zero heap allocations from socket to reply
//! (`tests/hot_path_allocs.rs`; lifecycle diagram in the crate docs'
//! `## Serving hot path` section).
//!
//! Admission control bounds *total outstanding* requests (pending +
//! in-flight) at `batcher.queue_depth`; rejections carry a structured
//! [`Backpressure`] retry hint.

use super::admission::AdmissionGate;
use super::batcher::{Batch, Batcher};
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse, RequestId};
use super::router::{InFlightGuard, Router};
use super::tiler::{ScheduleCost, Tiler, UnitCosts};
use super::worker::{BatchJob, ReplyTicket, ReplyTo, WorkerPool, WorkerReply};
use crate::config::{BackendKind, Config, ShardAffinity};
use crate::engine::{BackendSpec, BatchOutput};
use crate::net::protocol::{Frame, WireCost};
use crate::nn::QuantMlp;
use crate::runtime::ArtifactStore;
use crate::util::{oneshot, queue, PooledVec};
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::collections::HashMap;
// Deliberately std (not the loom shim): the coordinator's background
// threads hold `Weak` references, which loom's `Arc` lacks, and these
// atomics are id counters and stop flags with no cross-thread publication
// role. The model-checked admission bound lives in [`AdmissionGate`].
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// 429-style admission rejection with a structured retry hint.
///
/// [`ServerHandle::submit`]/[`ServerHandle::submit_with`] return this
/// (wrapped in `anyhow::Error`; recover it with
/// `err.downcast_ref::<Backpressure>()`) instead of an opaque "queue
/// full" failure, and the wire front-end maps it onto the protocol's
/// `Rejected` frame. The hint comes from
/// [`Batcher::retry_after_us`](super::Batcher::retry_after_us): queue
/// depth, `max_batch` and the flush deadline — an estimate, not a
/// reservation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backpressure {
    /// Suggested client backoff before retrying (µs, always ≥ 1).
    pub retry_after_us: u64,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server at capacity — retry in {} us", self.retry_after_us)
    }
}

impl std::error::Error for Backpressure {}

/// How a submission receives its reply — resolved exactly once, from a
/// coordinator thread.
///
/// `Callback` boxes an arbitrary closure (the blocking
/// [`ServerHandle::submit`] wraps a oneshot; tests and examples pass
/// their own) — flexible, but the box allocates. The TCP front-end
/// instead registers `Frame { tx, wire_id }`: the coordinator builds the
/// `Response`/`Error` frame itself, with pooled logits, and pushes it
/// straight onto the connection's writer queue — the allocation-free
/// reply lane a network connection keeps thousands of requests in
/// flight on without a blocked thread each.
///
/// The `Frame` variant is a deliberate coordinator → [`crate::net`]
/// coupling (within one crate): building the frame here avoids an
/// intermediate response struct plus a second copy on the writer
/// thread. The wire protocol module itself stays coordinator-free.
pub enum Completion {
    /// Invoke a closure with the response or the batch-failure reason.
    Callback(Box<dyn FnOnce(std::result::Result<InferenceResponse, String>) + Send>),
    /// Push the reply frame onto a connection writer queue, echoing the
    /// client's wire id.
    Frame { tx: queue::Sender<Frame>, wire_id: u64 },
}

impl Completion {
    /// Wrap a closure (the allocating, fully general form).
    pub fn callback(
        f: impl FnOnce(std::result::Result<InferenceResponse, String>) + Send + 'static,
    ) -> Self {
        Completion::Callback(Box::new(f))
    }
}

/// One independent batcher lane (see the module docs on sharding).
struct Shard {
    batcher: Mutex<Batcher>,
    /// Completions for requests whose `id % shards` routes here. Insert
    /// and removal stay on this shard's lock; the global outstanding
    /// count lives in [`Shared::outstanding`].
    waiters: Mutex<HashMap<RequestId, Completion>>,
    /// This shard's worker-rotation turn counter (`shard + turn·shards`
    /// seeds the router so distinct shards prefer disjoint workers).
    rr: AtomicUsize,
    /// This shard's dispatched batches awaiting their worker reply,
    /// keyed by batch id (whose low bits encode the shard, so the
    /// completion pool routes a reply back here without a global map).
    pending: Mutex<HashMap<u64, BatchCtx>>,
    /// This shard's producer handle on the completion queue; `None`
    /// once shutdown has begun (new dispatches then fail their batch
    /// inline). Per shard so dispatch touches no cross-shard lock.
    completions: Mutex<Option<queue::Sender<WorkerReply>>>,
}

/// A dispatched batch's context, parked in its shard's pending map
/// until the worker reply arrives (keyed by batch id).
struct BatchCtx {
    batch: Batch,
    guard: InFlightGuard,
    /// Coordinator-side pricing (None when the calibrated backend prices
    /// the batch itself; the reply's cost then takes over).
    sched_cost: Option<ScheduleCost>,
}

struct Shared {
    shards: Vec<Shard>,
    /// Admission bound: total outstanding requests (pending in any
    /// shard's batcher + dispatched but not yet completed) may not
    /// exceed `batcher.queue_depth`. One shared gate keeps the bound
    /// globally correct across shards without a global lock; its
    /// never-exceeds / never-leaks invariant is loom-model-checked
    /// ([`super::admission`]).
    admission: AdmissionGate,
    /// Lowered batch size, echoed in the wire protocol's `Info` frame.
    max_batch: usize,
    backend: BackendKind,
    /// Coordinator-side CiM pricing for backends that don't model cost
    /// themselves; `None` for `backend calibrated`, where each worker's
    /// own fabric replay prices the batch and the cost arrives on the
    /// reply.
    tiler: Option<Mutex<Tiler>>,
    /// Steady-state schedule memo per batch size. The tiler maps
    /// elements onto units round-robin, so the fabric state after any
    /// full schedule of this model is a fixed function of the model —
    /// every schedule after the first prices deterministically per
    /// batch size. Cache those warm costs and skip the O(model)
    /// scheduling walk (and its allocations) per batch.
    sched_cache: Mutex<HashMap<usize, ScheduleCost>>,
    /// Whether the coordinator tiler has run at least one schedule (its
    /// state is then the deterministic post-model state — see
    /// [`Shared::sched_cache`]).
    sched_warm: AtomicBool,
    router: Router,
    metrics: Arc<Metrics>,
    mlp: QuantMlp,
    /// Shard-selection rule (`batcher.affinity`; see the module docs).
    affinity: ShardAffinity,
    in_dim: usize,
    out_dim: usize,
    next_id: AtomicU64,
    stopping: AtomicBool,
    /// Pad executed batches to `padded_to` (PJRT's lowered shape is
    /// fixed); the native backend runs exactly the real rows.
    pad_batches: bool,
    /// Batch sequence counter; a batch's id is
    /// `seq · shards + shard_idx`, so `id % shards` recovers the shard.
    batch_seq: AtomicU64,
}

impl Shared {
    fn shard_index(&self, id: RequestId) -> usize {
        (id % self.shards.len() as u64) as usize
    }

    /// The lane a fresh request lands on: request-id round-robin, or —
    /// under connection affinity, when the submitter identified its
    /// connection — pinned to `conn % shards`.
    fn shard_for(&self, id: RequestId, conn: Option<u64>) -> usize {
        match (self.affinity, conn) {
            (ShardAffinity::Connection, Some(conn)) => (conn % self.shards.len() as u64) as usize,
            _ => self.shard_index(id),
        }
    }
}

/// The serving coordinator. Construct with [`CoordinatorServer::start`],
/// submit through the cloned [`ServerHandle`]s.
pub struct CoordinatorServer {
    shared: Arc<Shared>,
    flusher: Option<std::thread::JoinHandle<()>>,
    completion_pool: Vec<std::thread::JoinHandle<()>>,
}

/// Cheap cloneable submission handle. `submit` blocks the calling thread
/// until the response arrives (drive it from multiple client threads).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl CoordinatorServer {
    /// Start the coordinator: load artifacts, spawn the worker pool and the
    /// deadline flusher. Requires `make artifacts` to have run.
    pub fn start(cfg: Config) -> Result<(Self, ServerHandle)> {
        cfg.validate()?;
        let store = ArtifactStore::new(&cfg.artifacts_dir);
        let meta = store.manifest()?;
        ensure!(
            meta.batch == cfg.batcher.max_batch,
            "config max_batch {} != lowered batch {} — artifacts and config must agree",
            cfg.batcher.max_batch,
            meta.batch
        );
        let mlp = store.load_mlp().context("loading weights")?;
        let lib = crate::cells::tsmc65_library();
        // Coordinator-side pricing tiler for backends that don't model
        // cost themselves. `calibrated` moves pricing into the workers
        // (one weight-stationary fabric per worker), so the coordinator
        // keeps none.
        let tiler = match cfg.backend {
            BackendKind::Calibrated => None,
            _ => Some(Mutex::new(Tiler::from_config(&cfg, &lib))),
        };
        // Backend choice: native runs the batched LUT-GEMM in-process
        // (no HLO artifacts touched); calibrated wraps it with per-worker
        // schedule replay (the gate-level calibration is measured once
        // here and *carried in the spec* — never per worker thread);
        // pjrt compiles the AOT executable.
        let spec = match cfg.backend {
            BackendKind::Native => BackendSpec::Native {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                threads: cfg.gemm.threads,
            },
            BackendKind::Calibrated => BackendSpec::Calibrated {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                costs: UnitCosts::measure_cached(Tiler::pricing_kind(cfg.multiplier), &lib),
                banks: cfg.banks.count,
                units_per_bank: cfg.banks.units_per_bank,
                time_scale: cfg.timing.time_scale,
                threads: cfg.gemm.threads,
            },
            BackendKind::Pjrt => BackendSpec::Pjrt { hlo: store.mlp_hlo(cfg.multiplier) },
        };
        let pool = WorkerPool::spawn(cfg.workers.count, spec)?;
        let in_dim = *meta.dims.first().unwrap();
        let out_dim = *meta.dims.last().unwrap();
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let shards = (0..cfg.batcher.shards)
            .map(|_| Shard {
                batcher: Mutex::new(Batcher::from_config(&cfg.batcher)),
                waiters: Mutex::new(HashMap::new()),
                rr: AtomicUsize::new(0),
                pending: Mutex::new(HashMap::new()),
                completions: Mutex::new(Some(ctx.clone())),
            })
            .collect();
        drop(ctx);
        let shared = Arc::new(Shared {
            shards,
            admission: AdmissionGate::new(cfg.batcher.queue_depth),
            max_batch: cfg.batcher.max_batch,
            backend: cfg.backend,
            tiler,
            sched_cache: Mutex::new(HashMap::new()),
            sched_warm: AtomicBool::new(false),
            router: Router::new(pool),
            metrics: Arc::new(Metrics::new()),
            mlp,
            affinity: cfg.batcher.affinity,
            in_dim,
            out_dim,
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            pad_batches: cfg.backend == BackendKind::Pjrt,
            batch_seq: AtomicU64::new(0),
        });
        // Persistent completion pool: one thread per worker keeps the
        // pipeline full without per-batch thread spawns. Each thread
        // owns a reusable fan-out scratch, so completing a batch
        // allocates nothing.
        let mut completion_pool = Vec::new();
        for i in 0..cfg.workers.count {
            let crx = crx.clone();
            let weak = Arc::downgrade(&shared);
            let max_batch = cfg.batcher.max_batch;
            completion_pool.push(
                std::thread::Builder::new()
                    .name(format!("luna-completion-{i}"))
                    .spawn(move || {
                        // sized up front: fan-out never allocates, even
                        // on a thread that serves its first batch late
                        let mut scratch: Vec<Option<Completion>> =
                            Vec::with_capacity(max_batch); // lint: allow(alloc): startup scratch
                        while let Some(reply) = crx.recv() {
                            let Some(shared) = weak.upgrade() else { return };
                            // the batch id's low bits name the shard —
                            // the *dispatching* lane, which under
                            // connection affinity is not derivable from
                            // request ids
                            let shard_idx = shared.shard_index(reply.batch_id);
                            let ctx = {
                                let shard = &shared.shards[shard_idx];
                                shard.pending.lock().unwrap().remove(&reply.batch_id)
                            };
                            if let Some(ctx) = ctx {
                                complete_batch(&shared, shard_idx, ctx, reply.result, &mut scratch);
                            }
                        }
                    })
                    .expect("spawn completion thread"),
            );
        }
        drop(crx);
        let flusher = {
            let weak = Arc::downgrade(&shared);
            let period = Duration::from_micros((cfg.batcher.max_wait_us.max(50)) / 2);
            std::thread::Builder::new()
                .name("luna-flusher".into())
                .spawn(move || loop {
                    std::thread::sleep(period);
                    let Some(shared) = weak.upgrade() else { return };
                    if shared.stopping.load(Ordering::Relaxed) {
                        return;
                    }
                    for idx in 0..shared.shards.len() {
                        let due = {
                            let mut b = shared.shards[idx].batcher.lock().unwrap();
                            b.flush_due(std::time::Instant::now())
                        };
                        if let Some(batch) = due {
                            dispatch_batch(&shared, idx, batch);
                        }
                    }
                })
                .expect("spawn flusher")
        };
        let handle = ServerHandle { shared: shared.clone() };
        Ok((CoordinatorServer { shared, flusher: Some(flusher), completion_pool }, handle))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Flush pending requests, drain the completion pool, stop the flusher.
    pub fn shutdown(mut self) {
        self.shared.stopping.store(true, Ordering::Relaxed);
        for idx in 0..self.shared.shards.len() {
            let batches = { self.shared.shards[idx].batcher.lock().unwrap().flush_all() };
            for b in batches {
                dispatch_batch(&self.shared, idx, b);
            }
        }
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Close every shard's completion-queue sender: the only
        // remaining producers are the reply tickets riding in-flight
        // jobs, so the pool drains every dispatched batch, observes the
        // disconnect, and exits.
        for shard in &self.shared.shards {
            *shard.completions.lock().unwrap() = None;
        }
        let pool = std::mem::take(&mut self.completion_pool);
        for h in pool {
            let _ = h.join();
        }
    }
}

impl ServerHandle {
    /// Submit one image and block until the batched execution completes.
    /// Admission failures surface as [`Backpressure`] (downcastable from
    /// the returned error) carrying a `retry_after_us` hint.
    pub fn submit(&self, pixels: Vec<f32>) -> Result<InferenceResponse> {
        let (tx, rx) = oneshot::channel();
        self.submit_with(
            pixels,
            Completion::callback(move |result| {
                let _ = tx.send(result);
            }),
        )?;
        match rx.recv() {
            Some(Ok(resp)) => Ok(resp),
            Some(Err(why)) => Err(anyhow!("request failed: {why}")),
            None => Err(anyhow!("request dropped")),
        }
    }

    /// Admission-checked asynchronous submission: on success, `done` is
    /// resolved exactly once — with the response, or with the failure
    /// reason if the batch dies — from a coordinator thread. On
    /// rejection `done` is dropped unused (never resolved) and a
    /// [`Backpressure`] error comes back, so the caller replies 429
    /// itself.
    ///
    /// Admission bounds total outstanding requests (pending +
    /// in-flight) by `batcher.queue_depth` — the genuine overload
    /// guard, enforced by one shared atomic so it stays globally exact
    /// across batcher shards. Pixels arrive in a pooled buffer (plain
    /// `Vec<f32>` converts in), keeping the wire path allocation-free.
    pub fn submit_with(&self, pixels: impl Into<PooledVec<f32>>, done: Completion) -> Result<()> {
        self.submit_inner(None, pixels.into(), done)
    }

    /// [`submit_with`](Self::submit_with), identifying the submitting
    /// connection: under `batcher.affinity connection` every request
    /// carrying the same `conn` id lands on the same batcher shard
    /// (lane/cache affinity); under the default request affinity the id
    /// is ignored. The TCP front-end calls this with its per-connection
    /// counter.
    pub fn submit_from(
        &self,
        conn: u64,
        pixels: impl Into<PooledVec<f32>>,
        done: Completion,
    ) -> Result<()> {
        self.submit_inner(Some(conn), pixels.into(), done)
    }

    fn submit_inner(
        &self,
        conn: Option<u64>,
        pixels: PooledVec<f32>,
        done: Completion,
    ) -> Result<()> {
        ensure!(pixels.len() == self.shared.in_dim, "expected {} pixels", self.shared.in_dim);
        // ordering: Relaxed — pure id allocation, no publication.
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let shard_idx = self.shared.shard_for(id, conn);
        if let Err(observed) = self.shared.admission.try_admit() {
            let hint = {
                let batcher = self.shared.shards[shard_idx].batcher.lock().unwrap();
                batcher.retry_after_us(std::time::Instant::now(), observed)
            };
            self.shared.metrics.record_rejection(hint);
            return Err(Backpressure { retry_after_us: hint }.into());
        }
        let shard = &self.shared.shards[shard_idx];
        shard.waiters.lock().unwrap().insert(id, done);
        let maybe_batch = {
            let mut batcher = shard.batcher.lock().unwrap();
            match batcher.push(InferenceRequest::new(id, pixels)) {
                Ok(b) => b,
                // Unreachable by invariant (every shard's pending queue
                // is a subset of the outstanding set the gate above
                // caps); kept as defense in depth since the batcher is
                // also driven standalone, where `push` genuinely
                // backpressures.
                Err(_rejected) => {
                    let hint =
                        batcher.retry_after_us(std::time::Instant::now(), batcher.pending());
                    drop(batcher);
                    shard.waiters.lock().unwrap().remove(&id);
                    self.shared.admission.release(1);
                    self.shared.metrics.record_rejection(hint);
                    return Err(Backpressure { retry_after_us: hint }.into());
                }
            }
        };
        self.shared.metrics.record_admission();
        if let Some(batch) = maybe_batch {
            dispatch_batch(&self.shared, shard_idx, batch);
        }
        Ok(())
    }

    /// Input dimension the model expects (pixels per request).
    pub fn input_dim(&self) -> usize {
        self.shared.in_dim
    }

    /// Output dimension (logits per response).
    pub fn output_dim(&self) -> usize {
        self.shared.out_dim
    }

    /// The lowered batch size requests are batched up to.
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// Number of independent batcher shards.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Stable identifier of the execution backend serving this handle.
    pub fn backend_slug(&self) -> &'static str {
        self.shared.backend.slug()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }
}

/// Coordinator-side CiM pricing with the steady-state memo (see
/// [`Shared::sched_cache`]).
fn coordinator_cost(shared: &Shared, tiler: &Mutex<Tiler>, n: usize) -> ScheduleCost {
    if let Some(c) = shared.sched_cache.lock().unwrap().get(&n) {
        return *c;
    }
    // The first schedule runs from the cold fabric (its programming cost
    // is real and must not be cached); every later one starts from the
    // deterministic post-model state, so its cost is a pure function of
    // (model, n) — identical to what an uncached walk would report. The
    // warm flag flips under the tiler lock so "warm" can never describe
    // a schedule that actually ran first on the cold fabric.
    let (was_warm, cost) = {
        let mut t = tiler.lock().unwrap();
        // ordering: Relaxed — the swap runs under the tiler lock, which
        // already orders it against every other schedule walk.
        let was_warm = shared.sched_warm.swap(true, Ordering::Relaxed);
        (was_warm, t.schedule_cost(&shared.mlp, n))
    };
    if was_warm {
        shared.sched_cache.lock().unwrap().insert(n, cost);
    }
    cost
}

/// Price the batch on the CiM fabric (unless the backend prices it
/// itself), park its context under a batch id, and hand the flattened
/// inputs to a worker; the completion pool picks the reply up by id.
fn dispatch_batch(shared: &Arc<Shared>, shard_idx: usize, batch: Batch) {
    let n = batch.requests.len();
    if n == 0 {
        return;
    }
    // CiM cost model: schedule this batch on the coordinator's fabric —
    // skipped for `backend calibrated`, whose workers replay the schedule
    // on their own weight-stationary fabrics and return the cost.
    let sched_cost = shared.tiler.as_ref().map(|t| coordinator_cost(shared, t, n));

    // PJRT's lowered executable has a fixed batch dimension; the native
    // GEMM runs exactly the real rows (no MACs spent on padding, and no
    // zero fill — flatten_into pads only the PJRT tail).
    let exec_rows = if shared.pad_batches { batch.padded_to } else { n };
    let mut inputs = PooledVec::with_capacity(exec_rows * shared.in_dim);
    batch.flatten_into(shared.in_dim, exec_rows, &mut inputs);

    let shard = &shared.shards[shard_idx];
    let ctx_tx = { shard.completions.lock().unwrap().clone() };
    let Some(ctx_tx) = ctx_tx else {
        fail_batch(shared, shard_idx, &batch, "server is shutting down");
        return;
    };
    // Reserve the worker before parking the context so the reply can
    // never race its own bookkeeping; distinct shards seed the rotation
    // at disjoint workers.
    let turn = shard.rr.fetch_add(1, Ordering::Relaxed);
    let rot = shard_idx + turn.wrapping_mul(shared.shards.len());
    let (worker, guard) = shared.router.begin(rot);
    // low bits encode the shard so the completion pool can route the
    // reply back to this shard's pending map
    let seq = shared.batch_seq.fetch_add(1, Ordering::Relaxed);
    let batch_id = seq * shared.shards.len() as u64 + shard_idx as u64;
    shard.pending.lock().unwrap().insert(batch_id, BatchCtx { batch, guard, sched_cost });
    let job = BatchJob {
        inputs,
        batch: exec_rows,
        dim: shared.in_dim,
        reply: ReplyTo::Queue(ReplyTicket::new(ctx_tx, batch_id)),
    };
    if let Err(e) = shared.router.submit_to(worker, job) {
        let ctx = { shard.pending.lock().unwrap().remove(&batch_id) };
        if let Some(ctx) = ctx {
            fail_batch(shared, shard_idx, &ctx.batch, &format!("{e:#}"));
        }
    }
}

/// Fan one worker reply out to the batch's per-request completions.
/// `shard_idx` is the lane the batch dispatched from (its waiters live
/// there — under connection affinity that lane is not derivable from
/// request ids). `scratch` is the calling completion thread's reusable
/// fan-out buffer.
fn complete_batch(
    shared: &Arc<Shared>,
    shard_idx: usize,
    ctx: BatchCtx,
    result: Result<BatchOutput>,
    scratch: &mut Vec<Option<Completion>>,
) {
    let BatchCtx { batch, guard, sched_cost } = ctx;
    let _guard = guard;
    match result {
        Ok(output) => {
            let n = batch.requests.len();
            // The backend's own pricing (calibrated) wins over the
            // coordinator-side schedule; exactly one of the two exists.
            let cost = output.cost.or(sched_cost).unwrap_or_default();
            // Served-work metrics only count batches that actually
            // produced replies; failures go to record_batch_failure.
            shared.metrics.record_batch(n, batch.padded_to);
            shared.metrics.record_sim_cost(&cost);
            shared.metrics.record_host_gemm_us(output.host_gemm_us);
            let per_req_energy = cost.energy_fj / n as f64;
            let out_dim = shared.out_dim;
            // A batch forms inside one shard, so one lock acquisition on
            // that shard's waiter map covers every request; completions
            // resolve after release — they run arbitrary caller code
            // (callbacks) or push frames, which must never happen under
            // the waiters lock.
            scratch.clear();
            {
                let shard = &shared.shards[shard_idx];
                let mut waiters = shard.waiters.lock().unwrap();
                scratch.extend(batch.requests.iter().map(|req| waiters.remove(&req.id)));
            }
            shared.admission.release(n);
            for ((i, req), waiter) in batch.requests.iter().enumerate().zip(scratch.drain(..)) {
                let logits = &output.logits[i * out_dim..(i + 1) * out_dim];
                let label = crate::nn::argmax(logits);
                let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
                shared.metrics.latency.record_us(latency_us);
                match waiter {
                    Some(Completion::Callback(done)) => done(Ok(InferenceResponse {
                        id: req.id,
                        logits: logits.to_vec(),
                        label,
                        latency_us,
                        sim_energy_fj: per_req_energy,
                        sim_latency_ps: cost.latency_ps,
                        sim_programs: cost.programs,
                        sim_stationary_hits: cost.stationary_hits,
                    })),
                    Some(Completion::Frame { tx, wire_id }) => {
                        // pooled frame logits: recycled after the writer
                        // flushes the frame and drops it
                        let _ = tx.send(Frame::Response {
                            id: wire_id,
                            label: label as u32,
                            latency_us,
                            cost: WireCost {
                                energy_fj: per_req_energy,
                                latency_ps: cost.latency_ps,
                                programs: cost.programs,
                                stationary_hits: cost.stationary_hits,
                            },
                            logits: PooledVec::from_slice(logits),
                        });
                    }
                    None => {}
                }
            }
        }
        Err(e) => fail_batch(shared, shard_idx, &batch, &format!("{e:#}")),
    }
}

fn fail_batch(shared: &Arc<Shared>, shard_idx: usize, batch: &Batch, why: &str) {
    // Complete every waiter with the structured reason; the blocking
    // submit() surfaces it as "request failed: <why>" and the wire
    // front-end sends an Error frame.
    if batch.requests.is_empty() {
        return;
    }
    shared.metrics.record_batch_failure(batch.requests.len());
    let completions: Vec<_> = {
        let shard = &shared.shards[shard_idx];
        let mut waiters = shard.waiters.lock().unwrap();
        batch.requests.iter().map(|req| waiters.remove(&req.id)).collect()
    };
    shared.admission.release(batch.requests.len());
    for done in completions.into_iter().flatten() {
        match done {
            Completion::Callback(f) => f(Err(why.to_string())),
            Completion::Frame { tx, wire_id } => {
                let _ = tx.send(Frame::Error { id: wire_id, reason: why.to_string() });
            }
        }
    }
    eprintln!("batch of {} failed: {why}", batch.requests.len());
}
