//! The serving front-end: accepts single requests, batches them, executes
//! on the worker pool (native LUT-GEMM by default, calibrated schedule
//! replay with `backend calibrated`, PJRT with the `pjrt` feature — see
//! [`crate::engine`]), prices the CiM work with the tiler (coordinator-
//! side, or inside each calibrated worker), and fans per-request
//! responses back out.
//!
//! Concurrency model (std threads; no async runtime in this offline
//! image): every admitted request registers a [`Completion`] — blocking
//! callers ([`ServerHandle::submit`]) wrap a oneshot in a callback, the
//! TCP front-end ([`crate::net`]) registers its connection's reply queue
//! via [`ServerHandle::submit_with`]; a background flusher thread
//! enforces the batching deadline; a small **persistent completion
//! pool** receives worker replies and fans them out (a thread-per-batch
//! design measured ~25% slower at 4 workers — EXPERIMENTS.md §Perf).
//!
//! **Multi-tenant serving** (`serving.models`): one coordinator hosts
//! many model artifacts. A model **registry** maps ids to artifact
//! directories; compiled plans live in a byte-budgeted, single-flight
//! [`PlanCache`] shared by every submit path, so a model's plan compiles
//! once no matter how many shards, connections or workers touch it.
//! Requests name their model ([`ServerHandle::submit_model`]); each
//! batcher shard keeps an independent **lane per model**, so batches
//! form per model within a shard and never mix tenants. Hot swap:
//! [`ServerHandle::load_model`] registers a new tenant at runtime;
//! [`ServerHandle::retire_model`] flips the model's retiring flag (new
//! requests get a structured [`ModelUnavailable`]), drains its in-flight
//! requests, then drops its lanes, cache entry and per-worker executors
//! — no connection is dropped and every in-flight request resolves.
//!
//! **Sharded batching** (`batcher.shards`, default 1): requests dispatch
//! onto independent batcher lanes — each shard owns its own lane map
//! and waiter map, so connections landing on different shards never
//! contend on one lock. The lane is chosen by `batcher.affinity`:
//! `request` (default) round-robins on the request id, `connection`
//! pins every request from one connection to `conn % shards` (the TCP
//! front-end passes its connection id through
//! [`ServerHandle::submit_from`]), keeping that lane — and the worker
//! rotation it seeds — warm for the connection. Admission stays
//! globally correct through one shared atomic outstanding count, and
//! distinct shards seed the router at disjoint worker rotations.
//! Per-request numerics are batch-composition-independent (integer
//! accumulation is order-exact per row), so replies are bit-identical
//! for every shard count and either affinity (`tests/net_serving.rs`).
//!
//! **Zero-allocation hot path**: pixels, flat batch inputs, logits and
//! reply frames all live in pooled buffers ([`crate::util::pool`]),
//! worker jobs and replies travel over the allocation-free
//! [`crate::util::queue`], a plan-cache hit is one lock + one lookup +
//! one `Arc` clone, and the steady-state coordinator-side schedule cost
//! is memoized per (model, batch size) — after warmup a request performs
//! zero heap allocations from socket to reply
//! (`tests/hot_path_allocs.rs`; lifecycle diagram in the crate docs'
//! `## Serving hot path` section).
//!
//! Admission control bounds *total outstanding* requests (pending +
//! in-flight) at `batcher.queue_depth`; rejections carry a structured
//! [`Backpressure`] retry hint.

use super::admission::AdmissionGate;
use super::batcher::{Batch, Batcher};
use super::metrics::{Metrics, TenantLat};
use super::request::{InferenceRequest, InferenceResponse, RequestId};
use super::router::{InFlightGuard, Router};
use super::tiler::{ScheduleCost, Tiler, UnitCosts};
use super::worker::{BatchJob, ReplyTicket, ReplyTo, WorkerPool, WorkerReply};
use crate::config::{BackendKind, BatcherConfig, Config, ShardAffinity};
use crate::engine::{BackendSpec, ModelEntry, PlanCache};
use crate::net::protocol::{Frame, ModelId, WireCost};
use crate::nn::{GemmOptions, QuantMlp};
use crate::runtime::ArtifactStore;
use crate::util::trace::{FlightRecorder, Stage};
use crate::util::{oneshot, queue, PooledVec};
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::collections::HashMap;
// Deliberately std (not the loom shim): the coordinator's background
// threads hold `Weak` references, which loom's `Arc` lacks, and these
// atomics are id counters and stop flags with no cross-thread publication
// role. The model-checked admission bound lives in [`AdmissionGate`].
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// 429-style admission rejection with a structured retry hint.
///
/// [`ServerHandle::submit`]/[`ServerHandle::submit_with`] return this
/// (wrapped in `anyhow::Error`; recover it with
/// `err.downcast_ref::<Backpressure>()`) instead of an opaque "queue
/// full" failure, and the wire front-end maps it onto the protocol's
/// `Rejected` frame. The hint comes from
/// [`Batcher::retry_after_us`](super::Batcher::retry_after_us): queue
/// depth, `max_batch` and the flush deadline — an estimate, not a
/// reservation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backpressure {
    /// Suggested client backoff before retrying (µs, always ≥ 1).
    pub retry_after_us: u64,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server at capacity — retry in {} us", self.retry_after_us)
    }
}

impl std::error::Error for Backpressure {}

/// Structured "this model cannot take requests" rejection: the id is
/// unknown, or the model is mid-[`ServerHandle::retire_model`]. The wire
/// front-end maps `retiring` onto a retryable `Rejected` frame (the
/// model may return after a swap) and an unknown id onto a terminal
/// `Error`. Recover with `err.downcast_ref::<ModelUnavailable>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelUnavailable {
    pub model: ModelId,
    /// True when the model is draining for retirement (transient);
    /// false when the id is simply not registered.
    pub retiring: bool,
}

impl std::fmt::Display for ModelUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.retiring {
            write!(f, "model {} is retiring", self.model)
        } else {
            write!(f, "model {} is not being served", self.model)
        }
    }
}

impl std::error::Error for ModelUnavailable {}

/// Per-model serving counters ([`ServerHandle::model_stats`]): the
/// per-tenant goodput and weight-stationarity numbers the loadgen
/// reports per model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModelStats {
    /// Requests served to completion.
    pub requests: u64,
    /// Currently outstanding (admitted, not yet resolved).
    pub inflight: u64,
    /// Simulated LUT programming events attributed to this model.
    pub programs: u64,
    /// Simulated weight-stationary hits attributed to this model.
    pub stationary_hits: u64,
}

impl ModelStats {
    /// Fraction of this model's scheduled weight placements that hit an
    /// already-programmed unit (0.0 when nothing has been priced).
    pub fn stationary_hit_rate(&self) -> f64 {
        let total = self.programs + self.stationary_hits;
        if total == 0 {
            0.0
        } else {
            self.stationary_hits as f64 / total as f64
        }
    }
}

/// How a submission receives its reply — resolved exactly once, from a
/// coordinator thread.
///
/// `Callback` boxes an arbitrary closure (the blocking
/// [`ServerHandle::submit`] wraps a oneshot; tests and examples pass
/// their own) — flexible, but the box allocates. The TCP front-end
/// instead registers `Frame { tx, wire_id }`: the coordinator builds the
/// `Response`/`Error` frame itself, with pooled logits, and pushes it
/// straight onto the connection's writer queue — the allocation-free
/// reply lane a network connection keeps thousands of requests in
/// flight on without a blocked thread each.
///
/// The `Frame` variant is a deliberate coordinator → [`crate::net`]
/// coupling (within one crate): building the frame here avoids an
/// intermediate response struct plus a second copy on the writer
/// thread. The wire protocol module itself stays coordinator-free.
pub enum Completion {
    /// Invoke a closure with the response or the batch-failure reason.
    Callback(Box<dyn FnOnce(std::result::Result<InferenceResponse, String>) + Send>),
    /// Push the reply frame onto a connection writer queue, echoing the
    /// client's wire id.
    Frame { tx: queue::Sender<Frame>, wire_id: u64 },
}

impl Completion {
    /// Wrap a closure (the allocating, fully general form).
    pub fn callback(
        f: impl FnOnce(std::result::Result<InferenceResponse, String>) + Send + 'static,
    ) -> Self {
        Completion::Callback(Box::new(f))
    }
}

/// One registered tenant: where its artifacts live plus its lifecycle
/// and per-tenant counters. All atomics are Relaxed: `retiring` and
/// `inflight` get their ordering from the registry `RwLock` (see
/// [`ServerHandle::retire_model`]); the stats are monitoring counters.
struct ModelSlot {
    dir: String,
    retiring: AtomicBool,
    /// Admitted-but-unresolved requests for this model. Incremented
    /// under the registry read lock *before* the retiring check;
    /// decremented when the request resolves (reply, failure, or
    /// admission rollback) — the count [`ServerHandle::retire_model`]
    /// drains to zero.
    inflight: AtomicU64,
    requests: AtomicU64,
    programs: AtomicU64,
    stationary_hits: AtomicU64,
}

impl ModelSlot {
    fn new(dir: String) -> Self {
        ModelSlot {
            dir,
            retiring: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            programs: AtomicU64::new(0),
            stationary_hits: AtomicU64::new(0),
        }
    }

    fn stats(&self) -> ModelStats {
        ModelStats {
            requests: self.requests.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            programs: self.programs.load(Ordering::Relaxed),
            stationary_hits: self.stationary_hits.load(Ordering::Relaxed),
        }
    }
}

/// Drops a model's in-flight reservation unless disarmed — keeps
/// `submit_inner`'s error returns from leaking the count the retire
/// drain waits on. Disarmed once the request is owned by the batch
/// lifecycle (complete/fail paths decrement per request).
struct InflightToken {
    slot: Option<Arc<ModelSlot>>,
}

impl InflightToken {
    fn disarm(&mut self) {
        self.slot = None;
    }
}

impl Drop for InflightToken {
    fn drop(&mut self) {
        if let Some(slot) = self.slot.take() {
            slot.inflight.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// One model's batching lane within a shard: its batcher plus the
/// shared compiled entry and registry slot every batch dispatched from
/// this lane rides with.
struct Lane {
    batcher: Batcher,
    entry: Arc<ModelEntry>,
    slot: Arc<ModelSlot>,
}

/// One independent batcher shard (see the module docs on sharding).
struct Shard {
    /// Per-model batching lanes: batches form per model within a shard
    /// and never mix tenants. Lanes appear on a model's first request
    /// through this shard and leave at retire.
    lanes: Mutex<HashMap<ModelId, Lane>>,
    /// Completions for requests whose `id % shards` routes here. Insert
    /// and removal stay on this shard's lock; the global outstanding
    /// count lives in [`Shared::admission`].
    waiters: Mutex<HashMap<RequestId, Completion>>,
    /// This shard's worker-rotation turn counter (`shard + turn·shards`
    /// seeds the router so distinct shards prefer disjoint workers).
    rr: AtomicUsize,
    /// This shard's dispatched batches awaiting their worker reply,
    /// keyed by batch id (whose low bits encode the shard, so the
    /// completion pool routes a reply back here without a global map).
    pending: Mutex<HashMap<u64, BatchCtx>>,
    /// This shard's producer handle on the completion queue; `None`
    /// once shutdown has begun (new dispatches then fail their batch
    /// inline). Per shard so dispatch touches no cross-shard lock.
    completions: Mutex<Option<queue::Sender<WorkerReply>>>,
}

/// A dispatched batch's context, parked in its shard's pending map
/// until the worker reply arrives (keyed by batch id).
struct BatchCtx {
    batch: Batch,
    guard: InFlightGuard,
    /// Coordinator-side pricing (None when the calibrated backend prices
    /// the batch itself; the reply's cost then takes over).
    sched_cost: Option<ScheduleCost>,
    /// The tenant the batch belongs to (per-model stats + drain count).
    slot: Arc<ModelSlot>,
    /// The tenant's latency/queue histograms, resolved once per batch
    /// at dispatch (a lock + `Arc` clone; see [`Metrics::tenant`]).
    tenant: Arc<TenantLat>,
    /// When [`dispatch_batch`] started forming the batch — the end of
    /// every member request's queue-wait span.
    formed_at: Instant,
    /// When the batch was handed to a worker (batch-form span end).
    dispatched_at: Instant,
}

/// The coordinator-side pricing tiler plus which model last ran on its
/// fabric (multi-tenant schedules interleave on the one pricing fabric;
/// see [`coordinator_cost`]).
struct PricingState {
    tiler: Tiler,
    last: Option<ModelId>,
}

struct Shared {
    shards: Vec<Shard>,
    /// Admission bound: total outstanding requests (pending in any
    /// shard's batcher + dispatched but not yet completed) may not
    /// exceed `batcher.queue_depth`. One shared gate keeps the bound
    /// globally correct across shards without a global lock; its
    /// never-exceeds / never-leaks invariant is loom-model-checked
    /// ([`super::admission`]).
    admission: AdmissionGate,
    /// Lowered batch size, echoed in the wire protocol's `Info` frame.
    max_batch: usize,
    backend: BackendKind,
    /// Coordinator-side CiM pricing for backends that don't model cost
    /// themselves; `None` for `backend calibrated`, where each worker's
    /// own fabric replay prices the batch and the cost arrives on the
    /// reply.
    pricing: Option<Mutex<PricingState>>,
    /// Steady-state schedule memo per (model, batch size) — see
    /// [`coordinator_cost`] for what "steady state" means with tenants
    /// interleaving on one pricing fabric.
    sched_cache: Mutex<HashMap<(ModelId, usize), ScheduleCost>>,
    router: Router,
    metrics: Arc<Metrics>,
    /// Per-process span flight recorder ([`crate::util::trace`]): stage
    /// spans land here under each traced request's id, and the wire
    /// front-end serves `DumpTrace` from it. Pre-allocated at startup,
    /// so recording stays off the allocator.
    recorder: Arc<FlightRecorder>,
    /// Model id → registered tenant. Read-locked on every submit (the
    /// hot path takes no write lock); write-locked only by
    /// load/retire admin operations.
    registry: RwLock<HashMap<ModelId, Arc<ModelSlot>>>,
    /// Byte-budgeted single-flight cache of compiled plans, shared by
    /// every submit path (see [`crate::engine::plan_cache`]).
    plan_cache: Arc<PlanCache>,
    /// Lane construction recipe (new model lanes appear at runtime).
    batcher_cfg: BatcherConfig,
    /// The `gemm.*` knob set, forwarded into every lazy plan compile.
    gemm: GemmOptions,
    /// Shard-selection rule (`batcher.affinity`; see the module docs).
    affinity: ShardAffinity,
    in_dim: usize,
    out_dim: usize,
    next_id: AtomicU64,
    stopping: AtomicBool,
    /// Pad executed batches to `padded_to` (PJRT's lowered shape is
    /// fixed); the native backend runs exactly the real rows.
    pad_batches: bool,
    /// Batch sequence counter; a batch's id is
    /// `seq · shards + shard_idx`, so `id % shards` recovers the shard.
    batch_seq: AtomicU64,
}

impl Shared {
    fn shard_index(&self, id: RequestId) -> usize {
        (id % self.shards.len() as u64) as usize
    }

    /// The lane a fresh request lands on: request-id round-robin, or —
    /// under connection affinity, when the submitter identified its
    /// connection — pinned to `conn % shards`.
    fn shard_for(&self, id: RequestId, conn: Option<u64>) -> usize {
        match (self.affinity, conn) {
            (ShardAffinity::Connection, Some(conn)) => (conn % self.shards.len() as u64) as usize,
            _ => self.shard_index(id),
        }
    }

    /// Load + quantize + plan-compile `model` from `dir`, validating its
    /// manifest against the serving geometry (the cold half of
    /// [`PlanCache::get_or_compile`]).
    fn compile_model(&self, model: ModelId, dir: &str) -> Result<ModelEntry> {
        let store = ArtifactStore::new(dir);
        let meta =
            store.manifest().with_context(|| format!("model {model}: artifacts at {dir}"))?;
        ensure!(
            meta.batch == self.max_batch,
            "model {model}: lowered batch {} != serving max_batch {}",
            meta.batch,
            self.max_batch
        );
        let (first, last) = (*meta.dims.first().unwrap(), *meta.dims.last().unwrap());
        ensure!(
            first == self.in_dim && last == self.out_dim,
            "model {model}: dims {first}→{last} != serving {}→{}",
            self.in_dim,
            self.out_dim
        );
        let mlp = store.load_mlp().with_context(|| format!("model {model}: loading weights"))?;
        Ok(ModelEntry::compile(model, mlp, self.gemm))
    }
}

/// The serving coordinator. Construct with [`CoordinatorServer::start`],
/// submit through the cloned [`ServerHandle`]s.
pub struct CoordinatorServer {
    shared: Arc<Shared>,
    flusher: Option<std::thread::JoinHandle<()>>,
    completion_pool: Vec<std::thread::JoinHandle<()>>,
}

/// Cheap cloneable submission handle. `submit` blocks the calling thread
/// until the response arrives (drive it from multiple client threads).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl CoordinatorServer {
    /// Start the coordinator: load artifacts, spawn the worker pool and the
    /// deadline flusher. Requires `make artifacts` to have run.
    pub fn start(cfg: Config) -> Result<(Self, ServerHandle)> {
        cfg.validate()?;
        ensure!(
            cfg.backend != BackendKind::Pjrt || cfg.serving.models.is_empty(),
            "multi-tenant serving (serving.models) needs backend native or calibrated — \
             the PJRT executable serves a single model"
        );
        let store = ArtifactStore::new(&cfg.artifacts_dir);
        let meta = store.manifest()?;
        ensure!(
            meta.batch == cfg.batcher.max_batch,
            "config max_batch {} != lowered batch {} — artifacts and config must agree",
            cfg.batcher.max_batch,
            meta.batch
        );
        let mlp = store.load_mlp().context("loading weights")?;
        let lib = crate::cells::tsmc65_library();
        // Coordinator-side pricing tiler for backends that don't model
        // cost themselves. `calibrated` moves pricing into the workers
        // (one weight-stationary fabric per worker per model), so the
        // coordinator keeps none.
        let pricing = match cfg.backend {
            BackendKind::Calibrated => None,
            _ => Some(Mutex::new(PricingState {
                tiler: Tiler::from_config(&cfg, &lib),
                last: None,
            })),
        };
        // Backend choice: native runs the batched LUT-GEMM in-process
        // (no HLO artifacts touched); calibrated wraps it with per-worker
        // schedule replay (the gate-level calibration is measured once
        // here and *carried in the spec* — never per worker thread);
        // pjrt compiles the AOT executable.
        let spec = match cfg.backend {
            BackendKind::Native => BackendSpec::Native {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                gemm: cfg.gemm.options(),
            },
            BackendKind::Calibrated => BackendSpec::Calibrated {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                costs: UnitCosts::measure_cached(Tiler::pricing_kind(cfg.multiplier), &lib),
                banks: cfg.banks.count,
                units_per_bank: cfg.banks.units_per_bank,
                time_scale: cfg.timing.time_scale,
                gemm: cfg.gemm.options(),
            },
            BackendKind::Pjrt => BackendSpec::Pjrt { hlo: store.mlp_hlo(cfg.multiplier) },
        };
        let in_dim = *meta.dims.first().unwrap();
        let out_dim = *meta.dims.last().unwrap();
        // Model registry: the default model plus every configured
        // tenant. Tenant manifests are validated now (fail fast on a
        // bad config); their plans compile lazily, on first request,
        // through the plan cache.
        let mut registry = HashMap::new();
        registry.insert(ModelId::DEFAULT, Arc::new(ModelSlot::new(cfg.artifacts_dir.clone())));
        for (id, dir) in &cfg.serving.models {
            let model = ModelId::new(id)?;
            ensure!(!model.is_default(), "serving.models ids must be non-empty");
            let m = ArtifactStore::new(dir)
                .manifest()
                .with_context(|| format!("model {id}: artifacts at {dir}"))?;
            ensure!(
                m.batch == meta.batch
                    && m.dims.first() == meta.dims.first()
                    && m.dims.last() == meta.dims.last(),
                "model {id}: geometry must match the default model \
                 (got batch {} dims {:?}, want batch {} dims {}→{})",
                m.batch,
                m.dims,
                meta.batch,
                in_dim,
                out_dim
            );
            let slot = Arc::new(ModelSlot::new(dir.clone()));
            ensure!(registry.insert(model, slot).is_none(), "duplicate model id {id}");
        }
        let metrics = Arc::new(Metrics::new());
        let recorder =
            FlightRecorder::new("server", cfg.trace.ring_capacity, cfg.trace.sample_every);
        let plan_cache =
            Arc::new(PlanCache::new(cfg.plan_cache.max_bytes, metrics.plan_cache.clone()));
        // Compile the default model once, through the cache, and seed
        // every worker with the shared plan — N workers no longer
        // compile N private copies. (PJRT owns its executable; its
        // workers build from the spec.)
        let default_entry = plan_cache.get_or_compile(ModelId::DEFAULT, || {
            Ok(ModelEntry::compile(ModelId::DEFAULT, mlp, cfg.gemm.options()))
        })?;
        let seed = match cfg.backend {
            BackendKind::Pjrt => None,
            _ => Some(Arc::clone(&default_entry)),
        };
        let pool = WorkerPool::spawn_seeded(cfg.workers.count, spec, seed)?;
        let (ctx, crx) = queue::channel::<WorkerReply>();
        let shards = (0..cfg.batcher.shards)
            .map(|_| Shard {
                lanes: Mutex::new(HashMap::new()),
                waiters: Mutex::new(HashMap::new()),
                rr: AtomicUsize::new(0),
                pending: Mutex::new(HashMap::new()),
                completions: Mutex::new(Some(ctx.clone())),
            })
            .collect();
        drop(ctx);
        let shared = Arc::new(Shared {
            shards,
            admission: AdmissionGate::new(cfg.batcher.queue_depth),
            max_batch: cfg.batcher.max_batch,
            backend: cfg.backend,
            pricing,
            sched_cache: Mutex::new(HashMap::new()),
            router: Router::new(pool),
            metrics,
            recorder,
            registry: RwLock::new(registry),
            plan_cache,
            batcher_cfg: cfg.batcher.clone(),
            gemm: cfg.gemm.options(),
            affinity: cfg.batcher.affinity,
            in_dim,
            out_dim,
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            pad_batches: cfg.backend == BackendKind::Pjrt,
            batch_seq: AtomicU64::new(0),
        });
        // Persistent completion pool: one thread per worker keeps the
        // pipeline full without per-batch thread spawns. Each thread
        // owns a reusable fan-out scratch, so completing a batch
        // allocates nothing.
        let mut completion_pool = Vec::new();
        for i in 0..cfg.workers.count {
            let crx = crx.clone();
            let weak = Arc::downgrade(&shared);
            let max_batch = cfg.batcher.max_batch;
            completion_pool.push(
                std::thread::Builder::new()
                    .name(format!("luna-completion-{i}"))
                    .spawn(move || {
                        // sized up front: fan-out never allocates, even
                        // on a thread that serves its first batch late
                        let mut scratch: Vec<Option<Completion>> =
                            Vec::with_capacity(max_batch); // lint: allow(alloc): startup scratch
                        while let Some(reply) = crx.recv() {
                            let Some(shared) = weak.upgrade() else { return };
                            // the batch id's low bits name the shard —
                            // the *dispatching* lane, which under
                            // connection affinity is not derivable from
                            // request ids
                            let shard_idx = shared.shard_index(reply.batch_id);
                            let ctx = {
                                let shard = &shared.shards[shard_idx];
                                shard.pending.lock().unwrap().remove(&reply.batch_id)
                            };
                            if let Some(ctx) = ctx {
                                complete_batch(&shared, shard_idx, ctx, reply, &mut scratch);
                            }
                        }
                    })
                    .expect("spawn completion thread"),
            );
        }
        drop(crx);
        let flusher = {
            let weak = Arc::downgrade(&shared);
            let period = Duration::from_micros((cfg.batcher.max_wait_us.max(50)) / 2);
            std::thread::Builder::new()
                .name("luna-flusher".into())
                .spawn(move || {
                    // reused across ticks; reaches lane-count capacity
                    // once and then never grows again
                    let mut due: Vec<(ModelId, Arc<ModelEntry>, Arc<ModelSlot>, Batch)> =
                        Vec::new();
                    loop {
                        std::thread::sleep(period);
                        let Some(shared) = weak.upgrade() else { return };
                        if shared.stopping.load(Ordering::Relaxed) {
                            return;
                        }
                        for idx in 0..shared.shards.len() {
                            due.clear();
                            {
                                let mut lanes = shared.shards[idx].lanes.lock().unwrap();
                                let now = std::time::Instant::now();
                                for (model, lane) in lanes.iter_mut() {
                                    if let Some(batch) = lane.batcher.flush_due(now) {
                                        let entry = Arc::clone(&lane.entry);
                                        let slot = Arc::clone(&lane.slot);
                                        due.push((*model, entry, slot, batch));
                                    }
                                }
                            }
                            // dispatch after the lane lock is released
                            for (model, entry, slot, batch) in due.drain(..) {
                                dispatch_batch(&shared, idx, model, &entry, &slot, batch);
                            }
                        }
                    }
                })
                .expect("spawn flusher")
        };
        let handle = ServerHandle { shared: shared.clone() };
        Ok((CoordinatorServer { shared, flusher: Some(flusher), completion_pool }, handle))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Flush pending requests, drain the completion pool, stop the flusher.
    pub fn shutdown(mut self) {
        self.shared.stopping.store(true, Ordering::Relaxed);
        for idx in 0..self.shared.shards.len() {
            let flushed: Vec<(ModelId, Arc<ModelEntry>, Arc<ModelSlot>, Vec<Batch>)> = {
                let mut lanes = self.shared.shards[idx].lanes.lock().unwrap();
                lanes
                    .iter_mut()
                    .map(|(m, lane)| {
                        let entry = Arc::clone(&lane.entry);
                        let slot = Arc::clone(&lane.slot);
                        (*m, entry, slot, lane.batcher.flush_all())
                    })
                    .collect()
            };
            for (model, entry, slot, batches) in flushed {
                for b in batches {
                    dispatch_batch(&self.shared, idx, model, &entry, &slot, b);
                }
            }
        }
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Close every shard's completion-queue sender: the only
        // remaining producers are the reply tickets riding in-flight
        // jobs, so the pool drains every dispatched batch, observes the
        // disconnect, and exits.
        for shard in &self.shared.shards {
            *shard.completions.lock().unwrap() = None;
        }
        let pool = std::mem::take(&mut self.completion_pool);
        for h in pool {
            let _ = h.join();
        }
    }
}

impl ServerHandle {
    /// Submit one image to the default model and block until the batched
    /// execution completes. Admission failures surface as
    /// [`Backpressure`] (downcastable from the returned error) carrying
    /// a `retry_after_us` hint.
    pub fn submit(&self, pixels: Vec<f32>) -> Result<InferenceResponse> {
        self.submit_model(ModelId::DEFAULT, pixels)
    }

    /// [`submit`](Self::submit) against a named model. Unknown or
    /// retiring models fail with a downcastable [`ModelUnavailable`].
    pub fn submit_model(&self, model: ModelId, pixels: Vec<f32>) -> Result<InferenceResponse> {
        let (tx, rx) = oneshot::channel();
        self.submit_inner(
            None,
            model,
            pixels.into(),
            0,
            Completion::callback(move |result| {
                let _ = tx.send(result);
            }),
        )?;
        match rx.recv() {
            Some(Ok(resp)) => Ok(resp),
            Some(Err(why)) => Err(anyhow!("request failed: {why}")),
            None => Err(anyhow!("request dropped")),
        }
    }

    /// Admission-checked asynchronous submission to the default model:
    /// on success, `done` is resolved exactly once — with the response,
    /// or with the failure reason if the batch dies — from a
    /// coordinator thread. On rejection `done` is dropped unused (never
    /// resolved) and a [`Backpressure`] error comes back, so the caller
    /// replies 429 itself.
    ///
    /// Admission bounds total outstanding requests (pending +
    /// in-flight) by `batcher.queue_depth` — the genuine overload
    /// guard, enforced by one shared atomic so it stays globally exact
    /// across batcher shards. Pixels arrive in a pooled buffer (plain
    /// `Vec<f32>` converts in), keeping the wire path allocation-free.
    pub fn submit_with(&self, pixels: impl Into<PooledVec<f32>>, done: Completion) -> Result<()> {
        self.submit_inner(None, ModelId::DEFAULT, pixels.into(), 0, done)
    }

    /// [`submit_with`](Self::submit_with), identifying the submitting
    /// connection: under `batcher.affinity connection` every request
    /// carrying the same `conn` id lands on the same batcher shard
    /// (lane/cache affinity); under the default request affinity the id
    /// is ignored. The TCP front-end calls this with its per-connection
    /// counter.
    pub fn submit_from(
        &self,
        conn: u64,
        pixels: impl Into<PooledVec<f32>>,
        done: Completion,
    ) -> Result<()> {
        self.submit_inner(Some(conn), ModelId::DEFAULT, pixels.into(), 0, done)
    }

    /// [`submit_from`](Self::submit_from) against a named model — the
    /// multi-tenant wire front-end's entry point.
    pub fn submit_model_from(
        &self,
        conn: u64,
        model: ModelId,
        pixels: impl Into<PooledVec<f32>>,
        done: Completion,
    ) -> Result<()> {
        self.submit_inner(Some(conn), model, pixels.into(), 0, done)
    }

    /// [`submit_model_from`](Self::submit_model_from) with an
    /// ingress-assigned trace id. A nonzero `trace` (carried in on the
    /// wire) is honored as-is so a routed request keeps one id across
    /// processes; `0` lets this server's recorder sample locally.
    pub fn submit_traced(
        &self,
        conn: u64,
        model: ModelId,
        pixels: impl Into<PooledVec<f32>>,
        trace: u64,
        done: Completion,
    ) -> Result<()> {
        self.submit_inner(Some(conn), model, pixels.into(), trace, done)
    }

    fn submit_inner(
        &self,
        conn: Option<u64>,
        model: ModelId,
        pixels: PooledVec<f32>,
        trace: u64,
        done: Completion,
    ) -> Result<()> {
        let t0 = Instant::now();
        // Sample locally only when no id came in on the wire: a nonzero
        // wire trace is never reassigned, so a routed request keeps one
        // id end to end and its spans stitch into a single timeline.
        let trace = if trace == 0 { self.shared.recorder.sample() } else { trace };
        ensure!(pixels.len() == self.shared.in_dim, "expected {} pixels", self.shared.in_dim);
        let slot = {
            let registry = self.shared.registry.read().unwrap();
            let Some(slot) = registry.get(&model) else {
                return Err(ModelUnavailable { model, retiring: false }.into());
            };
            // ordering: Relaxed — both under the registry *read* lock;
            // retire_model flips `retiring` under the write lock and
            // only then reads `inflight`, so either this request sees
            // the flag, or the drain sees this increment. The increment
            // must precede the check for that pairing to hold.
            slot.inflight.fetch_add(1, Ordering::Relaxed);
            if slot.retiring.load(Ordering::Relaxed) {
                slot.inflight.fetch_sub(1, Ordering::Relaxed);
                return Err(ModelUnavailable { model, retiring: true }.into());
            }
            Arc::clone(slot)
        };
        let mut token = InflightToken { slot: Some(Arc::clone(&slot)) };
        // Resolve the compiled plan BEFORE admission: a compile stall
        // (single-flight, measured) must not hold an admission slot,
        // and a failed compile must not count against the queue depth.
        let entry = self
            .shared
            .plan_cache
            .get_or_compile(model, || self.shared.compile_model(model, &slot.dir))?;
        // ordering: Relaxed — pure id allocation, no publication.
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let shard_idx = self.shared.shard_for(id, conn);
        let shard = &self.shared.shards[shard_idx];
        if let Err(observed) = self.shared.admission.try_admit() {
            let hint = {
                let mut lanes = shard.lanes.lock().unwrap();
                let lane = lane_for(&mut lanes, model, &entry, &slot, &self.shared.batcher_cfg);
                lane.batcher.retry_after_us(std::time::Instant::now(), observed)
            };
            self.shared.metrics.record_rejection(hint);
            return Err(Backpressure { retry_after_us: hint }.into());
        }
        shard.waiters.lock().unwrap().insert(id, done);
        let maybe_batch = {
            let mut lanes = shard.lanes.lock().unwrap();
            let lane = lane_for(&mut lanes, model, &entry, &slot, &self.shared.batcher_cfg);
            let mut request = InferenceRequest::new(id, pixels);
            request.trace = trace;
            match lane.batcher.push(request) {
                Ok(b) => b,
                // Unreachable by invariant (every lane's pending queue
                // is a subset of the outstanding set the gate above
                // caps); kept as defense in depth since the batcher is
                // also driven standalone, where `push` genuinely
                // backpressures.
                Err(_rejected) => {
                    let now = std::time::Instant::now();
                    let hint = lane.batcher.retry_after_us(now, lane.batcher.pending());
                    drop(lanes);
                    shard.waiters.lock().unwrap().remove(&id);
                    self.shared.admission.release(1);
                    self.shared.metrics.record_rejection(hint);
                    return Err(Backpressure { retry_after_us: hint }.into());
                }
            }
        };
        // the request is now owned by the batch lifecycle; complete/
        // fail paths decrement the per-model in-flight count
        token.disarm();
        self.shared.metrics.record_admission();
        let admitted = Instant::now();
        let admit_us = admitted.duration_since(t0).as_micros() as u64;
        self.shared.metrics.record_stage_us(Stage::Admission, admit_us);
        self.shared.recorder.record(trace, Stage::Admission, t0, admitted);
        if let Some(batch) = maybe_batch {
            dispatch_batch(&self.shared, shard_idx, model, &entry, &slot, batch);
        }
        Ok(())
    }

    /// Register a new tenant at runtime (hot load). Validates the
    /// artifacts' geometry now; the plan compiles lazily on the model's
    /// first request. Fails if the id is already serving — hot *swap*
    /// is [`retire_model`](Self::retire_model) then `load_model`.
    pub fn load_model(&self, model: ModelId, dir: &str) -> Result<()> {
        ensure!(!model.is_default(), "the default model is always loaded");
        let store = ArtifactStore::new(dir);
        let meta =
            store.manifest().with_context(|| format!("model {model}: artifacts at {dir}"))?;
        let (first, last) = (*meta.dims.first().unwrap(), *meta.dims.last().unwrap());
        ensure!(
            meta.batch == self.shared.max_batch
                && first == self.shared.in_dim
                && last == self.shared.out_dim,
            "model {model}: geometry (batch {} dims {first}→{last}) must match serving \
             (batch {} dims {}→{})",
            meta.batch,
            self.shared.max_batch,
            self.shared.in_dim,
            self.shared.out_dim
        );
        let mut registry = self.shared.registry.write().unwrap();
        ensure!(
            !registry.contains_key(&model),
            "model {model} is already serving — retire it first to swap"
        );
        registry.insert(model, Arc::new(ModelSlot::new(dir.to_string())));
        Ok(())
    }

    /// Retire a tenant (hot unload): flag it retiring (new requests are
    /// rejected with a structured [`ModelUnavailable`]), drain every
    /// in-flight request, then drop its lanes, cached plan and
    /// per-worker executors. Connections are never dropped; this call
    /// returns once the model is fully gone.
    pub fn retire_model(&self, model: ModelId) -> Result<()> {
        ensure!(!model.is_default(), "cannot retire the default model");
        let slot = {
            let registry = self.shared.registry.write().unwrap();
            let Some(slot) = registry.get(&model) else {
                return Err(ModelUnavailable { model, retiring: false }.into());
            };
            // ordering: Relaxed — the registry write lock orders this
            // store against every submit's read-locked admit sequence;
            // after we release the lock, no submit can pass the
            // retiring check, so `inflight` only counts down.
            slot.retiring.store(true, Ordering::Relaxed);
            Arc::clone(slot)
        };
        while slot.inflight.load(Ordering::Relaxed) > 0 {
            if self.shared.stopping.load(Ordering::Relaxed) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.registry.write().unwrap().remove(&model);
        for shard in &self.shared.shards {
            shard.lanes.lock().unwrap().remove(&model);
        }
        self.shared.plan_cache.retire(model);
        self.shared.router.retire(model);
        self.shared.sched_cache.lock().unwrap().retain(|(m, _), _| *m != model);
        Ok(())
    }

    /// Sorted ids of the non-default models currently registered (the
    /// wire `Info` frame's model list; the default model is implicit on
    /// every server).
    pub fn models(&self) -> Vec<String> {
        let registry = self.shared.registry.read().unwrap();
        let mut out: Vec<String> =
            registry.keys().filter(|m| !m.is_default()).map(|m| m.as_str().to_string()).collect();
        out.sort();
        out
    }

    /// Per-tenant serving counters, `None` for an unregistered id. The
    /// default model reports under [`ModelId::DEFAULT`].
    pub fn model_stats(&self, model: ModelId) -> Option<ModelStats> {
        self.shared.registry.read().unwrap().get(&model).map(|s| s.stats())
    }

    /// The shared compiled-plan cache (tests and tools; serving goes
    /// through [`submit_model`](Self::submit_model)).
    pub fn plan_cache(&self) -> Arc<PlanCache> {
        Arc::clone(&self.shared.plan_cache)
    }

    /// Input dimension the model expects (pixels per request).
    pub fn input_dim(&self) -> usize {
        self.shared.in_dim
    }

    /// Output dimension (logits per response).
    pub fn output_dim(&self) -> usize {
        self.shared.out_dim
    }

    /// The lowered batch size requests are batched up to.
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// Number of independent batcher shards.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Stable identifier of the execution backend serving this handle.
    pub fn backend_slug(&self) -> &'static str {
        self.shared.backend.slug()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// This process's span flight recorder: the wire front-end records
    /// ingress spans into it and serves `DumpTrace` dumps from it.
    pub fn recorder(&self) -> Arc<FlightRecorder> {
        Arc::clone(&self.shared.recorder)
    }
}

/// This shard's lane for `model`, created on first touch (cold path;
/// the warm path is a plain map hit).
fn lane_for<'a>(
    lanes: &'a mut HashMap<ModelId, Lane>,
    model: ModelId,
    entry: &Arc<ModelEntry>,
    slot: &Arc<ModelSlot>,
    cfg: &BatcherConfig,
) -> &'a mut Lane {
    lanes.entry(model).or_insert_with(|| Lane {
        batcher: Batcher::from_config(cfg),
        entry: Arc::clone(entry),
        slot: Arc::clone(slot),
    })
}

/// Coordinator-side CiM pricing with the steady-state memo.
///
/// Multi-tenant schedules interleave on the one pricing fabric, so a
/// walk's programming cost depends on which model ran before it. A cost
/// is memoized for (model, n) only when the fabric's previous schedule
/// was the *same* model — the model-after-itself steady state, i.e. the
/// per-tenant cost as if the tenant owned the fabric. Cold walks (first
/// ever, or first after another tenant) report their genuine
/// programming cost and are never cached. Single-tenant behaviour is
/// identical to the classic warm-memo: first walk cold and uncached,
/// every later one serves from the memo.
fn coordinator_cost(
    shared: &Shared,
    pricing: &Mutex<PricingState>,
    mlp: &QuantMlp,
    model: ModelId,
    n: usize,
) -> ScheduleCost {
    if let Some(c) = shared.sched_cache.lock().unwrap().get(&(model, n)) {
        return *c;
    }
    let (was_warm, cost) = {
        let mut p = pricing.lock().unwrap();
        let was_warm = p.last == Some(model);
        p.last = Some(model);
        (was_warm, p.tiler.schedule_cost(mlp, n))
    };
    if was_warm {
        shared.sched_cache.lock().unwrap().insert((model, n), cost);
    }
    cost
}

/// Price the batch on the CiM fabric (unless the backend prices it
/// itself), park its context under a batch id, and hand the flattened
/// inputs to a worker; the completion pool picks the reply up by id.
fn dispatch_batch(
    shared: &Arc<Shared>,
    shard_idx: usize,
    model: ModelId,
    entry: &Arc<ModelEntry>,
    slot: &Arc<ModelSlot>,
    batch: Batch,
) {
    let n = batch.requests.len();
    if n == 0 {
        return;
    }
    let formed_at = Instant::now();
    // CiM cost model: schedule this batch on the coordinator's fabric —
    // skipped for `backend calibrated`, whose workers replay the schedule
    // on their own weight-stationary fabrics and return the cost.
    let sched_cost =
        shared.pricing.as_ref().map(|p| coordinator_cost(shared, p, &entry.mlp, model, n));

    // PJRT's lowered executable has a fixed batch dimension; the native
    // GEMM runs exactly the real rows (no MACs spent on padding, and no
    // zero fill — flatten_into pads only the PJRT tail).
    let exec_rows = if shared.pad_batches { batch.padded_to } else { n };
    let mut inputs = PooledVec::with_capacity(exec_rows * shared.in_dim);
    batch.flatten_into(shared.in_dim, exec_rows, &mut inputs);

    let shard = &shared.shards[shard_idx];
    let ctx_tx = { shard.completions.lock().unwrap().clone() };
    let Some(ctx_tx) = ctx_tx else {
        fail_batch(shared, shard_idx, &batch, slot, "server is shutting down");
        return;
    };
    // Reserve the worker before parking the context so the reply can
    // never race its own bookkeeping; distinct shards seed the rotation
    // at disjoint workers.
    let turn = shard.rr.fetch_add(1, Ordering::Relaxed);
    let rot = shard_idx + turn.wrapping_mul(shared.shards.len());
    let (worker, guard) = shared.router.begin(rot);
    // low bits encode the shard so the completion pool can route the
    // reply back to this shard's pending map
    let seq = shared.batch_seq.fetch_add(1, Ordering::Relaxed);
    let batch_id = seq * shared.shards.len() as u64 + shard_idx as u64;
    let tenant = shared.metrics.tenant(model);
    let ctx = BatchCtx {
        batch,
        guard,
        sched_cost,
        slot: Arc::clone(slot),
        tenant,
        formed_at,
        dispatched_at: Instant::now(),
    };
    shard.pending.lock().unwrap().insert(batch_id, ctx);
    let job = BatchJob {
        inputs,
        batch: exec_rows,
        dim: shared.in_dim,
        model,
        entry: Some(Arc::clone(entry)),
        reply: ReplyTo::Queue(ReplyTicket::new(ctx_tx, batch_id)),
    };
    if let Err(e) = shared.router.submit_to(worker, job) {
        let ctx = { shard.pending.lock().unwrap().remove(&batch_id) };
        if let Some(ctx) = ctx {
            fail_batch(shared, shard_idx, &ctx.batch, &ctx.slot, &format!("{e:#}"));
        }
    }
}

/// Fan one worker reply out to the batch's per-request completions.
/// `shard_idx` is the lane the batch dispatched from (its waiters live
/// there — under connection affinity that lane is not derivable from
/// request ids). `scratch` is the calling completion thread's reusable
/// fan-out buffer.
fn complete_batch(
    shared: &Arc<Shared>,
    shard_idx: usize,
    ctx: BatchCtx,
    reply: WorkerReply,
    scratch: &mut Vec<Option<Completion>>,
) {
    let BatchCtx { batch, guard, sched_cost, slot, tenant, formed_at, dispatched_at } = ctx;
    let _guard = guard;
    match reply.result {
        Ok(output) => {
            let done_at = Instant::now();
            let n = batch.requests.len();
            // The backend's own pricing (calibrated) wins over the
            // coordinator-side schedule; exactly one of the two exists.
            let cost = output.cost.or(sched_cost).unwrap_or_default();
            // Served-work metrics only count batches that actually
            // produced replies; failures go to record_batch_failure.
            shared.metrics.record_batch(n, batch.padded_to);
            shared.metrics.record_sim_cost(&cost);
            shared.metrics.record_host_gemm_us(output.host_gemm_us);
            // Stage accounting. Batch formation and the worker's wall
            // time — split into host GEMM plus the calibrated-gate
            // replay remainder — are batch-granular; queue-wait and
            // write-back land per request in the fan-out loop below.
            let form_us = dispatched_at.duration_since(formed_at).as_micros() as u64;
            shared.metrics.record_stage_us(Stage::BatchForm, form_us);
            let gemm_us = output.host_gemm_us.min(reply.wall_us);
            let gate_us = reply.wall_us - gemm_us;
            shared.metrics.record_stage_us(Stage::Gemm, gemm_us);
            if gate_us > 0 {
                shared.metrics.record_stage_us(Stage::CalibratedGate, gate_us);
            }
            // Worker-side spans are reconstructed from the reply's wall
            // time, anchored to end when the reply landed here.
            let done_us = shared.recorder.wall_us(done_at);
            let gemm_start = done_us.saturating_sub(reply.wall_us);
            // per-tenant accounting: requests served and how weight-
            // stationary this model's scheduled work was
            slot.requests.fetch_add(n as u64, Ordering::Relaxed);
            slot.programs.fetch_add(cost.programs, Ordering::Relaxed);
            slot.stationary_hits.fetch_add(cost.stationary_hits, Ordering::Relaxed);
            let per_req_energy = cost.energy_fj / n as f64;
            let out_dim = shared.out_dim;
            // A batch forms inside one shard, so one lock acquisition on
            // that shard's waiter map covers every request; completions
            // resolve after release — they run arbitrary caller code
            // (callbacks) or push frames, which must never happen under
            // the waiters lock.
            scratch.clear();
            {
                let shard = &shared.shards[shard_idx];
                let mut waiters = shard.waiters.lock().unwrap();
                scratch.extend(batch.requests.iter().map(|req| waiters.remove(&req.id)));
            }
            shared.admission.release(n);
            slot.inflight.fetch_sub(n as u64, Ordering::Relaxed);
            for ((i, req), waiter) in batch.requests.iter().enumerate().zip(scratch.drain(..)) {
                let logits = &output.logits[i * out_dim..(i + 1) * out_dim];
                let label = crate::nn::argmax(logits);
                let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
                shared.metrics.latency.record_us(latency_us);
                let queue_us = formed_at.duration_since(req.enqueued_at).as_micros() as u64;
                shared.metrics.record_stage_us(Stage::QueueWait, queue_us);
                tenant.latency.record_us(latency_us);
                tenant.queue.record_us(queue_us);
                if req.trace != 0 {
                    let rec = &shared.recorder;
                    rec.record(req.trace, Stage::QueueWait, req.enqueued_at, formed_at);
                    rec.record(req.trace, Stage::BatchForm, formed_at, dispatched_at);
                    rec.record_at(req.trace, Stage::Gemm, gemm_start, gemm_us);
                    if gate_us > 0 {
                        let gate_start = gemm_start + gemm_us;
                        rec.record_at(req.trace, Stage::CalibratedGate, gate_start, gate_us);
                    }
                }
                match waiter {
                    Some(Completion::Callback(done)) => done(Ok(InferenceResponse {
                        id: req.id,
                        logits: logits.to_vec(),
                        label,
                        latency_us,
                        sim_energy_fj: per_req_energy,
                        sim_latency_ps: cost.latency_ps,
                        sim_programs: cost.programs,
                        sim_stationary_hits: cost.stationary_hits,
                    })),
                    Some(Completion::Frame { tx, wire_id }) => {
                        // pooled frame logits: recycled after the writer
                        // flushes the frame and drops it
                        let _ = tx.send(Frame::Response {
                            id: wire_id,
                            label: label as u32,
                            latency_us,
                            cost: WireCost {
                                energy_fj: per_req_energy,
                                latency_ps: cost.latency_ps,
                                programs: cost.programs,
                                stationary_hits: cost.stationary_hits,
                            },
                            logits: PooledVec::from_slice(logits),
                            trace: req.trace,
                        });
                    }
                    None => {}
                }
                let resolved = Instant::now();
                let wb_us = resolved.duration_since(done_at).as_micros() as u64;
                shared.metrics.record_stage_us(Stage::WriteBack, wb_us);
                shared.recorder.record(req.trace, Stage::WriteBack, done_at, resolved);
            }
        }
        Err(e) => fail_batch(shared, shard_idx, &batch, &slot, &format!("{e:#}")),
    }
}

fn fail_batch(
    shared: &Arc<Shared>,
    shard_idx: usize,
    batch: &Batch,
    slot: &Arc<ModelSlot>,
    why: &str,
) {
    // Complete every waiter with the structured reason; the blocking
    // submit() surfaces it as "request failed: <why>" and the wire
    // front-end sends an Error frame.
    if batch.requests.is_empty() {
        return;
    }
    shared.metrics.record_batch_failure(batch.requests.len());
    let completions: Vec<_> = {
        let shard = &shared.shards[shard_idx];
        let mut waiters = shard.waiters.lock().unwrap();
        batch.requests.iter().map(|req| waiters.remove(&req.id)).collect()
    };
    shared.admission.release(batch.requests.len());
    slot.inflight.fetch_sub(batch.requests.len() as u64, Ordering::Relaxed);
    for done in completions.into_iter().flatten() {
        match done {
            Completion::Callback(f) => f(Err(why.to_string())),
            Completion::Frame { tx, wire_id } => {
                let _ = tx.send(Frame::Error { id: wire_id, reason: why.to_string() });
            }
        }
    }
    eprintln!("batch of {} failed: {why}", batch.requests.len());
}
