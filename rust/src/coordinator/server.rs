//! The serving front-end: accepts single requests, batches them, executes
//! on the worker pool (native LUT-GEMM by default, calibrated schedule
//! replay with `backend calibrated`, PJRT with the `pjrt` feature — see
//! [`crate::engine`]), prices the CiM work with the tiler (coordinator-
//! side, or inside each calibrated worker), and fans per-request
//! responses back out.
//!
//! Concurrency model (std threads; no async runtime in this offline
//! image): every admitted request registers a [`Completion`] callback —
//! blocking callers ([`ServerHandle::submit`]) wrap a oneshot in one,
//! the TCP front-end ([`crate::net`]) registers a frame writer via
//! [`ServerHandle::submit_with`]; a background flusher thread enforces
//! the batching deadline; a small **persistent completion pool**
//! receives worker replies and fans them out (a thread-per-batch design
//! measured ~25% slower at 4 workers — EXPERIMENTS.md §Perf).
//!
//! Admission control bounds *total outstanding* requests (pending +
//! in-flight) at `batcher.queue_depth`; rejections carry a structured
//! [`Backpressure`] retry hint.

use super::batcher::{Batch, Batcher};
use super::metrics::Metrics;
use super::request::{InferenceRequest, InferenceResponse, RequestId};
use super::router::Router;
use super::tiler::{ScheduleCost, Tiler, UnitCosts};
use super::worker::{BatchJob, WorkerPool};
use crate::config::{BackendKind, Config};
use crate::engine::{BackendSpec, BatchOutput};
use crate::nn::QuantMlp;
use crate::runtime::ArtifactStore;
use crate::util::oneshot;
use crate::Result;
use anyhow::{anyhow, ensure, Context};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// 429-style admission rejection with a structured retry hint.
///
/// [`ServerHandle::submit`]/[`ServerHandle::submit_with`] return this
/// (wrapped in `anyhow::Error`; recover it with
/// `err.downcast_ref::<Backpressure>()`) instead of an opaque "queue
/// full" failure, and the wire front-end maps it onto the protocol's
/// `Rejected` frame. The hint comes from
/// [`Batcher::retry_after_us`](super::Batcher::retry_after_us): queue
/// depth, `max_batch` and the flush deadline — an estimate, not a
/// reservation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backpressure {
    /// Suggested client backoff before retrying (µs, always ≥ 1).
    pub retry_after_us: u64,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server at capacity — retry in {} us", self.retry_after_us)
    }
}

impl std::error::Error for Backpressure {}

/// Completion callback a submission registers: invoked exactly once,
/// from a coordinator thread, with the response or the batch-failure
/// reason. The blocking [`ServerHandle::submit`] wraps a oneshot in one
/// of these; the TCP front-end registers a frame writer instead, so a
/// network connection can keep thousands of requests in flight without
/// a blocked thread each.
pub type Completion = Box<dyn FnOnce(std::result::Result<InferenceResponse, String>) + Send>;

struct Shared {
    batcher: Mutex<Batcher>,
    waiters: Mutex<HashMap<RequestId, Completion>>,
    /// Admission bound: total outstanding requests (pending in the
    /// batcher + dispatched but not yet completed) may not exceed
    /// `batcher.queue_depth` — the waiters map *is* the outstanding set,
    /// so its size under its own lock is the authoritative count.
    max_outstanding: usize,
    /// Lowered batch size, echoed in the wire protocol's `Info` frame.
    max_batch: usize,
    backend: BackendKind,
    /// Coordinator-side CiM pricing for backends that don't model cost
    /// themselves; `None` for `backend calibrated`, where each worker's
    /// own fabric replay prices the batch and the cost arrives on the
    /// reply.
    tiler: Option<Mutex<Tiler>>,
    router: Router,
    metrics: Arc<Metrics>,
    mlp: QuantMlp,
    in_dim: usize,
    out_dim: usize,
    next_id: AtomicU64,
    stopping: AtomicBool,
    /// Pad executed batches to `padded_to` (PJRT's lowered shape is
    /// fixed); the native backend runs exactly the real rows.
    pad_batches: bool,
    /// Queue feeding the persistent completion pool.
    completions: Mutex<std::sync::mpsc::Sender<CompletionJob>>,
}

/// An in-flight batch awaiting its worker reply.
struct CompletionJob {
    batch: Batch,
    rx: oneshot::Receiver<crate::Result<BatchOutput>>,
    guard: super::router::InFlightGuard,
    /// Coordinator-side pricing (None when the calibrated backend prices
    /// the batch itself; the reply's cost then takes over).
    sched_cost: Option<ScheduleCost>,
}

/// The serving coordinator. Construct with [`CoordinatorServer::start`],
/// submit through the cloned [`ServerHandle`]s.
pub struct CoordinatorServer {
    shared: Arc<Shared>,
    flusher: Option<std::thread::JoinHandle<()>>,
    completion_pool: Vec<std::thread::JoinHandle<()>>,
}

/// Cheap cloneable submission handle. `submit` blocks the calling thread
/// until the response arrives (drive it from multiple client threads).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl CoordinatorServer {
    /// Start the coordinator: load artifacts, spawn the worker pool and the
    /// deadline flusher. Requires `make artifacts` to have run.
    pub fn start(cfg: Config) -> Result<(Self, ServerHandle)> {
        cfg.validate()?;
        let store = ArtifactStore::new(&cfg.artifacts_dir);
        let meta = store.manifest()?;
        ensure!(
            meta.batch == cfg.batcher.max_batch,
            "config max_batch {} != lowered batch {} — artifacts and config must agree",
            cfg.batcher.max_batch,
            meta.batch
        );
        let mlp = store.load_mlp().context("loading weights")?;
        let lib = crate::cells::tsmc65_library();
        // Coordinator-side pricing tiler for backends that don't model
        // cost themselves. `calibrated` moves pricing into the workers
        // (one weight-stationary fabric per worker), so the coordinator
        // keeps none.
        let tiler = match cfg.backend {
            BackendKind::Calibrated => None,
            _ => Some(Mutex::new(Tiler::from_config(&cfg, &lib))),
        };
        // Backend choice: native runs the batched LUT-GEMM in-process
        // (no HLO artifacts touched); calibrated wraps it with per-worker
        // schedule replay (the gate-level calibration is measured once
        // here and *carried in the spec* — never per worker thread);
        // pjrt compiles the AOT executable.
        let spec = match cfg.backend {
            BackendKind::Native => BackendSpec::Native {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                threads: cfg.gemm.threads,
            },
            BackendKind::Calibrated => BackendSpec::Calibrated {
                mlp: mlp.clone(),
                kind: cfg.multiplier,
                costs: UnitCosts::measure_cached(Tiler::pricing_kind(cfg.multiplier), &lib),
                banks: cfg.banks.count,
                units_per_bank: cfg.banks.units_per_bank,
                time_scale: cfg.timing.time_scale,
                threads: cfg.gemm.threads,
            },
            BackendKind::Pjrt => BackendSpec::Pjrt { hlo: store.mlp_hlo(cfg.multiplier) },
        };
        let pool = WorkerPool::spawn(cfg.workers.count, spec)?;
        let in_dim = *meta.dims.first().unwrap();
        let out_dim = *meta.dims.last().unwrap();
        let (ctx, crx) = std::sync::mpsc::channel::<CompletionJob>();
        let crx = Arc::new(Mutex::new(crx));
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::from_config(&cfg.batcher)),
            waiters: Mutex::new(HashMap::new()),
            max_outstanding: cfg.batcher.queue_depth,
            max_batch: cfg.batcher.max_batch,
            backend: cfg.backend,
            tiler,
            router: Router::new(pool),
            metrics: Arc::new(Metrics::new()),
            mlp,
            in_dim,
            out_dim,
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            pad_batches: cfg.backend == BackendKind::Pjrt,
            completions: Mutex::new(ctx),
        });
        // Persistent completion pool: one thread per worker keeps the
        // pipeline full without per-batch thread spawns.
        let mut completion_pool = Vec::new();
        for i in 0..cfg.workers.count {
            let crx = crx.clone();
            let shared2 = Arc::downgrade(&shared);
            completion_pool.push(
                std::thread::Builder::new()
                    .name(format!("luna-completion-{i}"))
                    .spawn(move || loop {
                        let job = { crx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                let Some(shared) = shared2.upgrade() else { return };
                                complete_batch(&shared, job);
                            }
                            Err(_) => return,
                        }
                    })
                    .expect("spawn completion thread"),
            );
        }
        let flusher = {
            let weak = Arc::downgrade(&shared);
            let period = Duration::from_micros((cfg.batcher.max_wait_us.max(50)) / 2);
            std::thread::Builder::new()
                .name("luna-flusher".into())
                .spawn(move || loop {
                    std::thread::sleep(period);
                    let Some(shared) = weak.upgrade() else { return };
                    if shared.stopping.load(Ordering::Relaxed) {
                        return;
                    }
                    let due =
                        { shared.batcher.lock().unwrap().flush_due(std::time::Instant::now()) };
                    if let Some(batch) = due {
                        dispatch_batch(&shared, batch);
                    }
                })
                .expect("spawn flusher")
        };
        let handle = ServerHandle { shared: shared.clone() };
        Ok((CoordinatorServer { shared, flusher: Some(flusher), completion_pool }, handle))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Flush pending requests, drain the completion pool, stop the flusher.
    pub fn shutdown(mut self) {
        self.shared.stopping.store(true, Ordering::Relaxed);
        let batches = { self.shared.batcher.lock().unwrap().flush_all() };
        for b in batches {
            dispatch_batch(&self.shared, b);
        }
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Closing the channel ends the completion threads once drained.
        {
            let (dead_tx, _) = std::sync::mpsc::channel();
            *self.shared.completions.lock().unwrap() = dead_tx;
        }
        let pool = std::mem::take(&mut self.completion_pool);
        drop(self.shared);
        for h in pool {
            let _ = h.join();
        }
    }
}

impl ServerHandle {
    /// Submit one image and block until the batched execution completes.
    /// Admission failures surface as [`Backpressure`] (downcastable from
    /// the returned error) carrying a `retry_after_us` hint.
    pub fn submit(&self, pixels: Vec<f32>) -> Result<InferenceResponse> {
        let (tx, rx) = oneshot::channel();
        self.submit_with(
            pixels,
            Box::new(move |result| {
                let _ = tx.send(result);
            }),
        )?;
        match rx.recv() {
            Some(Ok(resp)) => Ok(resp),
            Some(Err(why)) => Err(anyhow!("request failed: {why}")),
            None => Err(anyhow!("request dropped")),
        }
    }

    /// Admission-checked asynchronous submission: on success, `done` is
    /// invoked exactly once — with the response, or with the failure
    /// reason if the batch dies — from a coordinator thread. On
    /// rejection `done` is dropped unused (never invoked) and a
    /// [`Backpressure`] error comes back, so the caller replies 429
    /// itself.
    ///
    /// Admission bounds total outstanding requests (pending +
    /// in-flight) by `batcher.queue_depth` — the genuine overload
    /// guard. The batcher's own pending bound is subsumed here (every
    /// queued request holds a waiter, so the pending queue is always
    /// strictly smaller than the outstanding set this gate caps).
    pub fn submit_with(&self, pixels: Vec<f32>, done: Completion) -> Result<()> {
        ensure!(pixels.len() == self.shared.in_dim, "expected {} pixels", self.shared.in_dim);
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let outstanding = {
            let mut waiters = self.shared.waiters.lock().unwrap();
            if waiters.len() >= self.shared.max_outstanding {
                Some(waiters.len())
            } else {
                waiters.insert(id, done);
                None
            }
        };
        if let Some(backlog) = outstanding {
            let hint = {
                let batcher = self.shared.batcher.lock().unwrap();
                batcher.retry_after_us(std::time::Instant::now(), backlog)
            };
            self.shared.metrics.record_rejection(hint);
            return Err(Backpressure { retry_after_us: hint }.into());
        }
        let maybe_batch = {
            let mut batcher = self.shared.batcher.lock().unwrap();
            match batcher.push(InferenceRequest::new(id, pixels)) {
                Ok(b) => b,
                // Unreachable by invariant (pending < outstanding <=
                // queue_depth at every push — the gate above already
                // rejected); kept as defense in depth since the batcher
                // is also driven standalone, where `push` genuinely
                // backpressures.
                Err(_rejected) => {
                    let hint =
                        batcher.retry_after_us(std::time::Instant::now(), batcher.pending());
                    drop(batcher);
                    self.shared.waiters.lock().unwrap().remove(&id);
                    self.shared.metrics.record_rejection(hint);
                    return Err(Backpressure { retry_after_us: hint }.into());
                }
            }
        };
        self.shared.metrics.record_admission();
        if let Some(batch) = maybe_batch {
            dispatch_batch(&self.shared, batch);
        }
        Ok(())
    }

    /// Input dimension the model expects (pixels per request).
    pub fn input_dim(&self) -> usize {
        self.shared.in_dim
    }

    /// Output dimension (logits per response).
    pub fn output_dim(&self) -> usize {
        self.shared.out_dim
    }

    /// The lowered batch size requests are batched up to.
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// Stable identifier of the execution backend serving this handle.
    pub fn backend_slug(&self) -> &'static str {
        self.shared.backend.slug()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }
}

/// Price the batch on the CiM fabric (unless the backend prices it
/// itself), run it on a worker, fan responses back out to the
/// per-request waiters.
fn dispatch_batch(shared: &Arc<Shared>, batch: Batch) {
    let n = batch.requests.len();
    if n == 0 {
        return;
    }
    // CiM cost model: schedule this batch on the coordinator's fabric —
    // skipped for `backend calibrated`, whose workers replay the schedule
    // on their own weight-stationary fabrics and return the cost.
    let sched_cost =
        shared.tiler.as_ref().map(|t| t.lock().unwrap().schedule(&shared.mlp, n).cost());

    // PJRT's lowered executable has a fixed batch dimension; the native
    // GEMM runs exactly the real rows (no MACs spent on padding).
    let exec_rows = if shared.pad_batches { batch.padded_to } else { n };
    let inputs = batch.flatten_rows(shared.in_dim, exec_rows);
    let (tx, rx) = oneshot::channel();
    let job = BatchJob { inputs, batch: exec_rows, dim: shared.in_dim, reply: tx };
    let guard = match shared.router.dispatch(job) {
        Ok(g) => g,
        Err(e) => {
            fail_batch(shared, &batch, &format!("{e:#}"));
            return;
        }
    };
    let job = CompletionJob { batch, rx, guard, sched_cost };
    let send_result = { shared.completions.lock().unwrap().send(job) };
    if let Err(std::sync::mpsc::SendError(job)) = send_result {
        // Pool already shut down (server tear-down path): complete inline.
        complete_batch(shared, job);
    }
}

/// Receive one worker reply and fan it out to the per-request waiters.
fn complete_batch(shared: &Arc<Shared>, job: CompletionJob) {
    let CompletionJob { batch, rx, guard, sched_cost } = job;
    let _guard = guard;
    match rx.recv() {
        Some(Ok(output)) => {
            let n = batch.requests.len();
            // The backend's own pricing (calibrated) wins over the
            // coordinator-side schedule; exactly one of the two exists.
            let cost = output.cost.or(sched_cost).unwrap_or_default();
            // Served-work metrics only count batches that actually
            // produced replies; failures go to record_batch_failure.
            shared.metrics.record_batch(n, batch.padded_to);
            shared.metrics.record_sim_cost(&cost);
            shared.metrics.record_host_gemm_us(output.host_gemm_us);
            let per_req_energy = cost.energy_fj / n as f64;
            let logits_all = &output.outputs[0];
            let out_dim = shared.out_dim;
            // One lock acquisition for the whole batch; completions are
            // invoked after release — they run arbitrary caller code
            // (the wire front-end serializes a frame here), which must
            // never happen under the waiters lock.
            let completions: Vec<_> = {
                let mut waiters = shared.waiters.lock().unwrap();
                batch.requests.iter().map(|req| waiters.remove(&req.id)).collect()
            };
            for ((i, req), waiter) in batch.requests.iter().enumerate().zip(completions) {
                let logits = logits_all[i * out_dim..(i + 1) * out_dim].to_vec();
                let label = crate::nn::argmax(&logits);
                let latency_us = req.enqueued_at.elapsed().as_micros() as u64;
                shared.metrics.latency.record_us(latency_us);
                if let Some(done) = waiter {
                    done(Ok(InferenceResponse {
                        id: req.id,
                        logits,
                        label,
                        latency_us,
                        sim_energy_fj: per_req_energy,
                        sim_latency_ps: cost.latency_ps,
                        sim_programs: cost.programs,
                        sim_stationary_hits: cost.stationary_hits,
                    }));
                }
            }
        }
        Some(Err(e)) => fail_batch(shared, &batch, &format!("{e:#}")),
        None => fail_batch(shared, &batch, "worker dropped reply"),
    }
}

fn fail_batch(shared: &Arc<Shared>, batch: &Batch, why: &str) {
    // Complete every waiter with the structured reason; the blocking
    // submit() surfaces it as "request failed: <why>" and the wire
    // front-end sends an Error frame.
    shared.metrics.record_batch_failure(batch.requests.len());
    let completions: Vec<_> = {
        let mut waiters = shared.waiters.lock().unwrap();
        batch.requests.iter().map(|req| waiters.remove(&req.id)).collect()
    };
    for done in completions.into_iter().flatten() {
        done(Err(why.to_string()));
    }
    eprintln!("batch of {} failed: {why}", batch.requests.len());
}
