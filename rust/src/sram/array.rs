//! Behavioural SRAM array with periphery inventory and energy accounting.

use super::energy::{AccessKind, EnergyLedger};
use crate::cells::{CellKind, CellLibrary, CostReport};

/// Array geometry. The paper's vehicle is 8×8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayGeometry {
    pub rows: usize,
    pub cols: usize,
}

impl ArrayGeometry {
    pub const PAPER_8X8: ArrayGeometry = ArrayGeometry { rows: 8, cols: 8 };

    pub fn bits(&self) -> usize {
        self.rows * self.cols
    }
}

/// Behavioural SRAM array: bit storage + row/column access operations,
/// each charged to an [`EnergyLedger`] per the calibrated access energies.
#[derive(Debug, Clone)]
pub struct SramArray {
    geom: ArrayGeometry,
    bits: Vec<bool>,
    ledger: EnergyLedger,
}

impl SramArray {
    pub fn new(geom: ArrayGeometry) -> Self {
        SramArray { geom, bits: vec![false; geom.bits()], ledger: EnergyLedger::default() }
    }

    /// The paper's 8×8 evaluation array.
    pub fn paper_8x8() -> Self {
        Self::new(ArrayGeometry::PAPER_8X8)
    }

    pub fn geometry(&self) -> ArrayGeometry {
        self.geom
    }

    fn idx(&self, row: usize, col: usize) -> usize {
        assert!(row < self.geom.rows && col < self.geom.cols, "address out of range");
        row * self.geom.cols + col
    }

    /// Write one bit; charges one write access (decoders + conditioning +
    /// column controller + cell).
    pub fn write_bit(&mut self, lib: &CellLibrary, row: usize, col: usize, value: bool) {
        let i = self.idx(row, col);
        self.bits[i] = value;
        self.ledger.charge(lib, AccessKind::WriteBit);
    }

    /// Read one bit; charges one read access (decoders + conditioning +
    /// sense amp).
    pub fn read_bit(&mut self, lib: &CellLibrary, row: usize, col: usize) -> bool {
        let v = self.bits[self.idx(row, col)];
        self.ledger.charge(lib, AccessKind::ReadBit);
        v
    }

    /// Write a full row (little-endian over columns), one access per bit —
    /// the per-bit accounting the paper's J/bit/access metric uses.
    pub fn write_row(&mut self, lib: &CellLibrary, row: usize, value: u64) {
        for col in 0..self.geom.cols {
            self.write_bit(lib, row, col, (value >> col) & 1 == 1);
        }
    }

    /// Read a full row (little-endian over columns).
    pub fn read_row(&mut self, lib: &CellLibrary, row: usize) -> u64 {
        (0..self.geom.cols).fold(0u64, |acc, col| {
            acc | ((self.read_bit(lib, row, col) as u64) << col)
        })
    }

    /// Peek without charging energy (testing/debug).
    pub fn peek(&self, row: usize, col: usize) -> bool {
        self.bits[self.idx(row, col)]
    }

    /// Accumulated energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Reset the energy ledger (e.g. between benchmark phases).
    pub fn reset_ledger(&mut self) {
        self.ledger = EnergyLedger::default();
    }

    /// Component inventory of the array incl. periphery (Fig 17/18 area
    /// accounting): cells + 1 conditioner, sense amp and column controller
    /// per column + one row and one column decoder.
    pub fn cost(&self) -> CostReport {
        CostReport::from_pairs(&[
            (CellKind::SramCell, self.geom.bits() as u64),
            (CellKind::BitlineConditioner, self.geom.cols as u64),
            (CellKind::SenseAmp, self.geom.cols as u64),
            (CellKind::ColumnController, self.geom.cols as u64),
            (CellKind::RowDecoder, 1),
            (CellKind::ColumnDecoder, 1),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;

    #[test]
    fn rows_store_and_read_back() {
        let lib = tsmc65_library();
        let mut a = SramArray::paper_8x8();
        a.write_row(&lib, 3, 0b0110_1001);
        assert_eq!(a.read_row(&lib, 3), 0b0110_1001);
        assert_eq!(a.read_row(&lib, 2), 0);
    }

    #[test]
    fn write_energy_matches_paper_constant() {
        // The calibrated write energy must be 173.8 pJ per bit per access.
        let lib = tsmc65_library();
        let mut a = SramArray::paper_8x8();
        a.write_bit(&lib, 0, 0, true);
        let pj = a.ledger().total_fj() / 1000.0;
        assert!((pj - crate::cells::tsmc65::PAPER_WRITE_ENERGY_PJ_PER_BIT).abs() < 1e-9,
            "write energy {pj} pJ");
    }

    #[test]
    #[should_panic]
    fn out_of_range_address_panics() {
        let lib = tsmc65_library();
        let mut a = SramArray::paper_8x8();
        a.write_bit(&lib, 8, 0, true);
    }

    #[test]
    fn cost_inventory_matches_paper_description() {
        let a = SramArray::paper_8x8();
        let c = a.cost();
        assert_eq!(c.count(CellKind::SramCell), 64);
        assert_eq!(c.count(CellKind::BitlineConditioner), 8);
        assert_eq!(c.count(CellKind::SenseAmp), 8);
        assert_eq!(c.count(CellKind::ColumnController), 8);
        assert_eq!(c.count(CellKind::RowDecoder), 1);
        assert_eq!(c.count(CellKind::ColumnDecoder), 1);
    }
}
