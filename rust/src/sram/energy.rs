//! Per-access energy accounting for the SRAM array (Fig 15 / §IV.B).

use crate::cells::{CellKind, CellLibrary};

/// Kinds of array access the ledger distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// One-bit write: decoders + bitline conditioning + column controller
    /// + cell write.
    WriteBit,
    /// One-bit read: decoders + bitline conditioning + sense amp.
    ReadBit,
}

impl AccessKind {
    /// Components exercised by this access, in Fig 15's inventory.
    pub fn components(self) -> &'static [CellKind] {
        match self {
            AccessKind::WriteBit => &[
                CellKind::RowDecoder,
                CellKind::ColumnDecoder,
                CellKind::BitlineConditioner,
                CellKind::ColumnController,
                CellKind::SramCell,
                CellKind::SenseAmp,
            ],
            AccessKind::ReadBit => &[
                CellKind::RowDecoder,
                CellKind::ColumnDecoder,
                CellKind::BitlineConditioner,
                CellKind::SenseAmp,
            ],
        }
    }
}

/// Energy per component class, femtojoules — the Fig 15 bar chart.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    entries: Vec<(CellKind, f64)>,
}

impl EnergyBreakdown {
    pub fn add(&mut self, kind: CellKind, fj: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(k, _)| *k == kind) {
            e.1 += fj;
        } else {
            self.entries.push((kind, fj));
        }
    }

    pub fn get(&self, kind: CellKind) -> f64 {
        self.entries.iter().find(|(k, _)| *k == kind).map(|(_, v)| *v).unwrap_or(0.0)
    }

    pub fn total_fj(&self) -> f64 {
        self.entries.iter().map(|(_, v)| v).sum()
    }

    /// `(kind, fJ, share)` rows sorted by energy, largest first.
    pub fn rows(&self) -> Vec<(CellKind, f64, f64)> {
        let total = self.total_fj();
        let mut rows: Vec<_> =
            self.entries.iter().map(|&(k, v)| (k, v, if total > 0.0 { v / total } else { 0.0 })).collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

/// Accumulating energy ledger with per-component attribution.
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    breakdown: EnergyBreakdown,
    accesses: u64,
}

impl EnergyLedger {
    /// Charge one access of `kind` under the library's calibrated
    /// per-access energies.
    pub fn charge(&mut self, lib: &CellLibrary, kind: AccessKind) {
        for &c in kind.components() {
            self.breakdown.add(c, lib.params(c).energy_per_access_fj);
        }
        self.accesses += 1;
    }

    /// Charge an externally computed amount (e.g. multiplier toggle energy)
    /// to a component class.
    pub fn charge_external(&mut self, kind: CellKind, fj: f64) {
        self.breakdown.add(kind, fj);
    }

    pub fn total_fj(&self) -> f64 {
        self.breakdown.total_fj()
    }

    pub fn accesses(&self) -> u64 {
        self.accesses
    }

    pub fn breakdown(&self) -> &EnergyBreakdown {
        &self.breakdown
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &EnergyLedger) {
        for &(k, v) in &other.breakdown.entries {
            self.breakdown.add(k, v);
        }
        self.accesses += other.accesses;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;

    #[test]
    fn write_charge_covers_all_components() {
        let lib = tsmc65_library();
        let mut l = EnergyLedger::default();
        l.charge(&lib, AccessKind::WriteBit);
        for &c in AccessKind::WriteBit.components() {
            assert!(l.breakdown().get(c) > 0.0, "{c:?}");
        }
        assert_eq!(l.accesses(), 1);
    }

    #[test]
    fn rows_sorted_descending() {
        let lib = tsmc65_library();
        let mut l = EnergyLedger::default();
        l.charge(&lib, AccessKind::WriteBit);
        let rows = l.breakdown().rows();
        for pair in rows.windows(2) {
            assert!(pair[0].1 >= pair[1].1);
        }
        // Bitline conditioning dominates (the paper's Fig 15 shape).
        assert_eq!(rows[0].0, CellKind::BitlineConditioner);
    }

    #[test]
    fn merge_accumulates() {
        let lib = tsmc65_library();
        let mut a = EnergyLedger::default();
        a.charge(&lib, AccessKind::WriteBit);
        let mut b = EnergyLedger::default();
        b.charge(&lib, AccessKind::ReadBit);
        b.charge_external(CellKind::Mux2, 47.96);
        a.merge(&b);
        assert_eq!(a.accesses(), 2);
        assert!(a.breakdown().get(CellKind::Mux2) > 0.0);
    }
}
