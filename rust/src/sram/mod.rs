//! SRAM array substrate — the paper's §IV.B test vehicle.
//!
//! An 8×8 SRAM array "composed of 64 SRAM cells ... 8 units for Bitline
//! conditioning, 8 sense amplifiers, 8 column controllers, as well as a
//! row decoder [and] a column decoder". This module models the array
//! behaviourally (bit storage, read/write ops) with per-access energy
//! accounting calibrated to the paper's measured **173.8 pJ/bit/access**
//! and the Fig 15 component breakdown.

mod array;
mod energy;

pub use array::{ArrayGeometry, SramArray};
pub use energy::{AccessKind, EnergyBreakdown, EnergyLedger};
