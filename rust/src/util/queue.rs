//! Steady-state allocation-free MPMC queue (`Mutex<VecDeque>` +
//! `Condvar`).
//!
//! `std::sync::mpsc` allocates per block of messages on every channel,
//! which breaks the serving path's zero-allocation invariant (see
//! [`super::pool`]). This queue's ring buffer reaches a steady capacity
//! after warmup and never allocates again; send is a lock + push +
//! notify, receive blocks on the condvar.
//!
//! Disconnect semantics match `mpsc`: [`Sender::send`] fails (returning
//! the value) once every receiver is gone; [`Receiver::recv`] returns
//! `None` once the queue is empty **and** every sender is gone. Both
//! halves are cloneable — the coordinator's completion pool shares one
//! receiver across its threads.
//!
//! The close-and-drain protocol (documented on [`Receiver`]'s `Drop`)
//! is model-checked under loom: the sync primitives come from the
//! [`super::sync`] shim, and `tests/loom_models.rs` plus the
//! `#[cfg(loom)]` models below explore every interleaving of
//! send/recv/clone/drop.

use crate::util::sync::{Arc, Condvar, Mutex};
use std::collections::VecDeque;

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

/// Cloneable producer half.
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Cloneable consumer half (multiple consumers block on one queue).
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

/// Create a connected queue pair.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(Inner {
        state: Mutex::new(State { queue: VecDeque::new(), senders: 1, receivers: 1 }),
        ready: Condvar::new(),
    });
    (Sender { inner: inner.clone() }, Receiver { inner })
}

impl<T> Sender<T> {
    /// Enqueue a value; `Err(value)` if every receiver is gone.
    pub fn send(&self, value: T) -> Result<(), T> {
        let mut st = self.inner.state.lock().unwrap();
        if st.receivers == 0 {
            return Err(value);
        }
        st.queue.push_back(value);
        drop(st);
        self.inner.ready.notify_one();
        Ok(())
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.state.lock().unwrap().senders += 1;
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let last = {
            let mut st = self.inner.state.lock().unwrap();
            st.senders -= 1;
            st.senders == 0
        };
        if last {
            // wake every blocked receiver so it can observe disconnect
            self.inner.ready.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Block for the next value; `None` once the queue is drained and
    /// every sender is gone.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Some(v);
            }
            if st.senders == 0 {
                return None;
            }
            st = self.inner.ready.wait(st).unwrap();
        }
    }

    /// Pop without blocking (`None` when empty, disconnected or not).
    pub fn try_recv(&self) -> Option<T> {
        self.inner.state.lock().unwrap().queue.pop_front()
    }

    /// Values currently queued.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.inner.state.lock().unwrap().receivers += 1;
        Receiver { inner: self.inner.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        // `mpsc` parity: the last receiver drops every queued value
        // (senders discover the disconnect on their next send). Values
        // are dropped *outside* the lock — their destructors may take
        // other locks (e.g. a worker job's reply ticket sending onto a
        // different queue).
        let drained = {
            let mut st = self.inner.state.lock().unwrap();
            st.receivers -= 1;
            if st.receivers == 0 {
                std::mem::take(&mut st.queue)
            } else {
                VecDeque::new()
            }
        };
        drop(drained);
    }
}

// Unit models for loom's scheduler (the cross-module protocol models —
// ticket drop guards, admission — live in `tests/loom_models.rs`). Each
// closure body runs once per explored interleaving; shimmed primitives
// are created inside it, as loom requires.
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;

    /// Sender-drop vs blocked receiver: the last sender's notify_all
    /// must wake a parked receiver into observing the disconnect, and a
    /// queued value must survive the sender's death.
    #[test]
    fn send_then_disconnect_reaches_receiver() {
        loom::model(|| {
            let (tx, rx) = channel::<u32>();
            let t = loom::thread::spawn(move || {
                tx.send(1).unwrap();
                // tx drops here: senders hits 0
            });
            assert_eq!(rx.recv(), Some(1), "queued value survives sender drop");
            assert_eq!(rx.recv(), None, "disconnect observed after drain");
            t.join().unwrap();
        });
    }

    /// Concurrent send vs last-receiver drop: either the send loses the
    /// race (value handed back) or the drain drops it — in every
    /// interleaving the value is accounted for exactly once.
    #[test]
    fn send_races_last_receiver_drop_without_leaking() {
        loom::model(|| {
            let (tx, rx) = channel::<std::sync::Arc<()>>();
            let probe = std::sync::Arc::new(());
            tx.send(probe.clone()).unwrap();
            let t = loom::thread::spawn(move || drop(rx));
            let second = tx.send(probe.clone());
            drop(second); // a rejected value comes back and drops here
            t.join().unwrap();
            assert_eq!(
                std::sync::Arc::strong_count(&probe),
                1,
                "every value dropped exactly once: drained, or returned by send"
            );
            assert!(tx.send(probe.clone()).is_err(), "disconnect is permanent");
        });
    }

    /// Two receivers racing one sender: each value consumed exactly
    /// once, and both consumers terminate on disconnect.
    #[test]
    fn competing_receivers_consume_each_value_once() {
        loom::model(|| {
            let (tx, rx) = channel::<u8>();
            let rx2 = rx.clone();
            tx.send(1).unwrap();
            tx.send(2).unwrap();
            drop(tx);
            let t = loom::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = rx2.recv() {
                    got.push(v);
                }
                got
            });
            let mut got = Vec::new();
            while let Some(v) = rx.recv() {
                got.push(v);
            }
            got.extend(t.join().unwrap());
            got.sort_unstable();
            assert_eq!(got, vec![1, 2], "every value consumed exactly once");
        });
    }
}

// These spawn real OS threads and sleep — meaningless (and panicking)
// under loom's cooperative scheduler, so they are compiled out of
// `--cfg loom` builds.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_roundtrip() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
        assert_eq!(rx.try_recv(), None);
        assert_eq!(rx.len(), 0);
    }

    #[test]
    fn recv_sees_disconnect_after_drain() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let tx2 = tx.clone();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Some(1), "queued values survive sender drop");
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_once_receivers_gone() {
        let (tx, rx) = channel();
        let rx2 = rx.clone();
        drop(rx);
        tx.send(9u8).unwrap();
        assert_eq!(rx2.recv(), Some(9));
        drop(rx2);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn blocking_recv_wakes_on_send_and_on_disconnect() {
        let (tx, rx) = channel::<u64>();
        let t = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(10));
        tx.send(42).unwrap();
        assert_eq!(t.join().unwrap(), Some(42));

        let (tx, rx) = channel::<u64>();
        let t = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(10));
        drop(tx);
        assert_eq!(t.join().unwrap(), None, "disconnect wakes a parked receiver");
    }

    #[test]
    fn last_receiver_drop_drains_queued_values() {
        use std::sync::Arc;
        let (tx, rx) = channel();
        let probe = Arc::new(());
        tx.send(probe.clone()).unwrap();
        tx.send(probe.clone()).unwrap();
        assert_eq!(Arc::strong_count(&probe), 3);
        drop(rx);
        assert_eq!(Arc::strong_count(&probe), 1, "queued values dropped with the last receiver");
        assert!(tx.send(probe.clone()).is_err());
    }

    #[test]
    fn multiple_consumers_share_one_queue() {
        let (tx, rx) = channel::<usize>();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                while rx.recv().is_some() {
                    got += 1;
                }
                got
            }));
        }
        drop(rx);
        for i in 0..30 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 30, "every value consumed exactly once");
    }

    #[test]
    fn steady_state_capacity_stabilizes() {
        let (tx, rx) = channel::<u32>();
        // fill/drain cycles must not grow the ring unboundedly
        for round in 0..10 {
            for i in 0..8 {
                tx.send(round * 8 + i).unwrap();
            }
            for _ in 0..8 {
                rx.recv().unwrap();
            }
        }
        assert_eq!(rx.len(), 0);
    }
}
