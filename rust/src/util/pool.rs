//! Size-classed buffer pool: recycled `Vec`s for the serving hot path.
//!
//! Every layer of the request path used to allocate — frame decode,
//! request pixels, the batcher's flat input matrix, backend logits,
//! reply frames. [`PooledVec`] replaces all of them with buffers drawn
//! from a process-wide free list and returned **on drop**, so after
//! warmup a steady-state request performs zero heap allocations end to
//! end (pinned by `tests/hot_path_allocs.rs`).
//!
//! Design:
//!
//! * **Size classes.** Buffers live in power-of-two capacity classes
//!   (class `k` holds capacities in `[2^k, 2^(k+1))`). A `get(min_cap)`
//!   pops from class `ceil(log2(min_cap))`, whose every member is large
//!   enough by construction; a miss allocates the full class size so the
//!   buffer recycles cleanly. Serving buffer sizes are effectively
//!   static (pixels, logits, one flat batch), so each class converges to
//!   a handful of resident buffers.
//! * **Drop-based recycling.** [`PooledVec`] is a thin owner that
//!   returns its buffer in `Drop` — no call-site discipline needed; a
//!   buffer that crosses threads (request → worker → reply writer) goes
//!   home from wherever it dies. `clear()` on return drops elements, so
//!   pools of element types that themselves own pooled buffers (e.g. a
//!   request vec whose requests hold pixel buffers) cascade correctly.
//! * **Global, typed pools.** One static [`ClassPool`] per element type
//!   (registered via [`PoolItem`]); no `Arc` plumbing through ten
//!   layers, and the pool survives server restarts within a process.
//!   Stats (hits / misses / recycled) are process-wide atomics surfaced
//!   on the metrics `pool` line
//!   ([`crate::coordinator::MetricsSnapshot::render`]).
//!
//! Under `--cfg loom` the class mutexes come from the
//! [`super::sync`] shim and the recycle protocol is model-checked
//! against pool instances created inside the model (loom types are not
//! const-constructible, so the global typed pools are compiled out and
//! [`PooledVec`] falls back to plain allocation — the *protocol* is
//! what the models pin, on `ClassPool` values they own).

use crate::util::sync::Mutex;
use std::mem::ManuallyDrop;
use std::ops::{Deref, DerefMut};
// Stats stay std atomics even under loom: they are monitoring counters
// with no synchronization role (nothing reads them to make a
// happens-before decision), and keeping them off the shim lets the loom
// models read exact cross-thread deltas after `join`.
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two size classes (`2^0 ..= 2^(CLASSES-1)` element
/// capacities; larger buffers share the last class, see [`ClassPool::get`]).
const CLASSES: usize = 24;

/// Free buffers retained per class; beyond this, returns are dropped
/// (bounds resident memory against a burst that later subsides).
const MAX_PER_CLASS: usize = 1024;

/// Process-wide pool counters (all typed pools share them): `hits` =
/// `get` served from the free list, `misses` = `get` that had to
/// allocate, `recycled` = buffers returned to a free list.
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static RECYCLED: AtomicU64 = AtomicU64::new(0);

/// Point-in-time view of the pool counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub recycled: u64,
}

impl PoolStats {
    /// Fraction of `get`s served without allocating (0.0 before any).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Snapshot the process-wide pool counters.
pub fn stats() -> PoolStats {
    PoolStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        recycled: RECYCLED.load(Ordering::Relaxed),
    }
}

/// A free list of `Vec<T>` buffers in power-of-two capacity classes.
/// Usually used through [`PooledVec`] / [`PoolItem`] rather than
/// directly.
pub struct ClassPool<T> {
    classes: [Mutex<Vec<Vec<T>>>; CLASSES],
}

/// ceil(log2(cap)) clamped to the class range; class 0 holds capacity 1.
fn class_for_request(min_cap: usize) -> usize {
    if min_cap <= 1 {
        return 0;
    }
    ((usize::BITS - (min_cap - 1).leading_zeros()) as usize).min(CLASSES - 1)
}

/// floor(log2(cap)) clamped: the class whose every member a buffer of
/// this capacity can serve.
fn class_for_return(cap: usize) -> usize {
    debug_assert!(cap >= 1);
    ((usize::BITS - 1 - cap.leading_zeros()) as usize).min(CLASSES - 1)
}

impl<T> ClassPool<T> {
    // Const-constructible only off loom (loom's Mutex has no const
    // constructor); the loom models build pools at model runtime.
    #[cfg(not(loom))]
    pub const fn new() -> Self {
        ClassPool { classes: [const { Mutex::new(Vec::new()) }; CLASSES] }
    }

    #[cfg(loom)]
    pub fn new() -> Self {
        ClassPool { classes: std::array::from_fn(|_| Mutex::new(Vec::new())) }
    }

    /// Pop a cleared buffer with `capacity >= min_cap` (allocating one
    /// rounded up to the class size on a miss).
    pub fn get(&self, min_cap: usize) -> Vec<T> {
        let class = class_for_request(min_cap);
        let popped = { self.classes[class].lock().unwrap().pop() };
        match popped {
            Some(mut v) => {
                HITS.fetch_add(1, Ordering::Relaxed);
                // Only the open-ended last class can under-deliver
                // (buffers beyond 2^(CLASSES-1) share it).
                if v.capacity() < min_cap {
                    v.reserve(min_cap);
                }
                v
            }
            None => {
                MISSES.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity((1usize << class).max(min_cap))
            }
        }
    }

    /// Return a buffer to its class (cleared; elements are dropped here,
    /// which cascades nested pooled buffers home). Zero-capacity buffers
    /// and over-full classes are simply dropped.
    pub fn put(&self, mut v: Vec<T>) {
        v.clear();
        if v.capacity() == 0 {
            return;
        }
        let class = class_for_return(v.capacity());
        let mut list = self.classes[class].lock().unwrap();
        if list.len() < MAX_PER_CLASS {
            list.push(v);
            RECYCLED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Element types with a process-wide [`ClassPool`]. Implemented for the
/// serving path's buffer elements (`u8`, `f32` here; request vecs in
/// [`crate::coordinator::request`]).
///
/// The `pool()` accessor only exists off loom: loom primitives cannot
/// live in statics, so `--cfg loom` builds have no global pools and
/// [`PooledVec`] allocates plainly (see the module docs).
pub trait PoolItem: Sized + 'static {
    #[cfg(not(loom))]
    fn pool() -> &'static ClassPool<Self>;
}

#[cfg(not(loom))]
static U8_POOL: ClassPool<u8> = ClassPool::new();
#[cfg(not(loom))]
static F32_POOL: ClassPool<f32> = ClassPool::new();

impl PoolItem for u8 {
    #[cfg(not(loom))]
    fn pool() -> &'static ClassPool<u8> {
        &U8_POOL
    }
}

impl PoolItem for f32 {
    #[cfg(not(loom))]
    fn pool() -> &'static ClassPool<f32> {
        &F32_POOL
    }
}

/// An owned `Vec<T>` drawn from (and returned to) the type's process
/// pool. Derefs to `Vec<T>`, so `push`/`extend_from_slice`/indexing all
/// work in place; dropping it anywhere recycles the buffer.
pub struct PooledVec<T: PoolItem> {
    buf: ManuallyDrop<Vec<T>>,
}

impl<T: PoolItem> PooledVec<T> {
    /// An empty pooled buffer (no capacity reserved until first use).
    pub fn new() -> Self {
        PooledVec { buf: ManuallyDrop::new(Vec::new()) }
    }

    /// A cleared pooled buffer with at least `cap` capacity.
    #[cfg(not(loom))]
    pub fn with_capacity(cap: usize) -> Self {
        PooledVec { buf: ManuallyDrop::new(T::pool().get(cap)) }
    }

    /// Loom builds have no global pools (see module docs): plain alloc.
    #[cfg(loom)]
    pub fn with_capacity(cap: usize) -> Self {
        PooledVec { buf: ManuallyDrop::new(Vec::with_capacity(cap)) }
    }

    /// Copy a slice into a pooled buffer (the hot-path constructor).
    pub fn from_slice(s: &[T]) -> Self
    where
        T: Clone,
    {
        let mut v = Self::with_capacity(s.len());
        v.extend_from_slice(s);
        v
    }

    /// Unwrap into a plain `Vec`, opting the buffer out of recycling.
    pub fn take(mut self) -> Vec<T> {
        // SAFETY: `self` is forgotten immediately after this take, so
        // `Drop` never runs on the now-empty `ManuallyDrop` — the inner
        // `Vec` is moved out exactly once.
        let v = unsafe { ManuallyDrop::take(&mut self.buf) };
        std::mem::forget(self);
        v
    }
}

impl<T: PoolItem> Drop for PooledVec<T> {
    fn drop(&mut self) {
        // SAFETY: `Drop` runs at most once, and the only other
        // `ManuallyDrop::take` site (`PooledVec::take`) forgets `self`
        // before `Drop` could run — so the inner `Vec` is still present
        // here and is moved out exactly once.
        let v = unsafe { ManuallyDrop::take(&mut self.buf) };
        #[cfg(not(loom))]
        T::pool().put(v);
        #[cfg(loom)]
        drop(v);
    }
}

impl<T: PoolItem> Default for PooledVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: PoolItem> Deref for PooledVec<T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        &self.buf
    }
}

impl<T: PoolItem> DerefMut for PooledVec<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.buf
    }
}

/// Adopt an existing `Vec` (it will recycle into the pool on drop).
impl<T: PoolItem> From<Vec<T>> for PooledVec<T> {
    fn from(v: Vec<T>) -> Self {
        PooledVec { buf: ManuallyDrop::new(v) }
    }
}

impl<T: PoolItem + Clone> Clone for PooledVec<T> {
    fn clone(&self) -> Self {
        let mut v = Self::with_capacity(self.len());
        v.extend_from_slice(self);
        v
    }
}

impl<T: PoolItem + std::fmt::Debug> std::fmt::Debug for PooledVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

impl<T: PoolItem + PartialEq> PartialEq for PooledVec<T> {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl<T: PoolItem + PartialEq> PartialEq<Vec<T>> for PooledVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        **self == *other
    }
}

impl<T: PoolItem + PartialEq> PartialEq<PooledVec<T>> for Vec<T> {
    fn eq(&self, other: &PooledVec<T>) -> bool {
        *self == **other
    }
}

impl<T: PoolItem + PartialEq> PartialEq<[T]> for PooledVec<T> {
    fn eq(&self, other: &[T]) -> bool {
        **self == other
    }
}

// Recycle-race models. Loom explores every interleaving of the two
// threads' get/put sequences against the class mutex and the stats
// counters; `tests/loom_models.rs` holds the cross-module protocols.
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::Arc;

    /// Two threads racing get/put on one class: stats account for every
    /// operation exactly once in every interleaving, no buffer is lost,
    /// and both buffers end up on the free list (the next two gets hit).
    #[test]
    fn concurrent_recycle_keeps_stats_and_buffers_consistent() {
        loom::model(|| {
            let pool = Arc::new(ClassPool::<u8>::new());
            let before = stats();
            let p = pool.clone();
            let t = loom::thread::spawn(move || {
                let v = p.get(8);
                assert!(v.capacity() >= 8);
                p.put(v);
            });
            let v = pool.get(8);
            assert!(v.capacity() >= 8);
            pool.put(v);
            t.join().unwrap();
            let after = stats();
            // exactly two gets and two successful returns, in every
            // interleaving (MAX_PER_CLASS is far above 2)
            assert_eq!(after.hits + after.misses, before.hits + before.misses + 2);
            assert_eq!(after.recycled, before.recycled + 2);
            // both buffers are on the free list: two more gets both hit
            let a = pool.get(8);
            let b = pool.get(8);
            let mid = stats();
            assert_eq!(mid.hits, after.hits + 2, "recycled buffers serve later gets");
            assert!(!std::ptr::eq(a.as_ptr(), b.as_ptr()), "distinct buffers");
        });
    }
}

// The global typed pools these exercise are compiled out under loom.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn class_math_covers_requests_and_returns() {
        assert_eq!(class_for_request(0), 0);
        assert_eq!(class_for_request(1), 0);
        assert_eq!(class_for_request(2), 1);
        assert_eq!(class_for_request(3), 2);
        assert_eq!(class_for_request(64), 6);
        assert_eq!(class_for_request(65), 7);
        assert_eq!(class_for_return(1), 0);
        assert_eq!(class_for_return(64), 6);
        assert_eq!(class_for_return(127), 6);
        assert_eq!(class_for_return(128), 7);
        // the capacity invariant below the open-ended last class: the
        // smallest capacity stored in class k is 2^k, and the largest
        // request routed to k is exactly 2^k — so every stored buffer
        // serves every request of its class
        for k in 1..CLASSES - 1 {
            assert_eq!(class_for_request(1 << k), k, "largest request of class {k}");
            assert_eq!(class_for_request((1 << k) + 1), k + 1, "first request past class {k}");
            assert_eq!(class_for_return(1 << k), k, "smallest buffer stored in class {k}");
            assert_eq!(class_for_return((1 << (k + 1)) - 1), k, "largest buffer in class {k}");
        }
    }

    #[test]
    fn get_after_put_reuses_the_buffer() {
        let pool: ClassPool<u64> = ClassPool::new();
        let mut v = pool.get(100);
        assert!(v.capacity() >= 100);
        v.extend(0..100u64);
        let cap = v.capacity();
        let ptr = v.as_ptr();
        pool.put(v);
        let back = pool.get(100);
        assert_eq!(back.as_ptr(), ptr, "same buffer comes back");
        assert_eq!(back.capacity(), cap);
        assert!(back.is_empty(), "recycled buffers come back cleared");
    }

    #[test]
    fn pooled_vec_roundtrips_through_drop() {
        // a size class no other concurrently-running test touches, so
        // the pointer identity below cannot race another taker
        const CAP: usize = (1 << 21) + 3;
        let mut a = PooledVec::<f32>::with_capacity(CAP);
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(a.len(), 3);
        assert_eq!(a[1], 2.0);
        let ptr = a.as_ptr();
        drop(a);
        let b = PooledVec::<f32>::with_capacity(CAP);
        assert_eq!(b.as_ptr(), ptr, "same-class request gets the recycled buffer");
    }

    #[test]
    fn pooled_vec_equality_and_clone() {
        let a = PooledVec::<f32>::from_slice(&[0.5, -1.0]);
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a, vec![0.5, -1.0]);
        assert_eq!(vec![0.5, -1.0], a);
        assert_ne!(a, vec![0.5]);
        assert_eq!(format!("{a:?}"), "[0.5, -1.0]");
    }

    #[test]
    fn take_opts_out_of_recycling() {
        let mut a = PooledVec::<u8>::with_capacity(8);
        a.push(7);
        let v = a.take();
        assert_eq!(v, vec![7u8]);
        // adopted vecs recycle on drop
        let adopted: PooledVec<u8> = v.into();
        drop(adopted);
    }

    #[test]
    fn stats_move_and_hit_rate_is_bounded() {
        let before = stats();
        let v = PooledVec::<u8>::with_capacity(1 << 20); // surely a fresh class entry
        drop(v);
        let _again = PooledVec::<u8>::with_capacity(1 << 20);
        let after = stats();
        assert!(after.hits + after.misses > before.hits + before.misses);
        assert!(after.recycled > before.recycled);
        let r = after.hit_rate();
        assert!((0.0..=1.0).contains(&r));
    }
}
