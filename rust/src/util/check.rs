//! Micro property-testing helper (proptest substitute).
//!
//! `check(cases, |rng| ...)` runs a property over a deterministic random
//! stream; on failure it panics with the case index and seed so the case
//! reproduces exactly. No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Run `prop` for `cases` pseudo-random cases. The closure gets a fresh
/// seeded RNG per case; return `Err(msg)` (or panic) to fail.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            let v = rng.gen_below(100);
            if v < 100 {
                Err(format!("always fails, v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("record", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
