//! Line-oriented `key value` text format.
//!
//! The artifact metadata (`manifest.txt`), the exported weights
//! (`weights.txt`) and the run configuration files all use this format —
//! one `key value...` pair per line, `#` comments, order-insensitive.
//! `python/compile/aot.py` writes it with plain `print`, Rust parses it
//! here; no JSON library exists on either side of this offline image that
//! both halves share, and this format is trivially diffable.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed key→value map (values are raw strings; typed accessors below).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KvMap {
    entries: BTreeMap<String, String>,
}

impl KvMap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from text. Later duplicate keys override earlier ones.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line
                .split_once(char::is_whitespace)
                .with_context(|| format!("line {}: expected `key value`", lineno + 1))?;
            entries.insert(key.to_string(), value.trim().to_string());
        }
        Ok(KvMap { entries })
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.entries
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("missing key `{key}`"))
    }

    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("key `{key}` is not an integer"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64> {
        self.get(key)?.parse().with_context(|| format!("key `{key}` is not an integer"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.parse().with_context(|| format!("key `{key}` is not a float"))
    }

    pub fn get_f32(&self, key: &str) -> Result<f32> {
        self.get(key)?.parse().with_context(|| format!("key `{key}` is not a float"))
    }

    /// Comma-separated list of integers.
    pub fn get_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .split(',')
            .map(|s| s.trim().parse().with_context(|| format!("key `{key}`: bad integer")))
            .collect()
    }

    /// Comma-separated list of floats.
    pub fn get_f32_list(&self, key: &str) -> Result<Vec<f32>> {
        self.get(key)?
            .split(',')
            .map(|s| s.trim().parse().with_context(|| format!("key `{key}`: bad float")))
            .collect()
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, key: &str) -> Result<Vec<String>> {
        Ok(self.get(key)?.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Serialize (sorted by key).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.entries {
            let _ = writeln!(out, "{k} {v}");
        }
        out
    }
}

/// Parse a whitespace-separated list of floats (bias rows etc.).
pub fn parse_floats(s: &str) -> Result<Vec<f32>> {
    s.split_whitespace()
        .map(|tok| tok.parse().with_context(|| format!("bad float `{tok}`")))
        .collect()
}

/// Parse a whitespace-separated list of small integers (the weights file's
/// code rows). Returns an error on any value > 15 when `four_bit` is set.
pub fn parse_codes(s: &str, four_bit: bool) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    for tok in s.split_whitespace() {
        let v: u8 = tok.parse().with_context(|| format!("bad code `{tok}`"))?;
        if four_bit && v > 15 {
            bail!("code {v} out of 4-bit range");
        }
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_access() {
        let kv = KvMap::parse("# comment\nbatch 8\ndims 64,32,10\nacc 0.97\n").unwrap();
        assert_eq!(kv.get_usize("batch").unwrap(), 8);
        assert_eq!(kv.get_usize_list("dims").unwrap(), vec![64, 32, 10]);
        assert!((kv.get_f64("acc").unwrap() - 0.97).abs() < 1e-12);
        assert!(kv.get("nope").is_err());
    }

    #[test]
    fn render_roundtrip() {
        let mut kv = KvMap::new();
        kv.set("a", 1);
        kv.set("b", "x,y");
        let back = KvMap::parse(&kv.render()).unwrap();
        assert_eq!(kv, back);
    }

    #[test]
    fn bad_lines_error() {
        assert!(KvMap::parse("keyonly\n").is_err());
    }

    #[test]
    fn codes_validate_range() {
        assert_eq!(parse_codes("1 2 15", true).unwrap(), vec![1, 2, 15]);
        assert!(parse_codes("16", true).is_err());
        assert!(parse_codes("16", false).is_ok());
    }
}
