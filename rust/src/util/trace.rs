//! Per-request tracing: a fixed-capacity lock-free ring-buffer flight
//! recorder per process.
//!
//! A trace id is assigned at the outermost tier a request enters — the
//! router's connection reader for routed traffic, the coordinator's
//! admission path for direct traffic — by counter-based 1-in-N sampling
//! (`trace.sample_every`; 0 disables). A nonzero id received on the
//! wire is never reassigned, which is what lets one routed request's
//! spans from two processes stitch into one timeline.
//!
//! Each stage span is four Relaxed atomic stores into a pre-allocated
//! ring cell, so recording is allocation-free and lock-free on the
//! serving hot path (`tests/hot_path_allocs.rs` pins this with tracing
//! on). The ring overwrites oldest-first; a reader that races a writer
//! on the wraparound cell may observe a torn span (fields from two
//! different spans) — benign for a monitoring dump, and bounded to at
//! most one cell per concurrent writer. Dumps render as Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto "X" complete
//! events): `ts` is wall-clock µs from a per-recorder epoch captured at
//! construction, so independently dumped processes share a clock to
//! within SystemTime skew.
//!
//! Ordering audit: every atomic access here is Relaxed by design. The
//! ring is monitoring state — a dump is a statistical view, not a
//! consistent cut, and no other memory is published through these
//! atomics.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Instant, SystemTime};

/// Pipeline stages a request passes through, ingress → write-back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Wire read → handed to dispatch/admission (router or server).
    Ingress = 0,
    /// Admission-gate decision (plan lookup + try_admit).
    Admission = 1,
    /// Enqueued in a batcher lane → batch formed.
    QueueWait = 2,
    /// Batch assembly: flatten + worker hand-off.
    BatchForm = 3,
    /// Host-side planned LUT-GEMM compute.
    Gemm = 4,
    /// Calibrated-backend reply gate (simulated-CiM latency wait).
    CalibratedGate = 5,
    /// Reply fan-out: logits copied and written to the client queue.
    WriteBack = 6,
}

/// Number of [`Stage`] variants (per-stage histogram array length).
pub const N_STAGES: usize = 7;

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Ingress,
        Stage::Admission,
        Stage::QueueWait,
        Stage::BatchForm,
        Stage::Gemm,
        Stage::CalibratedGate,
        Stage::WriteBack,
    ];

    /// Stable wire/JSON name (also the Prometheus `stage` label).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Ingress => "ingress",
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::Gemm => "gemm",
            Stage::CalibratedGate => "calibrated_gate",
            Stage::WriteBack => "write_back",
        }
    }

    fn from_u64(v: u64) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }
}

/// One pre-allocated ring cell. `trace == 0` marks an empty cell; a
/// wraparound race can tear fields across two spans (module docs).
#[derive(Default)]
struct SpanCell {
    trace: AtomicU64,
    stage: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

/// One recorded span, read back out of the ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub trace: u64,
    pub stage: Stage,
    /// Wall-clock µs since the Unix epoch (shared across processes).
    pub start_us: u64,
    pub dur_us: u64,
}

/// SplitMix64 finalizer: bijective avalanche mix for trace-id spreading.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Fixed-capacity lock-free flight recorder (module docs).
pub struct FlightRecorder {
    /// Tier label rendered into every event (`"server"` / `"router"`).
    role: &'static str,
    cells: Box<[SpanCell]>,
    cursor: AtomicU64,
    /// 1-in-N ingress sampling period; 0 disables sampling entirely.
    sample_every: u64,
    seq: AtomicU64,
    /// Per-process entropy folded into sampled trace ids so two
    /// processes sampling the same sequence numbers don't collide.
    base: u64,
    /// `SystemTime` µs at construction — the wall anchor for `ts`.
    epoch_wall_us: u64,
    epoch: Instant,
    /// Chrome `tid`: distinguishes recorders sharing one OS pid (the
    /// in-process fleet tests run router + backends in one process).
    tid: u64,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("role", &self.role)
            .field("capacity", &self.cells.len())
            .field("sample_every", &self.sample_every)
            .field("tid", &self.tid)
            .finish()
    }
}

impl FlightRecorder {
    /// Pre-allocate a recorder. `capacity` is clamped to ≥ 1; config
    /// validation bounds it to 64..=4096 so a JSON dump always fits one
    /// wire frame.
    pub fn new(role: &'static str, capacity: usize, sample_every: u64) -> Arc<FlightRecorder> {
        static NEXT_TID: AtomicU64 = AtomicU64::new(1);
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let wall = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .unwrap_or_default();
        let epoch_wall_us = wall.as_micros() as u64;
        let base = mix(epoch_wall_us ^ (std::process::id() as u64) ^ (tid << 48));
        let cells: Vec<SpanCell> =
            (0..capacity.max(1)).map(|_| SpanCell::default()).collect();
        Arc::new(FlightRecorder {
            role,
            cells: cells.into_boxed_slice(),
            cursor: AtomicU64::new(0),
            sample_every,
            seq: AtomicU64::new(0),
            base,
            epoch_wall_us,
            epoch: Instant::now(),
            tid,
        })
    }

    /// Counter-based 1-in-N sampling decision at ingress: every
    /// `sample_every`-th call returns a fresh nonzero trace id, the
    /// rest return 0 (untraced). 0 never collides with a real id.
    pub fn sample(&self) -> u64 {
        if self.sample_every == 0 {
            return 0;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if seq % self.sample_every != 0 {
            return 0;
        }
        let id = mix(self.base.wrapping_add(seq));
        if id == 0 {
            1
        } else {
            id
        }
    }

    /// Wall-clock µs for an `Instant` taken after construction.
    pub fn wall_us(&self, t: Instant) -> u64 {
        let since = t.checked_duration_since(self.epoch).unwrap_or_default();
        self.epoch_wall_us + since.as_micros() as u64
    }

    /// Record one stage span. No-op for untraced requests (`trace == 0`)
    /// — the hot path pays one branch. Allocation-free.
    pub fn record(&self, trace: u64, stage: Stage, start: Instant, end: Instant) {
        if trace == 0 {
            return;
        }
        let start_us = self.wall_us(start);
        let dur_us = end.checked_duration_since(start).unwrap_or_default().as_micros() as u64;
        self.record_at(trace, stage, start_us, dur_us);
    }

    /// Record a span from precomputed wall coordinates (used where a
    /// stage's position is derived arithmetically, e.g. splitting a
    /// worker's batch wall time into GEMM + calibrated gate).
    pub fn record_at(&self, trace: u64, stage: Stage, start_us: u64, dur_us: u64) {
        if trace == 0 {
            return;
        }
        let idx = (self.cursor.fetch_add(1, Ordering::Relaxed) % self.cells.len() as u64) as usize;
        let cell = &self.cells[idx];
        cell.trace.store(trace, Ordering::Relaxed);
        cell.stage.store(stage as u64, Ordering::Relaxed);
        cell.start_us.store(start_us, Ordering::Relaxed);
        // Chrome renders dur 0 as invisible; clamp to the 1 µs floor.
        cell.dur_us.store(dur_us.max(1), Ordering::Relaxed);
    }

    /// Read every recorded span, oldest-state included, sorted by start
    /// time. Allocates — admin/dump path only.
    pub fn events(&self) -> Vec<SpanEvent> {
        let mut out: Vec<SpanEvent> = self
            .cells
            .iter()
            .filter_map(|c| {
                let trace = c.trace.load(Ordering::Relaxed);
                if trace == 0 {
                    return None;
                }
                // A torn wraparound cell can hold an out-of-range stage
                // word mid-store only if Stage grows past u8 — it can't
                // today, but skip defensively rather than panic.
                let stage = Stage::from_u64(c.stage.load(Ordering::Relaxed))?;
                Some(SpanEvent {
                    trace,
                    stage,
                    start_us: c.start_us.load(Ordering::Relaxed),
                    dur_us: c.dur_us.load(Ordering::Relaxed),
                })
            })
            .collect();
        out.sort_by_key(|e| (e.start_us, e.stage as u8));
        out
    }

    /// Render the ring as Chrome trace-event JSON (`{"traceEvents":
    /// [...]}` — "X" complete events; load in `chrome://tracing` or
    /// Perfetto). `pid` is the OS process id, `tid` the per-process
    /// recorder index, so a merged multi-process dump keeps tiers on
    /// separate tracks. Admin path: allocates freely.
    pub fn dump_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\"traceEvents\":[");
        for (i, e) in self.events().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{},\"tid\":{},\"args\":{{\"trace\":\"{:#018x}\",\"role\":\"{}\"}}}}",
                e.stage.name(),
                self.role,
                e.start_us,
                e.dur_us,
                std::process::id(),
                self.tid,
                e.trace,
                self.role,
            );
        }
        out.push_str("]}");
        out
    }
}

/// Merge several `dump_json` outputs (one per process/tier) into one
/// Chrome trace document. String-level: each part's `traceEvents` array
/// body is spliced into a single array — valid because this crate
/// controls the emitted shape exactly.
pub fn merge_trace_dumps(parts: &[String]) -> String {
    let mut out = String::new();
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for part in parts {
        let Some(open) = part.find('[') else { continue };
        let Some(close) = part.rfind(']') else { continue };
        let body = part[open + 1..close].trim();
        if body.is_empty() {
            continue;
        }
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(body);
    }
    out.push_str("]}");
    out
}

/// One event pulled back out of a trace dump (test/tooling helper).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsedEvent {
    pub name: String,
    pub ts: u64,
    pub dur: u64,
    pub pid: u64,
    pub tid: u64,
    /// The `args.trace` hex string, e.g. `"0x00000000deadbeef"`.
    pub trace: String,
}

/// Parse a dump produced by [`FlightRecorder::dump_json`] /
/// [`merge_trace_dumps`] back into events. Not a general JSON parser —
/// it walks exactly the shape this module emits (tests use it to assert
/// cross-process stitching over the wire-dumped artifact).
pub fn parse_trace_json(json: &str) -> Vec<ParsedEvent> {
    fn grab_str(chunk: &str, key: &str) -> Option<String> {
        let pat = format!("\"{key}\":\"");
        let at = chunk.find(&pat)? + pat.len();
        let end = chunk[at..].find('"')? + at;
        Some(chunk[at..end].to_string())
    }
    fn grab_u64(chunk: &str, key: &str) -> Option<u64> {
        let pat = format!("\"{key}\":");
        let at = chunk.find(&pat)? + pat.len();
        let digits: String = chunk[at..].chars().take_while(|c| c.is_ascii_digit()).collect();
        digits.parse().ok()
    }
    json.split("{\"name\":\"")
        .skip(1)
        .filter_map(|chunk| {
            let end = chunk.find('"')?;
            Some(ParsedEvent {
                name: chunk[..end].to_string(),
                ts: grab_u64(chunk, "ts")?,
                dur: grab_u64(chunk, "dur")?,
                pid: grab_u64(chunk, "pid")?,
                tid: grab_u64(chunk, "tid")?,
                trace: grab_str(chunk, "trace")?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn sampling_is_one_in_n_and_ids_are_unique_nonzero() {
        let r = FlightRecorder::new("server", 64, 4);
        let ids: Vec<u64> = (0..16).map(|_| r.sample()).collect();
        let sampled: Vec<u64> = ids.iter().copied().filter(|&id| id != 0).collect();
        assert_eq!(sampled.len(), 4, "1-in-4 of 16 calls: {ids:?}");
        let mut uniq = sampled.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), sampled.len(), "ids are distinct");
    }

    #[test]
    fn sample_every_zero_disables_tracing() {
        let r = FlightRecorder::new("server", 64, 0);
        assert!((0..32).all(|_| r.sample() == 0));
    }

    #[test]
    fn untraced_records_are_no_ops() {
        let r = FlightRecorder::new("server", 8, 1);
        let t = Instant::now();
        r.record(0, Stage::Gemm, t, t);
        assert!(r.events().is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_at_capacity() {
        let r = FlightRecorder::new("server", 4, 1);
        for i in 0..10u64 {
            r.record_at(100 + i, Stage::Ingress, 1000 + i, 5);
        }
        let ev = r.events();
        assert_eq!(ev.len(), 4, "capacity bounds retained spans");
        let traces: Vec<u64> = ev.iter().map(|e| e.trace).collect();
        assert_eq!(traces, [106, 107, 108, 109], "oldest spans were overwritten");
    }

    #[test]
    fn events_are_sorted_and_wall_anchored() {
        let r = FlightRecorder::new("server", 16, 1);
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_micros(300);
        let t2 = t0 + Duration::from_micros(100);
        r.record(7, Stage::Gemm, t1, t1 + Duration::from_micros(50));
        r.record(7, Stage::Ingress, t2, t2 + Duration::from_micros(20));
        let ev = r.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].stage, Stage::Ingress, "sorted by start time");
        assert!(ev[0].start_us >= r.epoch_wall_us, "ts is wall-anchored");
        assert_eq!(ev[1].start_us - ev[0].start_us, 200);
    }

    #[test]
    fn dump_parses_back_bit_exactly() {
        let r = FlightRecorder::new("router", 16, 1);
        r.record_at(0xDEAD_BEEF, Stage::QueueWait, 12345, 67);
        r.record_at(0xDEAD_BEEF, Stage::WriteBack, 20000, 3);
        let parsed = parse_trace_json(&r.dump_json());
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "queue_wait");
        assert_eq!(parsed[0].ts, 12345);
        assert_eq!(parsed[0].dur, 67);
        assert_eq!(parsed[0].pid, std::process::id() as u64);
        assert_eq!(parsed[0].trace, format!("{:#018x}", 0xDEAD_BEEFu64));
        assert_eq!(parsed[1].name, "write_back");
    }

    #[test]
    fn merged_dumps_stitch_by_trace_across_recorders() {
        let router = FlightRecorder::new("router", 8, 1);
        let server = FlightRecorder::new("server", 8, 1);
        assert_ne!(router.tid, server.tid, "recorders get distinct tids");
        router.record_at(42, Stage::Ingress, 100, 10);
        server.record_at(42, Stage::Gemm, 120, 30);
        let merged =
            merge_trace_dumps(&[router.dump_json(), server.dump_json(), String::new()]);
        let parsed = parse_trace_json(&merged);
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].trace, parsed[1].trace, "one timeline by trace id");
        assert_ne!(parsed[0].tid, parsed[1].tid, "tracks stay separate");
        // merging an empty dump with empties is still a valid document
        assert_eq!(
            merge_trace_dumps(&[String::from("{\"traceEvents\":[]}")]),
            "{\"traceEvents\":[]}"
        );
    }

    #[test]
    fn stage_names_are_stable_and_roundtrip() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(Stage::from_u64(i as u64), Some(*s));
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u64(N_STAGES as u64), None);
    }
}
