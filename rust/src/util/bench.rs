//! Tiny measurement harness for the `cargo bench` targets.
//!
//! Criterion is unavailable offline; this provides the essentials:
//! warmup, fixed-duration measurement, mean / p50 / p95 per-iteration
//! timing, and a throughput helper. Output format is one stable line per
//! benchmark so EXPERIMENTS.md can quote it.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            self.items_per_iter * 1e9 / self.mean_ns
        }
    }

    pub fn report_line(&self) -> String {
        let mut line = format!(
            "bench {:<44} {:>12.1} ns/iter  p50 {:>12.1}  p95 {:>12.1}  ({} iters)",
            self.name, self.mean_ns, self.p50_ns, self.p95_ns, self.iters
        );
        if self.items_per_iter > 0.0 {
            line.push_str(&format!("  {:>12.0} items/s", self.throughput_per_sec()));
        }
        line
    }
}

/// Benchmark runner with fixed warmup and measurement budgets.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: Duration::from_millis(200), measure: Duration::from_millis(800) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: Duration::from_millis(30), measure: Duration::from_millis(150) }
    }

    pub fn new(warmup: Duration, measure: Duration) -> Self {
        Bencher { warmup, measure }
    }

    /// Run `f` repeatedly; `items` is the per-iteration work amount for
    /// throughput reporting (pass 1.0 when not meaningful).
    pub fn run<F: FnMut()>(&self, name: &str, items: f64, mut f: F) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure individual iterations.
        // lint: allow(alloc): measurement harness buffer, outside any
        // serving path (growth during a run would perturb samples, so
        // it pre-sizes once here).
        let mut samples_ns: Vec<f64> = Vec::with_capacity(4096);
        let t1 = Instant::now();
        while t1.elapsed() < self.measure {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let iters = samples_ns.len() as u64;
        let mean = samples_ns.iter().sum::<f64>() / iters.max(1) as f64;
        let pct = |p: f64| samples_ns[((p * (iters.max(1) - 1) as f64) as usize).min(samples_ns.len() - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            items_per_iter: items,
        };
        println!("{}", result.report_line());
        result
    }
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::new(Duration::from_millis(5), Duration::from_millis(20));
        let r = b.run("noop-ish", 1.0, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
    }
}
