//! Shared fixed-bucket log₂ latency histogram.
//!
//! Extracted from `coordinator::metrics` so every duration-shaped
//! metric in the crate (request latency, simulated CiM latency,
//! host-GEMM wall time, plan-cache compile/stall, per-stage and
//! per-tenant breakdowns) records into the same lock-free structure.
//!
//! Ordering audit: every atomic access here is Relaxed by design — the
//! histogram is monotonic monitoring state; a reader tolerates tearing
//! across buckets (a quantile is a statistical view, not a consistent
//! cut), and nothing is published through these atomics.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram (µs), 1 µs .. ~16 s.
///
/// The unit is nominal: the bucket math is unit-agnostic and callers
/// record nanoseconds into it too (see `Metrics::sim_latency`).
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^{i+1}) µs.
    buckets: [AtomicU64; 24],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(23);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from the bucket histogram (upper bound of the
    /// containing bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::check::check;

    #[test]
    fn quantiles_are_ordered_and_mean_positive() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 5000);
    }

    #[test]
    fn zero_clamps_to_the_resolution_floor() {
        let h = LatencyHistogram::default();
        h.record_us(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.quantile_us(0.5), 2, "0 lands in the [1, 2) bucket");
    }

    /// Exact percentile of raw samples under the same ceil-rank rule the
    /// histogram walk uses.
    fn exact_percentile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len();
        let rank = ((q * n as f64).ceil() as usize).max(1).min(n);
        sorted[rank - 1]
    }

    /// Property: against exact percentiles computed from the raw
    /// samples, every histogram quantile is an upper bound that is tight
    /// to within one log₂ bucket — `exact <= hist < 2 * max(exact, 1) + 1`
    /// (the containing bucket's upper bound is at most one doubling
    /// above the exact sample).
    #[test]
    fn quantiles_bound_exact_percentiles_within_one_bucket() {
        check("hist quantile vs exact percentile", 50, |rng| {
            let n = 1 + rng.gen_below(400) as usize;
            let h = LatencyHistogram::default();
            let mut raw = Vec::new();
            for _ in 0..n {
                // span the full bucket range: mix tiny and huge samples
                let bits = rng.gen_below(23);
                let us = rng.gen_below(1u64 << (bits + 1)).max(1);
                h.record_us(us);
                raw.push(us);
            }
            raw.sort_unstable();
            for q in [0.01, 0.25, 0.50, 0.90, 0.99, 1.0] {
                let exact = exact_percentile(&raw, q);
                let est = h.quantile_us(q);
                prop_assert!(
                    est >= exact,
                    "q={q}: histogram {est} below exact {exact} (n={n})"
                );
                prop_assert!(
                    est <= 2 * exact.max(1),
                    "q={q}: histogram {est} above one-bucket bound of exact {exact} (n={n})"
                );
            }
            let mean = h.mean_us();
            let exact_mean = raw.iter().sum::<u64>() as f64 / n as f64;
            prop_assert!(
                (mean - exact_mean).abs() < 1e-6,
                "mean {mean} != exact {exact_mean}"
            );
            prop_assert!(h.max_us() == *raw.last().unwrap(), "max is exact");
            Ok(())
        });
    }
}
