//! In-tree substitutes for crates unavailable in this offline image
//! (DESIGN.md §2 documents the substitutions):
//!
//! * [`rng`] — a deterministic SplitMix64 PRNG (replaces `rand` /
//!   `rand_chacha` for the paper's random studies; determinism is a
//!   feature here — every figure regenerates bit-identically);
//! * [`oneshot`] — a minimal blocking oneshot channel (replaces the tokio
//!   oneshot on the worker reply path);
//! * [`kv`] — a line-oriented `key value` text format shared with
//!   `python/compile/aot.py` (replaces serde_json for the manifest,
//!   weights and config files);
//! * [`bench`] — a tiny measurement harness used by the `cargo bench`
//!   targets (replaces criterion: warmup + timed iterations + mean/p50).
//! * [`check`] — a micro property-testing helper (replaces proptest):
//!   runs a closure over a deterministic random stream and reports the
//!   failing seed.
//! * [`pool`] — size-classed recycled buffers ([`PooledVec`]) backing
//!   the zero-allocation serving hot path;
//! * [`queue`] — a steady-state allocation-free MPMC queue (replaces
//!   `std::sync::mpsc`, which allocates message blocks, on the serving
//!   hot path).
//! * [`sync`] — the std/loom synchronization shim every concurrent
//!   module imports its primitives through, so `--cfg loom` swaps the
//!   whole crate onto loom's model-checked versions.
//! * [`hist`] — the shared lock-free log₂ latency histogram behind every
//!   duration metric (replaces per-module p50/p99 bookkeeping).
//! * [`trace`] — the per-process flight recorder: fixed-capacity
//!   lock-free span ring + Chrome trace-event JSON dumps (replaces any
//!   tracing/perfetto crate; see the crate docs' `## Observability`).

pub mod bench;
pub mod check;
pub mod hist;
pub mod kv;
pub mod oneshot;
pub mod pool;
pub mod queue;
pub mod rng;
pub mod sync;
pub mod trace;

pub use pool::{ClassPool, PoolItem, PoolStats, PooledVec};
pub use rng::Rng;

/// Create a unique scratch directory under the system temp dir
/// (tempfile-crate substitute for tests; not auto-deleted).
pub fn test_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "luna-cim-{tag}-{}-{n}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("create test dir");
    dir
}
