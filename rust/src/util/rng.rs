//! Deterministic SplitMix64 PRNG.
//!
//! Quality is more than sufficient for workload generation and Monte-Carlo
//! studies (passes the usual avalanche checks); the point is bit-exact
//! reproducibility of every figure across runs and platforms.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (Lemire's method, unbiased for our n ≪ 2^64).
    pub fn gen_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection sampling on the top bits
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.gen_below(hi - lo)
    }

    /// Uniform i64 in `[lo, hi)`.
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        assert!(lo < hi);
        lo + (hi - lo) * self.gen_f64() as f32
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Uniform 4-bit operand (the paper's random pairs).
    pub fn gen_u4(&mut self) -> u8 {
        self.gen_below(16) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_below_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(7);
        let mut seen = [false; 16];
        for _ in 0..2000 {
            let v = r.gen_below(16) as usize;
            assert!(v < 16);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all 16 values reachable");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::seed_from_u64(9);
        let mean: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn bool_probability_respected() {
        let mut r = Rng::seed_from_u64(11);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.03);
    }
}
