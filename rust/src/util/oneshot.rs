//! Minimal oneshot channel over `std::sync::mpsc`.
//!
//! The worker pool replies through these; `recv` blocks the calling
//! (client) thread, which is the concurrency model of the std-thread
//! coordinator (no async runtime in this offline image).
//!
//! lint: allow-file(mpsc): this module IS the mpsc wrapper — in-process
//! `repro serve` clients block on it, but the wire serving hot path
//! replies through `util::queue` and never constructs one.

use std::sync::mpsc;
use std::time::Duration;

pub struct Sender<T>(mpsc::SyncSender<T>);
pub struct Receiver<T>(mpsc::Receiver<T>);

/// Create a oneshot pair.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let (tx, rx) = mpsc::sync_channel(1);
    (Sender(tx), Receiver(rx))
}

impl<T> Sender<T> {
    /// Send the value; returns it back if the receiver is gone.
    pub fn send(self, value: T) -> Result<(), T> {
        self.0.try_send(value).map_err(|e| match e {
            mpsc::TrySendError::Full(v) | mpsc::TrySendError::Disconnected(v) => v,
        })
    }
}

impl<T> Receiver<T> {
    /// Block until the value arrives (None if the sender dropped).
    pub fn recv(self) -> Option<T> {
        self.0.recv().ok()
    }

    /// Block with a timeout.
    pub fn recv_timeout(self, dur: Duration) -> Option<T> {
        self.0.recv_timeout(dur).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (tx, rx) = channel();
        tx.send(41).unwrap();
        assert_eq!(rx.recv(), Some(41));
    }

    #[test]
    fn dropped_sender_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn dropped_receiver_returns_value() {
        let (tx, rx) = channel();
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn cross_thread() {
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            let _ = tx.send("hi");
        });
        assert_eq!(rx.recv(), Some("hi"));
    }
}
