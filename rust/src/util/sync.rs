//! loom-aware synchronization primitives.
//!
//! Every hot-path concurrency primitive ([`super::queue`], the class
//! mutexes in [`super::pool`], the admission gate in
//! [`crate::coordinator::admission`]) imports `Mutex`/`Condvar`/`Arc` and
//! the `atomic` module from here instead of `std::sync`. In a normal
//! build the re-exports *are* `std::sync` — zero cost, zero behavioral
//! difference. Under `RUSTFLAGS="--cfg loom"` they become loom's
//! model-checked versions, and the `#[cfg(loom)]` model suites
//! (`tests/loom_models.rs` plus in-module models) exhaustively explore
//! every interleaving of the protocols built on them:
//!
//! * the queue's sender/receiver-count close-and-drain protocol,
//! * the `ReplyTicket` exactly-once drop-guard delivery,
//! * pool recycle races and stats consistency,
//! * the admission count's never-exceeds / never-leaks invariant.
//!
//! Run the models with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_models
//! ```
//!
//! Shim contract (what loom's types do NOT support, and the repo rules
//! that follow):
//!
//! * loom primitives are not const-constructible — statics built on the
//!   shim must be gated `#[cfg(not(loom))]` (see the typed pool statics
//!   in [`super::pool`]); under loom, code paths that would touch them
//!   take a model-local or bypass route instead.
//! * loom primitives cannot cross model iterations — anything shimmed
//!   must be created inside `loom::model(|| ...)`.
//! * loom's `Arc` has no `downgrade`/`Weak` — the coordinator's
//!   background threads keep `std::sync::Arc` (they are not modeled;
//!   only the admission atomic they share moved onto the shim, inside
//!   [`crate::coordinator::admission::AdmissionGate`]).

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
