//! # LUNA-CIM — Lookup-Table based Programmable Neural Processing in Memory
//!
//! Full-system reproduction of the LUNA-CIM paper (Dehghanzadeh, Chatterjee,
//! Bhunia, 2023). The paper proposes LUT-based 4-bit multiplication inside
//! SRAM arrays using a divide-and-conquer (D&C) decomposition, plus two
//! approximate variants. This crate provides:
//!
//! * the **hardware substrate** the paper evaluates on (gate-level netlists,
//!   an event-driven logic simulator, a calibrated 65 nm-like standard-cell
//!   library, and an SRAM-array cost model) — see [`logic`], [`cells`],
//!   [`sram`];
//! * the **paper's contribution**: all five LUT-multiplier configurations
//!   (traditional, D&C, optimized D&C, ApproxD&C, ApproxD&C 2) as both
//!   behavioural models and structural netlists, generalized to arbitrary
//!   even bit-widths — see [`multiplier`];
//! * the **LUNA-CiM unit/bank abstraction** (SRAM array + multiplier +
//!   weight-programming protocol) — see [`luna`];
//! * the **analysis suite** regenerating every figure of the paper's
//!   evaluation (probability, Hamming distance, error maps, NN MAE) — see
//!   [`analysis`];
//! * a **quantized neural-network substrate** (bit-accurate functional model
//!   cross-checked against the AOT-compiled JAX/Pallas artifacts) — see
//!   [`nn`];
//! * the **serving coordinator**: request queue, dynamic batcher, worker
//!   pool over pluggable execution backends, the bank scheduler that
//!   maps matmuls onto LUNA units with energy/latency accounting, and
//!   multi-tenant model hosting behind a byte-budgeted compiled-plan
//!   cache with hot model swap (see `## Multi-tenant serving`) — see
//!   [`coordinator`];
//! * the **execution backends**: the native batched LUT-GEMM (default,
//!   zero external dependencies), the calibrated-timing backend (native
//!   numerics + per-worker schedule replay and optional simulated-latency
//!   gating), and the PJRT wrapper (feature `pjrt`) — see [`engine`];
//! * the **artifact store and PJRT runtime** that load the outputs of
//!   `python/compile/aot.py` — see [`runtime`] (the PJRT client itself
//!   is gated behind the `pjrt` cargo feature);
//! * the **network layer** that turns the coordinator into an actual
//!   service: a versioned binary wire protocol, a threaded TCP
//!   front-end with 429-style admission rejections, the matching
//!   client, the `repro route` front-tier router (multi-process
//!   shard-out — see `## Router tier`) and the `repro loadgen` traffic
//!   generator, plus the observability surface: per-request trace ids
//!   carried on the wire, a lock-free flight recorder per process, and
//!   the `repro stats` / `repro trace` scrape commands (see
//!   `## Observability`) — see [`net`] and the `## Wire protocol`
//!   section below;
//! * [`report`] — text/CSV regenerators for every table and figure.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request path is pure Rust + PJRT.
//!
//! ## Kernel architecture
//!
//! The host-side realization of the paper's "a LUT load is cheaper than
//! a multiply" claim went through three generations, all bit-exact with
//! the per-sample [`nn::QuantMlp::forward`] for every
//! [`multiplier::MultiplierKind`]:
//!
//! 1. **Scalar** — one [`multiplier::MultiplierModel::mul`] per MAC,
//!    plus per-sample quantize and allocation overhead.
//! 2. **Flat-gather** ([`nn::QuantLinear::gemm_batch_into`]) — the batch
//!    quantized once per layer, the zero-point correction hoisted per
//!    input row, but still a fresh 2D index `(w << 4) | x` and a random
//!    256-entry gather for every MAC.
//! 3. **Planned** ([`nn::MlpPlan`], the execution engine behind
//!    `backend native` and `calibrated`) — built on the observation
//!    that weights are static while activations arrive per request:
//!
//!    * *Plan compilation* (once, at backend construction): each weight
//!      row's column indices are counting-sorted into 16 buckets, one
//!      per 4-bit code — a CSR over codes ([`nn::LayerPlan`]).
//!    * *LUT-strip expansion* (once per input row): the 256-entry
//!      product table expands to a `16 × in_dim` strip
//!      `g[w][j] = table[(w << 4) | x_j]` of `i16` products
//!      (L1-resident). Every output row of that input then runs
//!      sequential column reads + strip adds — zero per-MAC index
//!      arithmetic, and the strip cost amortizes over `out_dim` rows.
//!      Narrow heads (`out_dim < 16`) can't amortize 16 strip rows and
//!      fall back to the flat gather per layer, decided at compile
//!      time — bit-identical arithmetic on both paths.
//!    * *SWAR strip accumulate* (the portable baseline, `gemm.simd
//!      swar`): within each bucket segment, four gathered strip
//!      products pack into one `u64` as 4×16-bit lanes, collapsing
//!      four adds into one 64-bit add. Strip products are
//!      multiplier-table bytes (`u8`, so ≤ 255 even for approximate
//!      tables) and lanes flush into a wide sum every 256 packed adds
//!      (256 · 255 < 2¹⁶), so no lane can carry into its neighbour —
//!      integer addition being associative, the result is bit-identical
//!      to the retained scalar path (the tail for short segments, and
//!      the reference kernel the benches race against).
//!    * *Runtime-dispatched SIMD strips* ([`nn::GemmSimd`], `gemm.simd`
//!      config, `--gemm-simd` on `repro serve`): plan compilation
//!      resolves `auto` to the best [`nn::StripKernel`] the host
//!      actually has — AVX2 (`_mm256_i32gather_epi32` over an `i32`
//!      strip copy, eight lanes per step) behind
//!      `is_x86_feature_detected!`, NEON (`vpadalq_s16` pairwise
//!      widening) on aarch64, else the SWAR baseline, else scalar.
//!      Integer segment sums are exact in any order, so every kernel is
//!      bit-identical; forcing a kernel the host lacks falls back to
//!      SWAR instead of faulting. All `unsafe` is confined to the
//!      `simd` module of `src/nn/gemm.rs`, every block commented with
//!      the runtime-dispatch guard that makes it sound — a confinement
//!      `repro lint` enforces (rule `simd-confined`).
//!    * *Persistent worker pool + shape-adaptive tiling*
//!      (`gemm.threads` and `gemm.partition` config, `--gemm-threads` /
//!      `--gemm-partition` on `repro serve`, threads `0` = one per
//!      core): the plan owns long-lived workers parked on condvars,
//!      spawned once at backend construction and woken per batch with
//!      zero steady-state allocation (pinned by
//!      `tests/hot_path_allocs.rs`) — replacing the per-call
//!      `std::thread::scope` fan-out of kernel v2. `partition rows`
//!      splits batch rows into contiguous chunks (the throughput
//!      shape); `outputs` splits each layer's output rows into
//!      per-thread spans so even a batch of one fans out (the latency
//!      shape); `auto` picks rows when the batch can feed every thread
//!      and outputs otherwise. Every output element is accumulated by
//!      exactly one thread in the fixed integer order, so results are
//!      bit-identical for every kernel × tiling × thread count (the
//!      full matrix is pinned by `tests/gemm_plan.rs`). The default
//!      stays `threads 1`: worker threads already scale across
//!      batches, so in-batch fan-out is opt-in for big-batch or
//!      latency-critical deployments.
//!
//! `benches/lut_gemm.rs` races the kernel generations at serving
//! shapes and (`--save-json`) records MACs/s per kernel, the
//! dispatched SIMD variant plus host CPU features
//! ([`nn::host_cpu_features`]), and a batch-1 µs/inference column to
//! `BENCH_lut_gemm.json`; CI runs it on every push, asserts the
//! dispatch landed on a non-scalar kernel, and uploads the JSON as a
//! workflow artifact, so the perf trajectory accumulates data points. The
//! serving metrics report the host-side per-batch GEMM wall time next
//! to the simulated CiM latency (`host gemm` line in
//! [`coordinator::MetricsSnapshot::render`]), so host speed and fabric
//! speed are comparable from one report.
//!
//! ## Serving hot path
//!
//! Lookup only beats arithmetic when the data movement around it is
//! cheap, so the steady-state request path is **allocation-free and
//! contention-free** end to end (pinned by `tests/hot_path_allocs.rs`:
//! a counting global allocator proves zero heap allocations per warm
//! request over the loopback wire path).
//!
//! **Pooled buffer lifecycle.** Every hot-path buffer is a
//! [`util::PooledVec`] drawn from a process-wide size-classed pool
//! ([`util::pool`]) and returned on drop:
//!
//! ```text
//! socket ──▶ reader: decode via reusable payload scratch
//!            pixels ◀── pool          (Request frame, pooled)
//!        ──▶ submit: request carries the pixel buffer into a shard's
//!            batcher (admission = one shared atomic outstanding count)
//!        ──▶ flush: batch's request vec ◀── pool
//!            flatten_into: flat inputs ◀── pool   (no dead zero fill;
//!            only PJRT's fixed shape pads a zero tail)
//!        ──▶ worker (util::queue, allocation-free): planned GEMM writes
//!            logits ◀── pool; input buffer ──▶ pool
//!        ──▶ completion pool: fan out under the shard's waiter lock,
//!            reply frame logits ◀── pool; batch + pixels ──▶ pool
//!        ──▶ writer: encode via reusable scratch, flush socket,
//!            drop frame ──▶ logits back to pool
//! ```
//!
//! Worker jobs, worker replies and per-connection reply frames travel
//! over [`util::queue`] (`Mutex<VecDeque>` + condvar — steady-state
//! capacity, no per-send node like `std::sync::mpsc`), and the
//! coordinator-side tiler cost is memoized per batch size once the
//! fabric state is warm. The metrics' `pool` line (hits / misses /
//! recycled, hit rate) shows the pool converging.
//!
//! **Shard dispatch rules** (`batcher.shards`, `--shards`): a request's
//! shard is picked by `batcher.affinity` — `request` (default) assigns
//! request ids round-robin (`id % shards`), `connection` pins every
//! request of one wire connection to `conn % shards` so a connection's
//! traffic keeps one batcher lane (and its worker rotation) warm.
//! Either way the request lives entirely on that shard — its batcher
//! slot, its waiter entry, its batch — and batch ids encode their lane
//! (`seq·shards + shard`), so completion fan-out never needs to
//! re-derive a lane from request ids. Batches never mix shards, each
//! shard seeds the worker router at a disjoint rotation
//! (`shard + turn·shards`), and admission stays one global atomic bound
//! (`batcher.queue_depth`) so `retry_after_us` hints and reject totals
//! are exact across shards. Because the planned kernel accumulates each
//! output row independently in a fixed integer order, replies are
//! bit-identical for every shard count and either affinity
//! (`tests/net_serving.rs` sweeps shards ∈ {1, 2, 4} under both).
//!
//! **SWAR safety argument**: see the packed-lane bullet under
//! `## Kernel architecture` — bounded products (`u8` table entries,
//! ≤ 255) plus a flush every 256 packed adds keep every 16-bit lane
//! below overflow, so the packed sum equals the scalar sum exactly,
//! not approximately.
//!
//! ## Timing model
//!
//! The paper's claim is a hardware cost — energy per MAC and
//! LUT-programming overhead measured in TSMC 65 nm — so the serving
//! stack models CiM time, not just host time. The pieces:
//!
//! * **Calibration.** [`coordinator::UnitCosts`] measures one LUNA unit
//!   configuration directly on the gate-level model: average switching
//!   energy per multiply over a pseudo-random operand stream, the LUT
//!   write energy per programming, and the worst observed critical-path
//!   settle time (ps) from the event-driven simulator. The measurement is
//!   expensive, so it is memoized per process
//!   ([`coordinator::UnitCosts::measure_cached`]) and carried by value
//!   into every worker — never re-run per thread. The `ideal` multiplier
//!   has no netlist; its schedules are priced as the optimized D&C unit
//!   (logged once — see [`coordinator::Tiler::pricing_kind`]).
//!
//! * **Waves.** The [`coordinator::Tiler`] maps each layer's `out×in`
//!   grid of 4-bit weight codes onto the fabric's units round-robin, in
//!   `⌈elements / units⌉` *waves*: during a wave every unit is programmed
//!   once and then multiplies once per batch sample, so a layer costs
//!   `waves × batch` cycles and `latency_ps = total_cycles × cycle_ps`.
//!
//! * **Weight-stationarity.** Fabric state persists across batches: a
//!   unit already holding the required code skips the (re)programming —
//!   a *stationary hit*. Programming is orders of magnitude costlier
//!   than a multiply, so steady-state batches pay mostly MAC energy; the
//!   metrics report the hit-rate.
//!
//! * **`timing.time_scale`** (config) maps simulated picoseconds to
//!   wall-clock on `backend calibrated`: each batch's reply is held for
//!   `latency_ps × time_scale` (as wall ps). `0` — the default — is
//!   report-only: costs ride on replies and metrics but nothing sleeps;
//!   `1.0` would be real time (far below timer resolution here); values
//!   around `1e4`–`1e6` stretch the schedule into the µs–ms range so
//!   batching/queueing behaviour under CiM-speed serving is observable.
//!   `repro loadgen` against a gated `repro serve --listen` endpoint is
//!   the tool for the queueing-aware saturation studies: sweep offered
//!   load and compare the measured p99 against the waves model.
//!
//! ## Multi-tenant serving
//!
//! One coordinator hosts many model artifacts (`serving.models` in the
//! config, `--model id=dir` on `repro serve`): requests carry an
//! optional model id and are batched **per model** — a batch never
//! mixes tenants, so every single-tenant bit-identity guarantee holds
//! per tenant unchanged. An absent id means the default model
//! (`artifacts_dir`), so single-tenant deployments and v0.1 clients
//! are the degenerate case, not a special one.
//!
//! **Compiled-plan cache** ([`engine::PlanCache`]). Plan compilation
//! (the counting-sort described under `## Kernel architecture`) is the
//! expensive per-model step, so compiled [`nn::MlpPlan`]s live in a
//! byte-budgeted LRU keyed by model id (`plan_cache.max_bytes`, default
//! 64 MiB). Exact byte accounting (weights + plan heap), strict LRU
//! eviction, and **single-flight** compilation — concurrent cold misses
//! on one model block on a condvar while exactly one thread compiles;
//! per-model churn properties are pinned by `tests/plan_cache.rs`. An
//! entry larger than the whole budget is served uncached rather than
//! evicting the world. A cache *hit* is one lock, one map lookup and an
//! `Arc` clone — `tests/hot_path_allocs.rs` pins that warm two-tenant
//! traffic allocates nothing. Evicting a plan never changes results:
//! recompiles are bit-identical with the evicted plan for every
//! multiplier kind (same tests), so the budget is purely a
//! memory/latency trade-off. The metrics' `plan cache` line reports
//! hits / misses / evictions / compiles, resident bytes and the
//! compile and compile-stall p99s.
//!
//! **Hot model swap.** `LoadModel { model, dir }` installs a new
//! tenant on a live server (geometry must match the resident models);
//! `RetireModel { model }` drains it — new requests for the retiring
//! model get a retryable `Rejected`, in-flight ones complete, and the
//! `AdminOk` ack is sent only once nothing references the old weights,
//! so `AdminOk` *is* the "swap window open" signal. No connection is
//! dropped at any point; replacing a model is retire + load under live
//! traffic (pinned by the hot-swap battery in `tests/net_serving.rs`).
//!
//! **Fleet rule.** A router backend must agree with the fleet on the
//! *model set*, not just the dimensions — a backend serving a
//! different tenant list fails the handshake and quarantines, so a
//! model-tagged request never reaches a backend that would `Error` it.
//!
//! `repro loadgen --models N --mix zipf|uniform` drives a multi-tenant
//! mix and lands per-tenant goodput, plan-cache hit rate and
//! compile-stall p99 in `BENCH_serve.json`; per-model fabric
//! weight-stationarity shows up in `model_stats`.
//!
//! ## Wire protocol
//!
//! [`net::protocol`] implements the network framing (std-only; no
//! serde/protobuf in this offline image). This section is normative.
//!
//! **Frame layout.** Every frame is an 8-byte header plus a bounded
//! payload, all integers little-endian:
//!
//! ```text
//! offset  size  field
//! 0       2     magic "LC" (0x4C 0x43)
//! 2       1     version: (major << 4) | minor — currently 0x03 (v0.3)
//! 3       1     frame type
//! 4       4     payload length, u32 LE (<= 1 MiB)
//! 8       n     payload
//! ```
//!
//! Frame types (client → server): `Hello` (0x05, empty payload — must
//! be answerable before any model state is known, hence the fixed
//! header carries the version) and `Request` (0x01: `id u64`, `count
//! u32`, `count × f32` pixels, then — since minor 2 — an optional
//! trailing model id naming the tenant; absent means the default
//! model, so a default-model request is byte-identical with v0.1, and
//! — since minor 3 — an optional trailing `trace u64` naming the
//! request's distributed trace (see `## Observability`; a traced
//! default-model request encodes the model field too, keeping the
//! trailing-field order fixed). `id` is client-assigned and echoed on
//! the reply). Server → client:
//! `Info` (0x06: `in_dim u32, out_dim u32, max_batch u32, backend
//! string`, then — minor 2 — `count u32` + that many model-id strings,
//! the sorted non-default tenant list — the `Hello` answer),
//! `Response` (0x02: `id u64, label u32, latency_us u64`, then the
//! schedule-cost fields `energy_fj f64, latency_ps u64, programs u64,
//! stationary_hits u64`, then `count u32, count × f32` logits),
//! `Rejected` (0x03: `id u64, retry_after_us u64, reason string` — the
//! 429: admission control turned the request away; retry after the
//! hint; `retry_after_us = 0` means "retryable, no backoff will help
//! here" — a retiring model) and `Error` (0x04: `id u64, reason
//! string`). The minor-2 admin pair (see `## Multi-tenant serving`):
//! `LoadModel` (0x07: model id + `dir` string), `RetireModel` (0x08:
//! model id), each acknowledged by `AdminOk` (0x09: model id) or
//! answered by `Error`. The minor-3 observability pair (see
//! `## Observability`): `GetStats` (0x0a, empty) answered by `Stats`
//! (0x0b: the responder's serialized [`coordinator::MetricsSnapshot`]
//! and/or [`coordinator::RouterSnapshot`] — a router also fans the
//! scrape out and appends one snapshot per reachable backend), and
//! `DumpTrace` (0x0c, empty) answered by `Trace` (0x0d: the flight
//! recorder's Chrome trace-event JSON as one string). A `Response`
//! likewise gains — minor 3 — an optional trailing `trace u64`
//! echoing the request's trace id. Strings are `len u32` + UTF-8, at most 1024
//! bytes; a wire model id is one length byte (≤ 63) + UTF-8. Replies
//! arrive in *completion* order, not send order — clients match on
//! `id`.
//!
//! **Versioning rules.** The version byte splits into nibbles: the
//! **major** bumps on any incompatible layout change (field order,
//! widths, semantics) and the **minor** bumps when a frame gains
//! trailing fields or new frame types appear:
//!
//! ```text
//! version  additions over the previous minor
//! v0.1     base protocol: Hello/Info, Request/Response,
//!          Rejected, Error
//! v0.2     Request trailing model id, Info tenant list,
//!          LoadModel/RetireModel/AdminOk admin frames
//! v0.3     Request/Response trailing trace id,
//!          GetStats/Stats and DumpTrace/Trace frames
//! ```
//!
//! A reader accepts its own major at any minor ≥ 1, no negotiation: a
//! frame with a foreign major gets an `Error` naming the supported
//! version, then close. Same-or-lower minors decode *strictly*
//! (trailing payload bytes are a protocol error); **higher** minors
//! decode the fields this build knows and tolerate trailing unknown
//! bytes — that is what lets an old server ignore a new client's
//! extras and lets old clients talk to new servers unchanged (pinned
//! by the compatibility battery in [`net::protocol`]). Unknown frame
//! types *within* an accepted version are a protocol error (close),
//! not an extension point; extensions get a minor bump. A corrupt or
//! truncated frame closes the connection — a length-prefixed stream
//! has no safe resynchronization point — but never affects other
//! connections or the coordinator itself (`rust/tests/net_serving.rs`
//! pins this).
//!
//! **Admission control.** `batcher.queue_depth` bounds the server's
//! total outstanding requests (pending + in-flight). Past it, `submit`
//! fails with a [`coordinator::Backpressure`] carrying `retry_after_us`
//! (derived from the flush deadline, queue depth and `max_batch` — see
//! [`coordinator::Batcher::retry_after_us`]), which the front-end maps
//! onto the `Rejected` frame. The metrics' `admission` line reports
//! accepted / rejected / hints issued and the reject rate.
//!
//! ## Router tier
//!
//! One process scales with `batcher.shards`; `repro route`
//! ([`net::router::RouterServer`]) scales *across* processes: a front
//! tier speaking the same versioned wire protocol on both sides, so
//! clients cannot tell a router from a single backend and backends
//! cannot tell a router from a client.
//!
//! **Dispatch policies** (`router.policy`). `hash` (default) places
//! each backend at `router.vnodes` salted points on a u64 ring and
//! routes a connection's requests to the first live point clockwise
//! from the connection id's hash: one connection sticks to one backend
//! (weight-stationary fabric and batcher lanes stay warm), removing a
//! backend remaps only ~1/N of connections, and dead backends are
//! walked past — both properties pinned by
//! `tests/router_properties.rs`. `least-outstanding` picks the
//! connected backend with the fewest in-flight requests: best
//! spreading, no affinity.
//!
//! **Health / drain state machine.** Per backend: *connected* ⇄
//! *quarantined*. A connect + `Hello`/`Info` handshake (agreeing with
//! the fleet's model dimensions *and* tenant list — see the fleet rule
//! under `## Multi-tenant serving`) promotes a probe connection to the
//! live multiplexed link; any link failure — read error, EOF, write
//! failure, a connection-scoped `Error` frame — quarantines the
//! backend: the link closes and **every request parked on it resolves
//! immediately with a retryable `Rejected` frame**
//! ([`net::router::FAILOVER_RETRY_US`], always ≥ 1 so hint-honoring
//! clients re-send). No request ever hangs on a dead backend — the
//! failover battery in `tests/net_serving.rs` kills a backend
//! mid-load and proves every in-flight request resolves. A prober
//! re-connects quarantined backends with exponential backoff
//! (`router.probe_ms` doubling to `router.max_backoff_ms`); success
//! counts a recovery and the backend rejoins the ring.
//!
//! **Fleet-wide admission rule.** A `Rejected` from one backend
//! triggers failover, not a client reject: the router remembers the
//! minimum `retry_after_us` hint seen and re-dispatches to untried
//! connected backends. The client sees `Rejected` only when *all*
//! backends rejected (carrying that minimum hint) or none are
//! connected — so a fleet's backpressure hint is exactly the soonest
//! any member could accept.
//!
//! **Affinity caveat.** The router multiplexes all client traffic to a
//! backend over *one* link, so backend-side
//! `batcher.affinity connection` would pin an entire router's traffic
//! to one lane on that backend; connection affinity is for
//! directly-serving stacks, which is why `request` stays the default.
//!
//! ## Observability
//!
//! The serving stack answers "where did this request's time go" with
//! three wire-scrapeable surfaces; none of them allocates on the
//! steady-state request path (still pinned by
//! `tests/hot_path_allocs.rs` with tracing on).
//!
//! **Per-request tracing.** A trace id is a nonzero `u64` assigned at
//! the *ingress* tier and carried on the wire as the v0.3 trailing
//! field, so one routed request is one trace across processes. The
//! sampling rules compose: a router samples untraced client requests
//! at its front door (1-in-`trace.sample_every`, `--trace-sample` on
//! `repro route`; `0` disables, `1` traces everything); a server
//! assigns ids only to *untraced* submissions (direct clients, local
//! loadgen); a nonzero wire trace id is honored as-is and never
//! reassigned — that invariant is what lets the router's spans and
//! the backend's spans stitch into one timeline by id. Each traced
//! request records **stage spans** — `ingress`, `admission`,
//! `queue_wait`, `batch_form`, `gemm`, `calibrated_gate` (suppressed
//! when the calibrated backend isn't gating), `write_back` — into a
//! per-process **flight recorder** ([`util::trace::FlightRecorder`]):
//! a fixed-capacity ring of atomic slots (`trace.ring_capacity`,
//! `--trace-ring`), written lock-free and allocation-free; when the
//! ring wraps, the oldest spans are overwritten — it is a flight
//! recorder, not a log. `DumpTrace` (or `repro trace --addr
//! A1[,A2,..] [--out PATH]`) renders the ring as Chrome trace-event
//! JSON (`chrome://tracing` / Perfetto); dumps from several processes
//! merge by re-basing each process's epoch
//! ([`util::trace::merge_trace_dumps`]), so a routed request shows as
//! router-ingress → backend stages → router-write-back on one
//! timeline.
//!
//! **Wire-scrapeable metrics.** `GetStats` returns the responder's
//! counters as a `Stats` frame: a server sends its
//! [`coordinator::MetricsSnapshot`], a router sends its
//! [`coordinator::RouterSnapshot`] *and* fans the scrape out to every
//! connected backend, appending one `MetricsSnapshot` per backend —
//! one scrape sees the whole fleet. `repro stats --addr ADDR
//! [--json | --prom]` renders human text, JSON, or a
//! Prometheus-exposition page (`luna_*` metrics; backend snapshots
//! get a `backend="addr"` label). Snapshots are built from relaxed
//! counters, so one snapshot may *tear* across fields (a request
//! counted in `requests` but not yet in a stage histogram); each
//! counter is individually exact, and a quiesced server's wire
//! snapshot equals its in-process one.
//!
//! **Latency breakdowns.** [`coordinator::Metrics`] keeps per-stage
//! and per-tenant time-in-stage histograms (the shared log₂
//! [`util::hist::LatencyHistogram`]), surfaced in every render format
//! and — via `repro loadgen --stats`, which pairs a `GetStats` scrape
//! before and after the sweep — as the `server_stats` delta block in
//! `BENCH_serve.json`, next to the client-measured numbers.
//!
//! ## Concurrency model
//!
//! The serving stack is hand-rolled threads + locks (no async runtime
//! in this offline image), so its correctness argument is explicit and
//! machine-checked. This section is normative; the harness that
//! enforces it is described at the end.
//!
//! **Queue close/drain protocol** ([`util::queue`]). Channels carry
//! sender and receiver counts inside the queue mutex. `recv` returns
//! `None` (never blocks forever) once all senders are gone and the
//! buffer is empty; `send` fails once all receivers are gone. The *last*
//! receiver to drop drains any buffered jobs **outside the lock**, so
//! values that carry drop-guards (worker jobs holding a
//! [`coordinator::worker::ReplyTicket`]) run their drop logic — which
//! may itself send on another channel — without re-entering the queue
//! mutex. Disconnect semantics intentionally mirror `std::sync::mpsc`
//! (pinned by the parity tests in `util::queue`).
//!
//! **Ticket drop semantics** ([`coordinator::worker::ReplyTicket`]).
//! Every batch handed to a worker is wrapped in a ticket that guarantees
//! the coordinator hears back *exactly once*: either the worker replies
//! explicitly (success or error), or the ticket's `Drop` sends a
//! "worker dropped reply" error — covering worker panics and
//! queue-drain teardown. Double-reply is impossible (replying consumes
//! the ticket); no-reply is impossible (drop fires the guard).
//!
//! **Admission-count invariant** ([`coordinator::AdmissionGate`]). One
//! process-wide atomic bounds outstanding requests (pending +
//! in-flight) across every shard: the number of *held* permits never
//! exceeds `batcher.queue_depth`, and every admit is balanced by
//! exactly one release on completion, failure, or batcher rejection.
//! The raw counter may transiently overshoot the bound while a losing
//! `try_admit` backs out its speculative increment — observers treat
//! [`coordinator::AdmissionGate::outstanding`] as monitoring data, not
//! a permit count.
//!
//! **Memory-ordering contract.** Every cross-thread *data* hand-off in
//! this crate happens through a mutex or a channel, which already
//! provide the happens-before edges. Bare atomics are therefore only
//! counters (metrics, pool stats, router load estimates, id
//! allocation, the admission count) whose readers tolerate stale or
//! torn-across-fields views, and `Ordering::Relaxed` is the repo-wide
//! default — RMW atomicity (each `fetch_add` observed exactly once) is
//! all they need. Any ordering stronger than `Relaxed` is an exception
//! that must carry an `// ordering:` justification comment; `repro
//! lint` rejects unjustified ones.
//!
//! **The harness.** Four CI gates check the above rather than trusting
//! it: (1) *loom* — `RUSTFLAGS="--cfg loom"` swaps every concurrent
//! module onto loom's model-checked primitives via the [`util::sync`]
//! shim, and `tests/loom_models.rs` plus the `#[cfg(loom)]` unit models
//! exhaustively explore the queue close/drain races, ticket
//! exactly-once delivery, pool recycle races, and the admission bound;
//! (2) *Miri* (strict provenance) runs the pool's `unsafe` paths and
//! the protocol decode tests under the interpreter; (3) *ThreadSanitizer*
//! (nightly `-Zsanitizer=thread`) runs the real serving integration
//! tests with multiple shards; (4) *`repro lint`* enforces the
//! source-level invariants (SAFETY comments on `unsafe` blocks, no
//! `mpsc`/bare allocation in hot-path modules, justified orderings) —
//! see [`lint`].

pub mod analysis;
pub mod cells;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod lint;
pub mod logic;
pub mod luna;
pub mod multiplier;
pub mod net;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sram;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
