//! # LUNA-CIM — Lookup-Table based Programmable Neural Processing in Memory
//!
//! Full-system reproduction of the LUNA-CIM paper (Dehghanzadeh, Chatterjee,
//! Bhunia, 2023). The paper proposes LUT-based 4-bit multiplication inside
//! SRAM arrays using a divide-and-conquer (D&C) decomposition, plus two
//! approximate variants. This crate provides:
//!
//! * the **hardware substrate** the paper evaluates on (gate-level netlists,
//!   an event-driven logic simulator, a calibrated 65 nm-like standard-cell
//!   library, and an SRAM-array cost model) — see [`logic`], [`cells`],
//!   [`sram`];
//! * the **paper's contribution**: all five LUT-multiplier configurations
//!   (traditional, D&C, optimized D&C, ApproxD&C, ApproxD&C 2) as both
//!   behavioural models and structural netlists, generalized to arbitrary
//!   even bit-widths — see [`multiplier`];
//! * the **LUNA-CiM unit/bank abstraction** (SRAM array + multiplier +
//!   weight-programming protocol) — see [`luna`];
//! * the **analysis suite** regenerating every figure of the paper's
//!   evaluation (probability, Hamming distance, error maps, NN MAE) — see
//!   [`analysis`];
//! * a **quantized neural-network substrate** (bit-accurate functional model
//!   cross-checked against the AOT-compiled JAX/Pallas artifacts) — see
//!   [`nn`];
//! * the **serving coordinator**: request queue, dynamic batcher, worker
//!   pool over pluggable execution backends, and the bank scheduler that
//!   maps matmuls onto LUNA units with energy/latency accounting — see
//!   [`coordinator`];
//! * the **execution backends**: the native batched LUT-GEMM (default,
//!   zero external dependencies) and the PJRT wrapper (feature `pjrt`)
//!   — see [`engine`];
//! * the **artifact store and PJRT runtime** that load the outputs of
//!   `python/compile/aot.py` — see [`runtime`] (the PJRT client itself
//!   is gated behind the `pjrt` cargo feature);
//! * [`report`] — text/CSV regenerators for every table and figure.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! request path is pure Rust + PJRT.

pub mod analysis;
pub mod cells;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod logic;
pub mod luna;
pub mod multiplier;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod sram;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
