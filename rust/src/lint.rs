//! `repro lint` — repo-invariant source checker for rules clippy can't
//! express (run as a CI gate next to clippy; see `.github/workflows/ci.yml`).
//!
//! Enforced invariants:
//!
//! 1. **`safety-comment`** — every `unsafe` *block* is immediately
//!    preceded by a `// SAFETY:` comment (same line or the contiguous
//!    comment run above). `unsafe fn` / `unsafe impl` / `unsafe trait`
//!    declarations are exempt: the obligation sits where the block is.
//! 2. **`no-mpsc`** — hot-path modules (`src/net/`, `src/coordinator/`,
//!    `src/util/`) never touch `std::sync::mpsc`: it allocates a node
//!    per send, which breaks the zero-allocation serving invariant.
//!    [`crate::util::queue`] is the in-tree replacement.
//! 3. **`no-bare-alloc`** — the same modules (minus the pool itself)
//!    contain no bare `Vec::with_capacity` / `vec![]` in non-test code:
//!    hot-path buffers come from [`crate::util::pool::PooledVec`].
//! 4. **`ordering-justified`** — every `Ordering::` stronger than
//!    `Relaxed` carries an `ordering:` justification comment; the
//!    memory-ordering contract (crate docs, `## Concurrency model`)
//!    makes `Relaxed` the default and anything stronger a documented
//!    exception.
//! 5. **`simd-confined`** — architecture-specific intrinsic paths
//!    (`std::arch` / `core::arch`) appear only inside the `simd`
//!    module of `src/nn/gemm.rs` (the runtime-dispatch layer), and
//!    every `unsafe` block in that module carries a SAFETY comment
//!    naming the dispatch guard that makes it sound (the word
//!    `dispatch` must appear in the comment run).
//!
//! Deliberate exceptions are waived in the source with a reasoned
//! directive comment: `lint: allow(mpsc): <reason>` or
//! `lint: allow(alloc): <reason>` on the offending line or in the
//! comment run directly above it; `lint: allow-file(mpsc): <reason>`
//! waives a whole file. A directive without a reason is itself a
//! violation — waivers are documentation, not escape hatches.
//!
//! The checker is line-oriented but tracks strings (including raw
//! strings), nested block comments, and `#[cfg(test)]` module blocks
//! across lines, so doc prose, string payloads and test-only code never
//! false-positive. `repro lint --self-test` proves the teeth: each rule
//! must reject a seeded violation (the negative self-test CI runs).

use crate::Result;
use anyhow::{bail, Context};
use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule slug (`safety-comment`, `no-mpsc`, ...).
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Cross-line lexer state for [`split_line`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lex {
    Code,
    /// Inside `/* */`, with nesting depth.
    Block(u32),
    /// Inside a normal `"` string.
    Str,
    /// Inside a raw string with this many `#`s.
    RawStr(u32),
}

/// Split one source line into its code part and its comment part,
/// blanking string/char contents out of the code part (so patterns in
/// payloads never match) while preserving byte positions.
fn split_line(state: Lex, line: &str) -> (String, String, Lex) {
    let mut code = String::with_capacity(line.len());
    let mut comment = String::new();
    let mut st = state;
    let bytes = line.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        match st {
            Lex::Block(depth) => {
                if bytes[i..].starts_with(b"*/") {
                    st = if depth > 1 { Lex::Block(depth - 1) } else { Lex::Code };
                    i += 2;
                } else if bytes[i..].starts_with(b"/*") {
                    st = Lex::Block(depth + 1);
                    i += 2;
                } else {
                    comment.push(bytes[i] as char);
                    i += 1;
                }
            }
            Lex::Str => {
                if bytes[i] == b'\\' {
                    i += 2; // skip the escaped byte (may run past EOL)
                } else if bytes[i] == b'"' {
                    st = Lex::Code;
                    code.push(' ');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Lex::RawStr(hashes) => {
                let close_len = 1 + hashes as usize;
                if bytes[i] == b'"' && ends_raw(&bytes[i + 1..], hashes) {
                    st = Lex::Code;
                    code.push(' ');
                    i += close_len;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Lex::Code => {
                if bytes[i..].starts_with(b"//") {
                    comment.push_str(&line[i + 2..]);
                    i = bytes.len();
                } else if bytes[i..].starts_with(b"/*") {
                    st = Lex::Block(1);
                    i += 2;
                } else if bytes[i] == b'"' {
                    st = Lex::Str;
                    code.push(' ');
                    i += 1;
                } else if bytes[i] == b'r' && !prev_is_ident(bytes, i) {
                    if let Some(hashes) = raw_str_open(&bytes[i + 1..]) {
                        st = Lex::RawStr(hashes);
                        code.push(' ');
                        i += 1 + hashes as usize + 1;
                    } else {
                        code.push('r');
                        i += 1;
                    }
                } else if bytes[i] == b'\'' {
                    // char literal vs lifetime: a closing quote within a
                    // few bytes means char — skip it so '"' or '{' in a
                    // char can't derail the lexer.
                    if let Some(len) = char_literal_len(&bytes[i..]) {
                        for _ in 0..len {
                            code.push(' ');
                        }
                        i += len;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(bytes[i] as char);
                    i += 1;
                }
            }
        }
    }
    // a normal string cannot continue past EOL unless the line ended in
    // an escape; keep it simple and carry the state either way (rustc
    // accepts multi-line strings)
    (code, comment, st)
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

/// After an `r`, does a raw string open here? Returns the `#` count.
fn raw_str_open(rest: &[u8]) -> Option<u32> {
    let mut hashes = 0u32;
    for &b in rest {
        match b {
            b'#' => hashes += 1,
            b'"' => return Some(hashes),
            _ => return None,
        }
    }
    None
}

/// Do `hashes` `#`s follow (closing a raw string)?
fn ends_raw(rest: &[u8], hashes: u32) -> bool {
    let n = hashes as usize;
    rest.len() >= n && rest[..n].iter().all(|&b| b == b'#')
}

/// Length of a char literal starting at `'`, or None for a lifetime.
fn char_literal_len(bytes: &[u8]) -> Option<usize> {
    if bytes.len() >= 3 && bytes[1] == b'\\' {
        // escaped char: '\n', '\'', '\\', '\x7f', '\u{..}'
        for (j, &b) in bytes.iter().enumerate().skip(2) {
            if b == b'\'' && j >= 3 {
                return Some(j + 1);
            }
            if b == b'\'' && bytes[1] == b'\\' && j == 3 {
                return Some(j + 1);
            }
            if j > 12 {
                return None;
            }
        }
        None
    } else if bytes.len() >= 3 && bytes[2] == b'\'' {
        Some(3)
    } else {
        None
    }
}

/// Does `code` contain `needle` as a non-identifier-prefixed match?
/// (`PooledVec::with_capacity` must not match `Vec::with_capacity`.)
fn contains_bare(code: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(needle) {
        let at = from + pos;
        let bounded = at == 0 || {
            let prev = code.as_bytes()[at - 1];
            !(prev.is_ascii_alphanumeric() || prev == b'_')
        };
        if bounded {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Does `code` contain `word` as a whole token (both sides bounded)?
fn contains_word(code: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let prev_ok = at == 0 || {
            let prev = code.as_bytes()[at - 1];
            !(prev.is_ascii_alphanumeric() || prev == b'_')
        };
        if prev_ok && !starts_ident_cont(code, at + word.len()) {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// Find the word `unsafe` introducing a *block* (not `fn`/`impl`/
/// `trait`/`extern`) in a code line.
fn has_unsafe_block(code: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find("unsafe") {
        let at = from + pos;
        let before_ok = at == 0 || {
            let prev = code.as_bytes()[at - 1];
            !(prev.is_ascii_alphanumeric() || prev == b'_')
        };
        let rest = code[at + "unsafe".len()..].trim_start();
        let after_ok = !rest.starts_with(char::is_alphanumeric) && !rest.starts_with('_');
        if before_ok && after_ok {
            let declares = ["fn", "impl", "trait", "extern"]
                .iter()
                .any(|kw| rest.starts_with(kw) && !starts_ident_cont(rest, kw.len()));
            if !declares {
                return true;
            }
        }
        from = at + "unsafe".len();
    }
    false
}

fn starts_ident_cont(s: &str, at: usize) -> bool {
    s.as_bytes().get(at).is_some_and(|b| b.is_ascii_alphanumeric() || *b == b'_')
}

/// A waiver directive for `rule` with a non-empty reason, in comment text.
fn has_waiver(comment: &str, rule: &str) -> bool {
    directive_with_reason(comment, &format!("lint: allow({rule}):"))
}

fn has_file_waiver(comment: &str, rule: &str) -> bool {
    directive_with_reason(comment, &format!("lint: allow-file({rule}):"))
}

fn directive_with_reason(comment: &str, directive: &str) -> bool {
    comment
        .find(directive)
        .is_some_and(|at| !comment[at + directive.len()..].trim().is_empty())
}

/// Orderings that demand a justification comment.
const STRONG_ORDERINGS: [&str; 4] =
    ["Ordering::Acquire", "Ordering::Release", "Ordering::AcqRel", "Ordering::SeqCst"];

/// Architecture-specific intrinsic paths the `simd-confined` rule
/// restricts to the dispatch layer.
const ARCH_TOKENS: [&str; 2] = ["std::arch", "core::arch"];

/// Is this path inside the hot-path module set the alloc/mpsc rules
/// police? (`label` uses `/` separators — normalized by [`lint_tree`].)
/// `engine/plan_cache.rs` is included by name: its hit path sits on the
/// per-request serving path even though the rest of `engine/` is
/// offline compilation code.
fn is_hot_path(label: &str) -> bool {
    ["src/net/", "src/coordinator/", "src/util/"].iter().any(|m| label.contains(m))
        || label.ends_with("src/engine/plan_cache.rs")
}

fn is_pool_module(label: &str) -> bool {
    label.ends_with("src/util/pool.rs")
}

/// Lint one file's source text. `label` is the path reported in
/// violations and used for rule scoping.
pub fn lint_source(label: &str, text: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let hot = is_hot_path(label);
    let pool = is_pool_module(label);
    let simd_home = label.ends_with("src/nn/gemm.rs");
    let file_waives_mpsc = has_file_waiver(text, "mpsc");
    let file_waives_alloc = has_file_waiver(text, "alloc");

    let mut lex = Lex::Code;
    // comment run directly above the current line (reset by code/blank)
    let mut run = String::new();
    let mut depth = 0i64;
    // #[cfg(test)] module skipping for the mpsc/alloc rules
    let mut test_attr_pending = false;
    let mut test_skip_above: Option<i64> = None;
    // `mod simd` brace tracking for the simd-confined rule
    let mut simd_mod_above: Option<i64> = None;

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let (code, comment, next_lex) = split_line(lex, raw);
        lex = next_lex;
        let code_trim = code.trim();
        let in_test_block = test_skip_above.is_some();
        let in_simd_mod = simd_mod_above.is_some();

        if code_trim.is_empty() {
            if comment.is_empty() {
                run.clear(); // blank line breaks the comment run
            } else {
                run.push('\n');
                run.push_str(&comment);
            }
            continue;
        }

        // --- rule checks on this code-bearing line ---
        let waived = |rule: &str| has_waiver(&run, rule) || has_waiver(&comment, rule);

        if has_unsafe_block(code_trim)
            && !run.contains("SAFETY:")
            && !comment.contains("SAFETY:")
        {
            out.push(Violation {
                file: label.to_string(),
                line: line_no,
                rule: "safety-comment",
                msg: "`unsafe` block without an immediately preceding `// SAFETY:` comment"
                    .to_string(),
            });
        }

        for pat in STRONG_ORDERINGS {
            if contains_bare(code_trim, pat)
                && !run.contains("ordering:")
                && !comment.contains("ordering:")
            {
                out.push(Violation {
                    file: label.to_string(),
                    line: line_no,
                    rule: "ordering-justified",
                    msg: format!(
                        "`{pat}` without an `// ordering:` justification — \
                         the repo default is Relaxed (crate docs, Concurrency model)"
                    ),
                });
            }
        }

        for pat in ARCH_TOKENS {
            if contains_bare(code_trim, pat) && !(simd_home && in_simd_mod) {
                out.push(Violation {
                    file: label.to_string(),
                    line: line_no,
                    rule: "simd-confined",
                    msg: format!(
                        "`{pat}` outside the `simd` module of src/nn/gemm.rs — \
                         arch-specific intrinsics live behind the dispatch layer \
                         (force paths via GemmSimd, read features via host_cpu_features)"
                    ),
                });
            }
        }

        if simd_home
            && in_simd_mod
            && has_unsafe_block(code_trim)
            && !run.contains("dispatch")
            && !comment.contains("dispatch")
        {
            out.push(Violation {
                file: label.to_string(),
                line: line_no,
                rule: "simd-confined",
                msg: "`unsafe` in the simd module whose SAFETY comment does not name the \
                      runtime-dispatch guard (the word `dispatch`)"
                    .to_string(),
            });
        }

        if hot && !in_test_block {
            if contains_bare(code_trim, "mpsc") && !file_waives_mpsc && !waived("mpsc") {
                out.push(Violation {
                    file: label.to_string(),
                    line: line_no,
                    rule: "no-mpsc",
                    msg: "std::sync::mpsc in a hot-path module (allocates per send); \
                          use crate::util::queue"
                        .to_string(),
                });
            }
            if !pool && !file_waives_alloc && !waived("alloc") {
                let bare_vec = contains_bare(code_trim, "Vec::with_capacity")
                    || contains_bare(code_trim, "vec!");
                if bare_vec {
                    out.push(Violation {
                        file: label.to_string(),
                        line: line_no,
                        rule: "no-bare-alloc",
                        msg: "bare Vec::with_capacity / vec![] in a hot-path module; \
                              use PooledVec (or waive with a reason)"
                            .to_string(),
                    });
                }
            }
        }

        // --- bookkeeping for the next line ---
        if test_attr_pending {
            if contains_word(code_trim, "mod") {
                test_skip_above = Some(depth);
                test_attr_pending = false;
            } else if !code_trim.starts_with("#[") {
                test_attr_pending = false;
            }
        }
        if code_trim.contains("#[cfg(test)") || code_trim.contains("#[cfg(all(test") {
            test_attr_pending = true;
        }
        if simd_home
            && simd_mod_above.is_none()
            && contains_word(code_trim, "mod")
            && contains_word(code_trim, "simd")
        {
            simd_mod_above = Some(depth);
        }
        depth += code.matches('{').count() as i64;
        depth -= code.matches('}').count() as i64;
        if let Some(above) = test_skip_above {
            if depth <= above {
                test_skip_above = None;
            }
        }
        if let Some(above) = simd_mod_above {
            if depth <= above {
                simd_mod_above = None;
            }
        }
        run.clear();
        if !comment.is_empty() {
            // a trailing comment on a code line also seeds the run for
            // the next line (attribute-then-code patterns)
            run.push_str(&comment);
        }
    }
    out
}

/// Recursively collect `.rs` files under `dir` (sorted for determinism).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the whole tree rooted at the crate dir (the one holding `src/`):
/// `src/`, `tests/`, `benches/`.
pub fn lint_tree(root: &Path) -> Result<Vec<Violation>> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    if files.is_empty() {
        bail!("no .rs files under {} — wrong --root?", root.display());
    }
    let mut out = Vec::new();
    for path in files {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        let label = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        out.extend(lint_source(&label, &text));
    }
    Ok(out)
}

/// Negative self-test: every rule must reject its seeded violation and
/// accept the corrected twin. Violations are assembled from fragments so
/// linting this file's own source never trips on them.
pub fn self_test() -> Result<()> {
    let mut failures = Vec::new();
    let mut expect = |name: &str, rule: &str, src: &str, want: usize| {
        let got = lint_source("src/coordinator/seeded.rs", src)
            .iter()
            .filter(|v| v.rule == rule)
            .count();
        if got != want {
            failures.push(format!("{name}: expected {want} `{rule}` violation(s), got {got}"));
        }
    };

    // seeded: unsafe block with no SAFETY comment (the acceptance
    // criterion's canonical violation)
    let uns = String::from("uns") + "afe";
    let bad_safety = format!("fn f(p: *const u8) -> u8 {{\n    {uns} {{ *p }}\n}}\n");
    expect("missing-SAFETY", "safety-comment", &bad_safety, 1);
    let good_safety =
        format!("fn f(p: *const u8) -> u8 {{\n    // SAFETY: contract\n    {uns} {{ *p }}\n}}\n");
    expect("present-SAFETY", "safety-comment", &good_safety, 0);
    let decl = format!("{uns} fn g() {{}}\n{uns} impl Send for T {{}}\n");
    expect("unsafe-declarations-exempt", "safety-comment", &decl, 0);

    // seeded: strong ordering without justification
    let seq = String::from("Ordering::Seq") + "Cst";
    let bad_ord = format!("fn f() {{ X.load({seq}); }}\n");
    expect("unjustified-SeqCst", "ordering-justified", &bad_ord, 1);
    let good_ord = format!("fn f() {{\n    // ordering: publishes map\n    X.load({seq});\n}}\n");
    expect("justified-SeqCst", "ordering-justified", &good_ord, 0);

    // seeded: mpsc in a hot-path module
    let mp = String::from("mp") + "sc";
    let bad_mpsc = format!("use std::sync::{mp};\n");
    expect("hot-path-mpsc", "no-mpsc", &bad_mpsc, 1);
    let waived = format!("// lint: allow({mp}): off the hot loop\nuse std::sync::{mp};\n");
    expect("waived-mpsc", "no-mpsc", &waived, 0);

    // seeded: arch intrinsics outside the gemm simd module (the
    // simd-confined rule's canonical violation)
    let arch = String::from("std::ar") + "ch";
    let bad_arch = format!("fn f() {{ {arch}::x86_64::_mm_pause(); }}\n");
    expect("arch-outside-simd", "simd-confined", &bad_arch, 1);

    // seeded: bare allocation in a hot-path module
    let vwc = String::from("Vec::with_cap") + "acity";
    let bad_alloc = format!("fn f() {{ let v: Vec<u8> = {vwc}(8); }}\n");
    expect("hot-path-bare-alloc", "no-bare-alloc", &bad_alloc, 1);
    let pooled = format!("fn f() {{ let v = Pooled{vwc}(8); }}\n");
    expect("pooledvec-not-flagged", "no-bare-alloc", &pooled, 0);
    let in_test = format!("#[cfg(test)]\nmod t {{\n    let v: Vec<u8> = {vwc}(8);\n}}\n");
    expect("test-code-exempt", "no-bare-alloc", &in_test, 0);

    // seeded: the flight recorder (`src/util/trace.rs`) is policed like
    // the rest of the hot-path set — a bare allocation on its record
    // path must be caught, so tracing can never re-introduce steady-state
    // allocation unnoticed
    let bad_trace = format!("fn record() {{ let spans: Vec<u8> = {vwc}(64); }}\n");
    let got = lint_source("src/util/trace.rs", &bad_trace)
        .iter()
        .filter(|v| v.rule == "no-bare-alloc")
        .count();
    if got != 1 {
        failures.push(format!("trace-module-policed: expected 1 `no-bare-alloc`, got {got}"));
    }

    // seeded: the rest of the simd-confined matrix needs the gemm.rs
    // label — arch tokens are legal inside its `mod simd`, and unsafe
    // there must name the dispatch guard in its SAFETY comment
    let count = |label: &str, src: &str| {
        lint_source(label, src).iter().filter(|v| v.rule == "simd-confined").count()
    };
    let core_arch = String::from("core::ar") + "ch";
    let in_simd = format!("mod simd {{\n    fn f() {{ {core_arch}::x86_64::noop(); }}\n}}\n");
    let got = count("src/nn/gemm.rs", &in_simd);
    if got != 0 {
        failures.push(format!("simd-module-allowed: expected 0 `simd-confined`, got {got}"));
    }
    let got = count("src/nn/other.rs", &in_simd);
    if got != 1 {
        failures.push(format!("simd-module-elsewhere: expected 1 `simd-confined`, got {got}"));
    }
    let undispatched = format!(
        "mod simd {{\n    fn f() {{\n        // SAFETY: aligned\n        {uns} {{ g() }}\n    }}\n}}\n"
    );
    let got = count("src/nn/gemm.rs", &undispatched);
    if got != 1 {
        failures.push(format!("undispatched-unsafe: expected 1 `simd-confined`, got {got}"));
    }
    let dispatched = format!(
        "mod simd {{\n    fn f() {{\n        // SAFETY: behind the avx2 runtime dispatch \
         guard\n        {uns} {{ g() }}\n    }}\n}}\n"
    );
    let got = count("src/nn/gemm.rs", &dispatched);
    if got != 0 {
        failures.push(format!("dispatched-unsafe: expected 0 `simd-confined`, got {got}"));
    }

    if failures.is_empty() {
        println!("lint self-test: every rule rejects its seeded violation");
        Ok(())
    } else {
        bail!("lint self-test failed:\n  {}", failures.join("\n  "));
    }
}

/// CLI entry: lint the tree, print violations, error out if any.
pub fn run(root: &Path) -> Result<()> {
    let violations = lint_tree(root)?;
    if violations.is_empty() {
        println!("lint: clean");
        return Ok(());
    }
    for v in &violations {
        eprintln!("{v}");
    }
    bail!("{} lint violation(s)", violations.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_test_passes() {
        self_test().unwrap();
    }

    #[test]
    fn strings_and_comments_never_false_positive() {
        // the patterns appear only in a doc comment and a string payload
        let src = "//! replaces std::sync::mpsc on the hot path\n\
                   fn f() -> &'static str {\n    \"Vec::with_capacity(8) vec![]\"\n}\n";
        assert!(lint_source("src/util/doc.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_span_lines_without_leaking_code() {
        let src = "fn f() -> &'static str {\n    r#\"\nuse std::sync::mpsc;\nvec![1]\n\"#\n}\n";
        assert!(lint_source("src/net/raw.rs", src).is_empty());
    }

    #[test]
    fn scoping_limits_alloc_and_mpsc_rules_to_hot_modules() {
        let src = "fn f() { let v: Vec<u8> = Vec::with_capacity(8); let w = vec![1]; }\n";
        assert!(lint_source("src/analysis/free.rs", src).is_empty(), "cold modules are free");
        assert_eq!(lint_source("src/net/hot.rs", src).len(), 2, "hot modules are policed");
        assert!(lint_source("src/util/pool.rs", src).is_empty(), "the pool is the allocator");
        // engine/ is offline compilation code EXCEPT the plan cache,
        // whose hit path serves every request
        assert!(lint_source("src/engine/compile.rs", src).is_empty(), "engine is cold");
        assert_eq!(
            lint_source("src/engine/plan_cache.rs", src).len(),
            2,
            "the plan cache hit path is policed like the serving modules"
        );
    }

    #[test]
    fn waiver_requires_a_reason() {
        let bare = "// lint: allow(alloc):\nfn f() { let v: Vec<u8> = Vec::with_capacity(8); }\n";
        assert_eq!(lint_source("src/util/x.rs", bare).len(), 1, "reasonless waiver is void");
        let reasoned = "// lint: allow(alloc): startup scratch\nlet v = Vec::with_capacity(8);\n";
        assert!(lint_source("src/util/x.rs", reasoned).is_empty());
    }

    #[test]
    fn simd_rule_confines_arch_tokens_to_the_gemm_dispatch_module() {
        let stray = "fn f() { std::arch::x86_64::noop(); }\n";
        assert_eq!(lint_source("src/coordinator/x.rs", stray).len(), 1, "stray intrinsic path");
        let confined = "mod simd {\n    fn f() { std::arch::x86_64::noop(); }\n}\n";
        assert!(lint_source("src/nn/gemm.rs", confined).is_empty(), "the dispatch layer is home");
        assert_eq!(lint_source("src/nn/other.rs", confined).len(), 1, "only gemm.rs hosts it");
        // after the module's closing brace the allowance ends
        let after = "mod simd {\n    fn f() {}\n}\nfn g() { core::arch::x86_64::noop(); }\n";
        assert_eq!(lint_source("src/nn/gemm.rs", after).len(), 1, "allowance ends at the brace");
    }

    #[test]
    fn ordering_rule_ignores_relaxed() {
        let src = "fn f() { X.load(Ordering::Relaxed); }\n";
        assert!(lint_source("src/util/x.rs", src).is_empty());
    }

    #[test]
    fn tree_lint_passes_on_this_repo() {
        // CI runs `repro lint` from rust/; the unit test finds the crate
        // root relative to this source file instead.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let violations = lint_tree(root).unwrap();
        assert!(
            violations.is_empty(),
            "repo must lint clean:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
