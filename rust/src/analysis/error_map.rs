//! Figs 7, 8, 11, 12 — error heatmaps and histograms of the approximate
//! configurations vs the exact D&C product, over all (Weight, Data) pairs.

use crate::multiplier::MultiplierKind;

/// A 16×16 signed error map: `err[w][y] = exact − approx` (the paper's
/// heatmap color intensity; positive = approximation undershoots).
#[derive(Debug, Clone)]
pub struct ErrorMap {
    pub kind: MultiplierKind,
    pub err: Vec<Vec<i32>>, // [w][y]
}

/// Compute the error map of `kind` vs exact multiplication (Figs 7 / 11).
pub fn error_map(kind: MultiplierKind) -> ErrorMap {
    let err = (0..16u8)
        .map(|w| (0..16u8).map(|y| kind.error(w, y)).collect())
        .collect();
    ErrorMap { kind, err }
}

impl ErrorMap {
    /// (min, max) error — the paper's ranges: ApproxD&C [0, 45],
    /// ApproxD&C 2 [−15, 30].
    pub fn range(&self) -> (i32, i32) {
        let mut lo = i32::MAX;
        let mut hi = i32::MIN;
        for row in &self.err {
            for &e in row {
                lo = lo.min(e);
                hi = hi.max(e);
            }
        }
        (lo, hi)
    }

    /// Histogram of error occurrences (Figs 8 / 12): sorted
    /// `(error, count)` pairs over all 256 (w, y) pairs.
    pub fn histogram(&self) -> Vec<(i32, u32)> {
        let mut map = std::collections::BTreeMap::new();
        for row in &self.err {
            for &e in row {
                *map.entry(e).or_insert(0u32) += 1;
            }
        }
        map.into_iter().collect()
    }

    /// Mean signed error (bias). ApproxD&C is strictly non-negative biased;
    /// ApproxD&C 2 is closer to zero-centred ("balanced error
    /// distribution" — §III.C).
    pub fn mean_error(&self) -> f64 {
        let sum: i64 = self.err.iter().flatten().map(|&e| e as i64).sum();
        sum as f64 / 256.0
    }

    /// Mean absolute error over the exhaustive input space.
    pub fn mean_abs_error(&self) -> f64 {
        let sum: i64 = self.err.iter().flatten().map(|&e| e.unsigned_abs() as i64).sum();
        sum as f64 / 256.0
    }

    /// CSV of the 16×16 map (`w,y,error` rows) for external plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("w,y,error\n");
        for (w, row) in self.err.iter().enumerate() {
            for (y, &e) in row.iter().enumerate() {
                out.push_str(&format!("{w},{y},{e}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_error_range_matches_fig8() {
        let m = error_map(MultiplierKind::Approx);
        assert_eq!(m.range(), (0, 45));
    }

    #[test]
    fn approx2_error_range_matches_fig12() {
        let m = error_map(MultiplierKind::Approx2);
        assert_eq!(m.range(), (-15, 30));
    }

    #[test]
    fn exact_configs_have_zero_error() {
        for kind in [MultiplierKind::Dnc, MultiplierKind::DncOpt, MultiplierKind::Traditional] {
            let m = error_map(kind);
            assert_eq!(m.range(), (0, 0), "{kind}");
        }
    }

    #[test]
    fn histograms_cover_256_pairs() {
        for kind in [MultiplierKind::Approx, MultiplierKind::Approx2] {
            let total: u32 = error_map(kind).histogram().iter().map(|(_, c)| c).sum();
            assert_eq!(total, 256);
        }
    }

    #[test]
    fn approx2_is_better_centred_than_approx() {
        // §III.C: "the balanced error distribution in ApproxD&C 2".
        let bias1 = error_map(MultiplierKind::Approx).mean_error();
        let bias2 = error_map(MultiplierKind::Approx2).mean_error();
        assert!(bias2.abs() < bias1.abs());
    }

    #[test]
    fn approx_error_equals_z_lsb() {
        let m = error_map(MultiplierKind::Approx);
        for w in 0..16usize {
            for y in 0..16usize {
                assert_eq!(m.err[w][y], crate::multiplier::z_lsb(w as u8, y as u8) as i32);
            }
        }
    }
}
