//! Fig 6 — Hamming-distance selection of the fixed `Z_LSB`.
//!
//! For every 6-bit candidate `c`, the paper computes the average Hamming
//! distance between `c` and the actual (4b×2b) products, weighted by
//! their probability of occurrence, and normalized per bit (divided by
//! the 6-bit width — that normalization is what makes the paper's
//! reported minimum 0.275 at `c = 0`).

use super::probability::lsb_product_pmf;

/// Mean per-bit Hamming distance for every candidate 0..=63 (the Fig 6
/// curve).
pub fn mean_hamming_per_candidate() -> [f64; 64] {
    let pmf = lsb_product_pmf();
    let mut out = [0.0f64; 64];
    for (c, slot) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (p, &prob) in pmf.iter().enumerate() {
            if prob > 0.0 {
                acc += prob * ((p ^ c).count_ones() as f64);
            }
        }
        *slot = acc / 6.0;
    }
    out
}

/// The candidate minimizing mean Hamming distance and its value —
/// the paper's (0, 0.275).
pub fn best_candidate() -> (u8, f64) {
    let dists = mean_hamming_per_candidate();
    let (c, d) = dists
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("64 candidates");
    (c as u8, *d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_is_the_best_candidate() {
        let (c, _) = best_candidate();
        assert_eq!(c, 0);
    }

    #[test]
    fn minimum_matches_paper_0_275() {
        let (_, d) = best_candidate();
        assert!((d - 0.275).abs() < 5e-3, "min mean Hamming distance {d} vs paper 0.275");
    }

    #[test]
    fn distances_bounded_by_word_width() {
        for d in mean_hamming_per_candidate() {
            assert!(d >= 0.0 && d <= 1.0, "per-bit distance in [0,1], got {d}");
        }
    }

    #[test]
    fn all_ones_candidate_is_bad() {
        let dists = mean_hamming_per_candidate();
        assert!(dists[63] > dists[0] * 2.0);
    }
}
