//! Fig 5 — probability distribution of the (4b×2b) LSB-side product.
//!
//! Operand 1 uniform over [0, 15], operand 2 uniform over [0, 3]; the
//! product ranges over [0, 45] ⊂ [0, 63]. The paper highlights
//! P(product = 0) ≈ 0.296 (exactly 19/64) and enumerates the values in
//! 0..=63 that can never occur.

/// Exact probability mass function over products 0..=63 of `w · y_lo`
/// with `w ~ U[0,15]`, `y_lo ~ U[0,3]` (the stem chart of Fig 5).
pub fn lsb_product_pmf() -> [f64; 64] {
    let mut counts = [0u32; 64];
    for w in 0..16u32 {
        for y in 0..4u32 {
            counts[(w * y) as usize] += 1;
        }
    }
    let mut pmf = [0.0f64; 64];
    for (p, &c) in pmf.iter_mut().zip(counts.iter()) {
        *p = c as f64 / 64.0;
    }
    pmf
}

/// The paper's headline: P(Z_LSB = 0) = 19/64 ≈ 0.2969 ("0.296").
pub fn probability_of_zero() -> f64 {
    lsb_product_pmf()[0]
}

/// Values in 0..=63 that can never be a (4b×2b) product — the paper lists
/// 17, 19, 23, 25, 29, 31, 32, 34, 35, 37, 38, 40, 41, 43, 44 and 46–63.
pub fn impossible_values() -> Vec<u8> {
    lsb_product_pmf()
        .iter()
        .enumerate()
        .filter(|(_, &p)| p == 0.0)
        .map(|(v, _)| v as u8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        let s: f64 = lsb_product_pmf().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_probability_matches_paper() {
        // 19/64: w=0 (4 ways) + y=0 (16 ways) − both (1 way) = 19 of 64.
        assert!((probability_of_zero() - 19.0 / 64.0).abs() < 1e-12);
        // The paper rounds to 0.296.
        assert!((probability_of_zero() - 0.296).abs() < 1e-3);
    }

    #[test]
    fn impossible_set_matches_paper_list() {
        let mut expected: Vec<u8> =
            vec![17, 19, 23, 25, 29, 31, 32, 34, 35, 37, 38, 40, 41, 43, 44];
        expected.extend(46..=63);
        assert_eq!(impossible_values(), expected);
    }

    #[test]
    fn zero_is_the_mode() {
        let pmf = lsb_product_pmf();
        let max = pmf.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(pmf[0], max);
    }
}
