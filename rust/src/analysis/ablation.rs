//! Ablation studies over the design choices DESIGN.md calls out.
//!
//! * [`fixed_zlsb_sweep`] — extends Fig 6: for *every* candidate fixed
//!   Z_LSB, the element MAE and the trained-classifier accuracy, showing
//!   the paper's Hamming-distance criterion picks a good-but-not-optimal
//!   constant for accuracy;
//! * [`stationarity_study`] — weight-stationary vs reprogram-every-wave
//!   scheduling energy (why LUNA's programmability needs a scheduler);
//! * [`fanout_sharing_study`] — LUT-copy fan-out (Table II's hidden
//!   knob): SRAM bits vs copies-per-unit-pair across widths.

use crate::cells::CellLibrary;
use crate::coordinator::tiler::{Tiler, UnitCosts};
use crate::multiplier::{approx, ideal_value, MultiplierKind};
use crate::nn::{DigitsDataset, QuantMlp};

/// One row of the fixed-Z_LSB sweep.
#[derive(Debug, Clone)]
pub struct ZlsbRow {
    pub candidate: u8,
    pub mean_hamming: f64,
    pub element_mae: f64,
    /// Classifier accuracy with this fixed Z_LSB (None when no model given).
    pub accuracy: Option<f64>,
}

/// Sweep every 6-bit fixed Z_LSB candidate (Fig 4/6 design space).
pub fn fixed_zlsb_sweep(model: Option<(&QuantMlp, &DigitsDataset)>) -> Vec<ZlsbRow> {
    let hams = super::hamming::mean_hamming_per_candidate();
    (0..64u8)
        .map(|c| {
            let mut abs_err = 0u64;
            for w in 0..16u8 {
                for y in 0..16u8 {
                    let approx_v = approx::value_fixed(w, y, c) as i64;
                    abs_err += (ideal_value(w, y) as i64 - approx_v).unsigned_abs();
                }
            }
            let accuracy = model.map(|(mlp, ds)| {
                ds.accuracy(|px| {
                    classify_with_fixed_zlsb(mlp, px, c)
                })
            });
            ZlsbRow {
                candidate: c,
                mean_hamming: hams[c as usize],
                element_mae: abs_err as f64 / 256.0,
                accuracy,
            }
        })
        .collect()
}

/// Forward an MLP where every product uses ApproxD&C with fixed `c`.
fn classify_with_fixed_zlsb(mlp: &QuantMlp, px: &[f32], c: u8) -> usize {
    // Mirror QuantLinear::forward but with the parametric approximation.
    let mut h = px.to_vec();
    for layer in &mlp.layers {
        let xq = layer.x_quant.quantize_slice(&h);
        let x_sum: i32 = xq.iter().map(|&x| x as i32).sum();
        let mut out = Vec::with_capacity(layer.out_dim);
        for o in 0..layer.out_dim {
            let row = &layer.wq[o * layer.in_dim..(o + 1) * layer.in_dim];
            let lut: i32 = row
                .iter()
                .zip(&xq)
                .map(|(&w, &x)| approx::value_fixed(w, x, c) as i32)
                .sum();
            let acc = lut - 8 * x_sum;
            let v = acc as f32 * layer.w_quant.scale * layer.x_quant.scale + layer.bias[o];
            out.push(if layer.relu { v.max(0.0) } else { v });
        }
        h = out;
    }
    crate::nn::argmax(&h)
}

/// Result of the scheduling-policy ablation.
#[derive(Debug, Clone)]
pub struct StationarityResult {
    pub batches: usize,
    pub stationary_energy_fj: f64,
    pub naive_energy_fj: f64,
    /// naive / stationary — how much the scheduler saves.
    pub ratio: f64,
}

/// Weight-stationary scheduling vs naive reprogram-every-batch, over a
/// stream of identical batches (steady-state serving).
pub fn stationarity_study(
    lib: &CellLibrary,
    mlp: &QuantMlp,
    units: usize,
    batches: usize,
    batch: usize,
) -> StationarityResult {
    let costs = UnitCosts::measure_cached(MultiplierKind::DncOpt, lib);
    // stationary: one tiler across the stream
    let mut stationary = Tiler::new(units, 1, costs);
    let mut stationary_energy = 0.0;
    for _ in 0..batches {
        stationary_energy += stationary.schedule(mlp, batch).total_energy_fj;
    }
    // naive: a fresh fabric per batch (every LUT reprogrammed every time)
    let mut naive_energy = 0.0;
    for _ in 0..batches {
        let mut naive = Tiler::new(units, 1, costs);
        naive_energy += naive.schedule(mlp, batch).total_energy_fj;
    }
    StationarityResult {
        batches,
        stationary_energy_fj: stationary_energy,
        naive_energy_fj: naive_energy,
        ratio: naive_energy / stationary_energy,
    }
}

/// One row of the fan-out sharing study.
#[derive(Debug, Clone)]
pub struct FanoutRow {
    pub width: u32,
    pub units_per_copy: u32,
    pub srams: u64,
    pub muxes: u64,
}

/// Table II's hidden knob: how many chunk units share one LUT copy.
/// The paper uses 2 (fan-out considerations); 1 = fully private copies,
/// `n/2` = one global copy (maximum wiring fan-out).
pub fn fanout_sharing_study(widths: &[u32]) -> Vec<FanoutRow> {
    let mut rows = Vec::new();
    for &n in widths {
        assert!(n >= 4 && n % 2 == 0);
        let chunks = (n / 2) as u64;
        let bits_per_copy = 2 * n as u64 + 2;
        let muxes = chunks * 3 * (n as u64 + 2);
        for upc in [1u64, 2, chunks] {
            let copies = chunks.div_ceil(upc);
            rows.push(FanoutRow {
                width: n,
                units_per_copy: upc as u32,
                srams: copies * bits_per_copy,
                muxes,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;

    #[test]
    fn zlsb_sweep_zero_matches_approx_module() {
        let rows = fixed_zlsb_sweep(None);
        assert_eq!(rows.len(), 64);
        // candidate 0 == ApproxD&C: MAE 11.25
        assert!((rows[0].element_mae - 11.25).abs() < 1e-9);
        // the Hamming winner is 0 (paper) ...
        let ham_best = rows
            .iter()
            .min_by(|a, b| a.mean_hamming.partial_cmp(&b.mean_hamming).unwrap())
            .unwrap();
        assert_eq!(ham_best.candidate, 0);
        // ... but the MAE winner is a mid-range constant, not 0 —
        // the criterion matters (documented ablation finding).
        let mae_best =
            rows.iter().min_by(|a, b| a.element_mae.partial_cmp(&b.element_mae).unwrap()).unwrap();
        assert_ne!(mae_best.candidate, 0);
        assert!(mae_best.element_mae < rows[0].element_mae);
    }

    #[test]
    fn zlsb_sweep_with_model_reports_accuracy() {
        let mlp = QuantMlp::random_digits(9);
        let ds = DigitsDataset::generate(2, 42);
        let rows = fixed_zlsb_sweep(Some((&mlp, &ds)));
        assert!(rows.iter().all(|r| r.accuracy.is_some()));
    }

    #[test]
    fn stationary_scheduling_saves_energy() {
        let lib = tsmc65_library();
        let mlp = QuantMlp::random_for_study(3);
        let total_elems: usize = mlp.layers.iter().map(|l| l.wq.len()).sum();
        let r = stationarity_study(&lib, &mlp, total_elems, 8, 4);
        assert!(r.ratio > 3.0, "stationary should save a lot, ratio {}", r.ratio);
        assert!(r.stationary_energy_fj > 0.0);
    }

    #[test]
    fn fanout_study_reproduces_table2_at_sharing_2() {
        let rows = fanout_sharing_study(&[4, 8, 16]);
        let at = |n: u32, upc: u32| {
            rows.iter().find(|r| r.width == n && r.units_per_copy == upc).unwrap()
        };
        assert_eq!(at(4, 2).srams, 10);
        assert_eq!(at(8, 2).srams, 36);
        assert_eq!(at(16, 2).srams, 136);
        // private copies cost more, global sharing costs least
        assert!(at(16, 1).srams > at(16, 2).srams);
        assert!(at(16, 8).srams < at(16, 2).srams);
    }
}
