//! Fig 13 — Mean Absolute Error of each multiplier configuration.
//!
//! The paper integrates the specialised multipliers into neural networks,
//! drives them with random input data for 100 iterations, and reports the
//! MAE vs "IDEAL" multiplication. We reproduce both granularities:
//!
//! * [`element_mae`] — MAE of the raw 4b×4b products over random pairs
//!   (the multiplier in isolation);
//! * [`network_mae`] — MAE of a quantized MLP's output logits when every
//!   MAC uses the configuration (the paper's network-level study).

use crate::multiplier::{MultiplierKind, MultiplierModel};
use crate::nn::{DigitsDataset, QuantMlp};
use crate::util::Rng;

/// One Fig 13 bar.
#[derive(Debug, Clone)]
pub struct MaeResult {
    pub kind: MultiplierKind,
    pub element_mae: f64,
    pub network_mae: f64,
}

/// MAE of raw products vs ideal over `iters` random 4-bit pairs.
pub fn element_mae(kind: MultiplierKind, iters: usize, seed: u64) -> f64 {
    let mut rng = Rng::seed_from_u64(seed);
    let mut acc = 0u64;
    for _ in 0..iters {
        let w: u8 = rng.gen_u4();
        let y: u8 = rng.gen_u4();
        acc += kind.error(w, y).unsigned_abs() as u64;
    }
    acc as f64 / iters as f64
}

/// Exact element-level MAE over the full 16×16 input space (the limit the
/// random study converges to).
pub fn element_mae_exhaustive(kind: MultiplierKind) -> f64 {
    super::error_map::error_map(kind).mean_abs_error()
}

/// Network-level MAE: mean |logit difference| between `kind` and IDEAL
/// on `iters` random inputs through a quantized MLP.
pub fn network_mae(mlp: &QuantMlp, kind: MultiplierKind, iters: usize, seed: u64) -> f64 {
    let mut rng = Rng::seed_from_u64(seed);
    let ideal = MultiplierModel::new(MultiplierKind::Ideal);
    let model = MultiplierModel::new(kind);
    let dim = mlp.input_dim();
    let mut acc = 0.0f64;
    let mut count = 0usize;
    for _ in 0..iters {
        let x: Vec<f32> = (0..dim).map(|_| rng.gen_f64() as f32).collect();
        let a = mlp.forward(&x, &ideal);
        let b = mlp.forward(&x, &model);
        for (va, vb) in a.iter().zip(b.iter()) {
            acc += (va - vb).abs() as f64;
            count += 1;
        }
    }
    acc / count as f64
}

/// The full Fig 13 study: every configuration's element- and network-level
/// MAE, 100 iterations (the paper's count), deterministic seed.
pub fn fig13_study(iters: usize, seed: u64) -> Vec<MaeResult> {
    let mlp = QuantMlp::random_for_study(seed ^ 0xF13);
    let _ = DigitsDataset::generate(8, seed); // warm the dataset cache path
    MultiplierKind::ALL
        .iter()
        .map(|&kind| MaeResult {
            kind,
            element_mae: element_mae(kind, iters * 100, seed),
            network_mae: network_mae(&mlp, kind, iters, seed),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_kinds_have_zero_mae() {
        for kind in [MultiplierKind::Dnc, MultiplierKind::DncOpt, MultiplierKind::ArrayMult] {
            assert_eq!(element_mae(kind, 500, 7), 0.0, "{kind}");
            assert_eq!(element_mae_exhaustive(kind), 0.0, "{kind}");
        }
    }

    #[test]
    fn approx_mae_near_analytic_mean() {
        // E|err| for ApproxD&C = E[Z_LSB] = E[w]·E[y_lo] = 7.5 · 1.5.
        let mae = element_mae_exhaustive(MultiplierKind::Approx);
        assert!((mae - 11.25).abs() < 1e-9, "{mae}");
        let sampled = element_mae(MultiplierKind::Approx, 20_000, 3);
        assert!((sampled - 11.25).abs() < 0.5, "{sampled}");
    }

    #[test]
    fn approx2_has_lower_mae_than_approx() {
        // The W-dependent approximation is the better estimator: its MAE
        // E|w(y_lo−1)| = 7.5 · 1.0 = 7.5 < 11.25.
        let a = element_mae_exhaustive(MultiplierKind::Approx);
        let b = element_mae_exhaustive(MultiplierKind::Approx2);
        assert!((b - 7.5).abs() < 1e-9);
        assert!(b < a);
    }

    #[test]
    fn network_mae_behaviour() {
        // Deterministic facts: exact configs have zero network MAE, the
        // approximate ones do not. The element-level ordering (approx2
        // 7.5 < approx 11.25) does NOT carry to network level: approx's
        // one-sided (always-undershooting) error is partially absorbed by
        // the ReLU clamp, while approx2's sign-balanced error propagates.
        // EXPERIMENTS.md §Fig13 records the measured values.
        let (mut approx_sum, mut approx2_sum) = (0.0, 0.0);
        for seed in 0..6u64 {
            let mlp = QuantMlp::random_for_study(40 + seed);
            assert_eq!(network_mae(&mlp, MultiplierKind::DncOpt, 10, seed), 0.0);
            approx_sum += network_mae(&mlp, MultiplierKind::Approx, 10, seed);
            approx2_sum += network_mae(&mlp, MultiplierKind::Approx2, 10, seed);
        }
        assert!(approx_sum > 0.0 && approx2_sum > 0.0);
        // element-level ordering is deterministic
        assert!(
            element_mae_exhaustive(MultiplierKind::Approx2)
                < element_mae_exhaustive(MultiplierKind::Approx)
        );
    }

    #[test]
    fn fig13_study_is_deterministic() {
        let a = fig13_study(5, 99);
        let b = fig13_study(5, 99);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.element_mae, y.element_mae);
            assert_eq!(x.network_mae, y.network_mae);
        }
    }
}
