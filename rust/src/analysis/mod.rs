//! Statistical analyses behind the paper's approximation choices.
//!
//! Reproduces, exactly or statistically:
//!
//! * **Fig 5** — probability distribution of the (4b×2b) LSB-side product
//!   ([`probability`]): P(0) = 19/64 ≈ 0.2969 ("0.296" in the paper);
//! * **Fig 6** — mean per-bit Hamming distance of each candidate fixed
//!   `Z_LSB` ([`hamming`]): minimum 0.275 at candidate 0;
//! * **Figs 7, 8, 11, 12** — error heatmaps and histograms of ApproxD&C
//!   and ApproxD&C 2 vs the exact D&C product ([`error_map`]);
//! * **Fig 13** — Mean Absolute Error of each multiplier configuration
//!   inside a neural network ([`mae`]).

pub mod ablation;
pub mod error_map;
pub mod hamming;
pub mod mae;
pub mod probability;
