//! Pluggable batch-execution backends for the serving coordinator.
//!
//! The coordinator used to hard-code the external PJRT runtime; this
//! module makes execution a trait so the same serving stack (batcher →
//! router → worker pool → completion pool) runs against either:
//!
//! * [`NativeBackend`] — the in-process batched LUT-GEMM over the
//!   quantized functional model. Zero external dependencies: the whole
//!   request path is pure Rust, so `backend native` (the default) serves
//!   traffic without `make artifacts`' HLO outputs or the `xla` crate.
//! * [`PjrtBackend`] *(feature `pjrt`)* — the AOT-compiled JAX/Pallas
//!   executable through PJRT, unchanged from the original worker path.
//!
//! Workers construct their backend **per thread** from a cloneable
//! [`BackendSpec`]: PJRT handles are not `Send`, and the native backend
//! keeps per-thread scratch buffers, so neither backend ever crosses a
//! thread boundary after construction.

mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::multiplier::MultiplierKind;
use crate::nn::QuantMlp;
use crate::Result;
use std::path::PathBuf;

/// A batch executor. `run_batch` takes the padded row-major
/// `batch × dim` input matrix and returns every output tuple element
/// flattened (the MLP artifacts return a single-element tuple of
/// `batch × out_dim` logits; the native backend mirrors that shape).
///
/// Takes `&mut self` because backends own per-thread state (PJRT device
/// buffers, native scratch); each worker thread owns its backend
/// exclusively.
pub trait ExecBackend {
    /// Stable backend identifier (logs, metrics).
    fn name(&self) -> &'static str;

    /// Execute one padded batch.
    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<Vec<Vec<f32>>>;
}

/// Cloneable recipe a worker thread uses to build its own backend.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// In-process batched LUT-GEMM over the quantized model.
    Native { mlp: QuantMlp, kind: MultiplierKind },
    /// PJRT execution of the HLO-text artifact at `hlo` (feature `pjrt`).
    Pjrt { hlo: PathBuf },
}

impl BackendSpec {
    /// Construct the backend on the calling thread.
    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendSpec::Native { mlp, kind } => {
                Ok(Box::new(NativeBackend::new(mlp.clone(), *kind)))
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { hlo } => Ok(Box::new(PjrtBackend::load(hlo)?)),
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt { hlo } => anyhow::bail!(
                "PJRT backend requested ({}) but this build has no `pjrt` feature — \
                 rebuild with `--features pjrt` or set `backend native`",
                hlo.display()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::MultiplierModel;

    #[test]
    fn native_spec_builds_and_matches_functional_model() {
        let mlp = QuantMlp::random_for_study(21);
        let spec = BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::DncOpt };
        let mut backend = spec.build().unwrap();
        assert_eq!(backend.name(), "native");
        let xs = vec![0.25f32; 2 * 16];
        let out = backend.run_batch(&xs, 2, 16).unwrap();
        assert_eq!(out.len(), 1);
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let want = mlp.forward(&xs[0..16], &model);
        assert_eq!(&out[0][0..8], &want[..]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_clearly_without_feature() {
        let spec = BackendSpec::Pjrt { hlo: PathBuf::from("/tmp/x.hlo.txt") };
        let err = spec.build().unwrap_err();
        assert!(format!("{err:#}").contains("backend native"));
    }
}
