//! Pluggable batch-execution backends for the serving coordinator.
//!
//! The coordinator used to hard-code the external PJRT runtime; this
//! module makes execution a trait so the same serving stack (batcher →
//! router → worker pool → completion pool) runs against any of:
//!
//! * [`NativeBackend`] — the in-process **planned** LUT-GEMM over the
//!   quantized functional model (weights compiled once into code-sorted
//!   column buckets, one LUT-strip expansion per input row summed by a
//!   runtime-dispatched kernel, optional in-batch tiling via the
//!   `gemm.*` knobs — see [`crate::nn::MlpPlan`]).
//!   Zero external dependencies: the whole request path is pure Rust, so
//!   `backend native` (the default) serves traffic without
//!   `make artifacts`' HLO outputs or the `xla` crate.
//! * [`CalibratedBackend`] — the native GEMM plus a per-worker
//!   [`crate::coordinator::Tiler`] that replays every batch on the
//!   simulated LUNA fabric (weight-stationary state persists across
//!   batches) and attaches the [`ScheduleCost`] to the reply; a
//!   `time_scale` knob optionally gates the reply on the simulated
//!   latency mapped to wall-clock.
//! * [`PjrtBackend`] *(feature `pjrt`)* — the AOT-compiled JAX/Pallas
//!   executable through PJRT, unchanged from the original worker path.
//!
//! Workers construct their backend **per thread** from a cloneable
//! [`BackendSpec`]: PJRT handles are not `Send`, and the native backend
//! keeps per-thread scratch buffers, so neither backend ever crosses a
//! thread boundary after construction. The expensive part of the
//! calibrated backend — the gate-level [`UnitCosts`] measurement — is
//! computed once per process and carried *inside* the spec, so spawning
//! more workers never re-runs the event-sim calibration.

mod calibrated;
mod native;
pub mod plan_cache;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use calibrated::CalibratedBackend;
pub use native::NativeBackend;
pub use plan_cache::{ModelEntry, PlanCache};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::coordinator::tiler::{ScheduleCost, Tiler, UnitCosts};
use crate::multiplier::MultiplierKind;
use crate::nn::{GemmOptions, MlpPlan, QuantMlp};
use crate::util::PooledVec;
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Result of one executed batch: the flattened `batch × out_dim` logits
/// (every serving artifact returns a single logits tensor; PJRT's
/// single-element output tuple unwraps to the same shape), plus the
/// simulated CiM cost when the backend models it. The logits buffer is
/// pooled — dropping the output after fan-out recycles it.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Flattened `batch × out_dim` logits.
    pub logits: PooledVec<f32>,
    /// Simulated CiM cost of this batch ([`CalibratedBackend`] only;
    /// `None` from backends that execute without a timing model).
    pub cost: Option<ScheduleCost>,
    /// Host-side wall time the backend spent computing this batch (µs).
    /// Excludes the calibrated backend's simulated-latency gate, so the
    /// metrics can compare host GEMM speed against simulated CiM speed.
    pub host_gemm_us: u64,
}

impl BatchOutput {
    /// Logits with no timing model attached.
    pub fn plain(logits: impl Into<PooledVec<f32>>) -> Self {
        BatchOutput { logits: logits.into(), cost: None, host_gemm_us: 0 }
    }
}

/// A batch executor. `run_batch` takes the padded row-major
/// `batch × dim` input matrix and returns a [`BatchOutput`].
///
/// Takes `&mut self` because backends own per-thread state (PJRT device
/// buffers, native scratch, the calibrated backend's fabric state); each
/// worker thread owns its backend exclusively.
pub trait ExecBackend {
    /// Stable backend identifier (logs, metrics).
    fn name(&self) -> &'static str;

    /// Execute one padded batch.
    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<BatchOutput>;
}

/// Cloneable recipe a worker thread uses to build its own backend.
///
/// `gemm` on the native/calibrated variants is the per-worker planned
/// LUT-GEMM knob set (the `gemm.*` config section): thread cap
/// (`0` = one per available core, `1` = the default single-threaded
/// kernel — worker threads already scale across batches, so in-batch
/// fan-out is opt-in), strip-kernel choice and batch-tiling mode.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// In-process planned LUT-GEMM over the quantized model.
    Native { mlp: QuantMlp, kind: MultiplierKind, gemm: GemmOptions },
    /// Native execution + per-worker `Tiler` schedule replay. `costs` is
    /// the process-shared calibration (measure once, clone everywhere);
    /// `time_scale` maps simulated picoseconds to wall-clock (0 =
    /// report-only, see [`crate::config::TimingConfig`]).
    Calibrated {
        mlp: QuantMlp,
        kind: MultiplierKind,
        costs: UnitCosts,
        banks: usize,
        units_per_bank: usize,
        time_scale: f64,
        gemm: GemmOptions,
    },
    /// PJRT execution of the HLO-text artifact at `hlo` (feature `pjrt`).
    Pjrt { hlo: PathBuf },
}

impl BackendSpec {
    /// Construct the backend on the calling thread.
    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendSpec::Native { mlp, kind, gemm } => {
                Ok(Box::new(NativeBackend::with_options(mlp.clone(), *kind, *gemm)))
            }
            BackendSpec::Calibrated {
                mlp,
                kind,
                costs,
                banks,
                units_per_bank,
                time_scale,
                gemm,
            } => {
                let tiler = Tiler::new(*banks, *units_per_bank, *costs);
                Ok(Box::new(CalibratedBackend::new(mlp.clone(), *kind, tiler, *time_scale, *gemm)))
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { hlo } => Ok(Box::new(PjrtBackend::load(hlo)?)),
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt { hlo } => anyhow::bail!(
                "PJRT backend requested ({}) but this build has no `pjrt` feature — \
                 rebuild with `--features pjrt` or set `backend native`",
                hlo.display()
            ),
        }
    }

    /// Construct the backend over an **already-compiled** shared model +
    /// plan instead of this spec's own model. This is how multi-tenant
    /// workers build per-model executors from plan-cache entries: the
    /// spec contributes the execution *style* (multiplier kind,
    /// calibration, banks, `time_scale`), the cache contributes the
    /// compiled artifacts, and nothing is recompiled or copied per
    /// worker. The PJRT backend is single-model (its executable is the
    /// artifact) and rejects this path.
    pub fn build_for(
        &self,
        mlp: Arc<QuantMlp>,
        plan: Arc<MlpPlan>,
    ) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendSpec::Native { kind, .. } => {
                Ok(Box::new(NativeBackend::from_shared(mlp, plan, *kind)))
            }
            BackendSpec::Calibrated { kind, costs, banks, units_per_bank, time_scale, .. } => {
                let tiler = Tiler::new(*banks, *units_per_bank, *costs);
                Ok(Box::new(CalibratedBackend::from_shared(mlp, plan, *kind, tiler, *time_scale)))
            }
            BackendSpec::Pjrt { hlo } => anyhow::bail!(
                "the PJRT backend ({}) serves a single compiled executable and cannot \
                 execute plan-cache models — use `backend native` or `backend calibrated` \
                 for multi-tenant serving",
                hlo.display()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;
    use crate::multiplier::MultiplierModel;

    #[test]
    fn native_spec_builds_and_matches_functional_model() {
        let mlp = QuantMlp::random_for_study(21);
        for threads in [1usize, 2, 0] {
            let gemm = GemmOptions::with_threads(threads);
            let spec = BackendSpec::Native { mlp: mlp.clone(), kind: MultiplierKind::DncOpt, gemm };
            let mut backend = spec.build().unwrap();
            assert_eq!(backend.name(), "native");
            let xs = vec![0.25f32; 2 * 16];
            let out = backend.run_batch(&xs, 2, 16).unwrap();
            assert_eq!(out.logits.len(), 2 * 8);
            assert!(out.cost.is_none(), "native backend carries no timing model");
            let model = MultiplierModel::new(MultiplierKind::DncOpt);
            let want = mlp.forward(&xs[0..16], &model);
            assert_eq!(&out.logits[0..8], &want[..], "threads {threads}");
        }
    }

    #[test]
    fn calibrated_spec_builds_and_costs_batches() {
        let mlp = QuantMlp::random_for_study(22);
        let lib = tsmc65_library();
        let spec = BackendSpec::Calibrated {
            mlp: mlp.clone(),
            kind: MultiplierKind::DncOpt,
            costs: UnitCosts::measure_cached(MultiplierKind::DncOpt, &lib),
            banks: 16,
            units_per_bank: 4,
            time_scale: 0.0,
            gemm: GemmOptions::with_threads(2),
        };
        let mut backend = spec.build().unwrap();
        assert_eq!(backend.name(), "calibrated");
        let xs = vec![0.25f32; 2 * 16];
        let out = backend.run_batch(&xs, 2, 16).unwrap();
        let cost = out.cost.expect("calibrated backend prices every batch");
        assert!(cost.programs > 0 && cost.energy_fj > 0.0 && cost.latency_ps > 0);
        // bit-exact with the plain native backend, threaded or not
        let gemm = GemmOptions::default();
        let mut nb = BackendSpec::Native { mlp, kind: MultiplierKind::DncOpt, gemm }
            .build()
            .unwrap();
        let native = nb.run_batch(&xs, 2, 16).unwrap();
        assert_eq!(out.logits, native.logits);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_fails_clearly_without_feature() {
        let spec = BackendSpec::Pjrt { hlo: PathBuf::from("/tmp/x.hlo.txt") };
        let err = spec.build().unwrap_err();
        assert!(format!("{err:#}").contains("backend native"));
    }
}
