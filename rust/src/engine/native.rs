//! Native batched LUT-GEMM execution: the quantized functional model run
//! in-process, one flat 256-entry product-table gather per MAC.
//!
//! This is the paper's D&C promise cashed in at serving time: because the
//! LUT multiplication is a table load, a whole `batch × in_dim` matrix
//! runs through [`crate::nn::QuantMlp::forward_batch_with`] with the
//! batch quantized once per layer, the zero-point correction hoisted out
//! of the inner loop, and scratch buffers reused across layers and
//! batches. Bit-exact with the per-sample forward for every
//! [`MultiplierKind`].

use super::{BatchOutput, ExecBackend};
use crate::multiplier::{MultiplierKind, MultiplierModel};
use crate::nn::{BatchScratch, QuantMlp};
use crate::Result;
use anyhow::ensure;

/// In-process batched executor over the quantized MLP.
pub struct NativeBackend {
    mlp: QuantMlp,
    model: MultiplierModel,
    scratch: BatchScratch,
}

impl NativeBackend {
    pub fn new(mlp: QuantMlp, kind: MultiplierKind) -> Self {
        NativeBackend { mlp, model: MultiplierModel::new(kind), scratch: BatchScratch::default() }
    }

    pub fn kind(&self) -> MultiplierKind {
        self.model.kind
    }

    /// The quantized model this backend executes (the calibrated wrapper
    /// replays its schedule on the simulated fabric).
    pub fn mlp(&self) -> &QuantMlp {
        &self.mlp
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<BatchOutput> {
        ensure!(
            dim == self.mlp.input_dim(),
            "input dim {} != model input dim {}",
            dim,
            self.mlp.input_dim()
        );
        ensure!(
            inputs.len() == batch * dim,
            "input length {} != batch {} x dim {}",
            inputs.len(),
            batch,
            dim
        );
        let logits = self.mlp.forward_batch_with(inputs, batch, &self.model, &mut self.scratch);
        Ok(BatchOutput::plain(vec![logits]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_run_is_bit_exact_with_per_sample_forward() {
        let mlp = QuantMlp::random_digits(17);
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let batch = 8;
        let xs: Vec<f32> = (0..batch * 64).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        for kind in MultiplierKind::ALL {
            let mut backend = NativeBackend::new(mlp.clone(), kind);
            let out = backend.run_batch(&xs, batch, 64).unwrap();
            let model = MultiplierModel::new(kind);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 64..(b + 1) * 64], &model);
                assert_eq!(&out.outputs[0][b * 10..(b + 1) * 10], &want[..], "{kind} row {b}");
            }
        }
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let mlp = QuantMlp::random_digits(1);
        let mut backend = NativeBackend::new(mlp, MultiplierKind::Ideal);
        assert!(backend.run_batch(&[0.0; 64], 1, 32).is_err());
        assert!(backend.run_batch(&[0.0; 63], 1, 64).is_err());
    }

    #[test]
    fn scratch_reuse_across_batches_stays_exact() {
        let mlp = QuantMlp::random_digits(2);
        let model = MultiplierModel::new(MultiplierKind::Approx2);
        let mut backend = NativeBackend::new(mlp.clone(), MultiplierKind::Approx2);
        for round in 0..3 {
            let x = vec![0.1 * (round + 1) as f32; 64];
            let mut xs = Vec::new();
            for _ in 0..4 {
                xs.extend_from_slice(&x);
            }
            let out = backend.run_batch(&xs, 4, 64).unwrap();
            let want = mlp.forward(&x, &model);
            for b in 0..4 {
                assert_eq!(
                    &out.outputs[0][b * 10..(b + 1) * 10],
                    &want[..],
                    "round {round} row {b}"
                );
            }
        }
    }
}
