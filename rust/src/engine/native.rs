//! Native planned LUT-GEMM execution: the quantized functional model run
//! in-process through a pre-compiled [`MlpPlan`].
//!
//! This is the paper's D&C promise cashed in at serving time. At backend
//! construction the static weight codes are compiled into per-row
//! 16-bucket column plans; at run time each input row expands the
//! 256-entry product table into an L1-resident per-code LUT strip
//! **once**, so the hot loop is sequential column reads and strip adds —
//! no per-MAC `(w << 4) | x` index arithmetic. Strips are summed by a
//! runtime-dispatched kernel (`gemm.simd`: AVX2/NEON/SWAR/scalar) and
//! batches optionally tile across a persistent worker pool by rows or
//! output spans (`gemm.threads` / `gemm.partition`). Bit-exact with the
//! per-sample forward for every [`MultiplierKind`], kernel, tiling mode
//! and thread count (`tests/gemm_plan.rs`).

use super::{BatchOutput, ExecBackend};
use crate::multiplier::{MultiplierKind, MultiplierModel};
use crate::nn::{GemmOptions, MlpPlan, PlanScratch, QuantMlp};
use crate::util::PooledVec;
use crate::Result;
use anyhow::ensure;
use std::sync::Arc;
use std::time::Instant;

/// In-process planned-LUT-GEMM executor over the quantized MLP.
///
/// The model and its compiled plan are held behind `Arc`s: the plan is
/// the expensive compile-once object, so the multi-tenant plan cache
/// ([`crate::engine::PlanCache`]) compiles it once per model and every
/// worker backend shares the same read-only copy
/// ([`NativeBackend::from_shared`]). Scratch and fabric state stay
/// per-backend, so sharing never crosses the `&mut self` contract.
pub struct NativeBackend {
    mlp: Arc<QuantMlp>,
    plan: Arc<MlpPlan>,
    model: MultiplierModel,
    scratch: PlanScratch,
}

impl NativeBackend {
    /// Single-threaded planned kernel (the serving default: worker
    /// threads already scale across batches).
    pub fn new(mlp: QuantMlp, kind: MultiplierKind) -> Self {
        Self::with_threads(mlp, kind, 1)
    }

    /// Planned kernel with up to `threads` GEMM threads per batch
    /// (`0` = one per available core), kernel and tiling on `auto`.
    pub fn with_threads(mlp: QuantMlp, kind: MultiplierKind, threads: usize) -> Self {
        Self::with_options(mlp, kind, GemmOptions::with_threads(threads))
    }

    /// Planned kernel with the full `gemm.*` knob set (thread cap,
    /// forced strip kernel, tiling mode). Compiles the plan on the
    /// calling thread; cached-plan callers use
    /// [`NativeBackend::from_shared`].
    pub fn with_options(mlp: QuantMlp, kind: MultiplierKind, opts: GemmOptions) -> Self {
        let plan = Arc::new(mlp.plan_with(opts));
        Self::from_shared(Arc::new(mlp), plan, kind)
    }

    /// Planned kernel over an already-compiled shared plan — no compile,
    /// no model copy; this is the plan-cache hit path.
    pub fn from_shared(mlp: Arc<QuantMlp>, plan: Arc<MlpPlan>, kind: MultiplierKind) -> Self {
        NativeBackend {
            mlp,
            plan,
            model: MultiplierModel::new(kind),
            scratch: PlanScratch::default(),
        }
    }

    pub fn kind(&self) -> MultiplierKind {
        self.model.kind
    }

    /// Resolved planned-GEMM thread cap.
    pub fn threads(&self) -> usize {
        self.plan.threads()
    }

    /// The quantized model this backend executes (the calibrated wrapper
    /// replays its schedule on the simulated fabric).
    pub fn mlp(&self) -> &QuantMlp {
        &self.mlp
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<BatchOutput> {
        ensure!(
            dim == self.mlp.input_dim(),
            "input dim {} != model input dim {}",
            dim,
            self.mlp.input_dim()
        );
        ensure!(
            inputs.len() == batch * dim,
            "input length {} != batch {} x dim {}",
            inputs.len(),
            batch,
            dim
        );
        let t0 = Instant::now();
        // pooled output: the logits buffer recycles once the reply path
        // has fanned the batch out (zero steady-state allocations)
        let mut logits = PooledVec::with_capacity(batch * self.mlp.output_dim());
        self.plan.forward_batch_into(inputs, batch, &self.model, &mut self.scratch, &mut logits);
        let mut out = BatchOutput::plain(logits);
        out.host_gemm_us = t0.elapsed().as_micros() as u64;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_run_is_bit_exact_with_per_sample_forward() {
        let mlp = QuantMlp::random_digits(17);
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let batch = 8;
        let xs: Vec<f32> = (0..batch * 64).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        for kind in MultiplierKind::ALL {
            for threads in [1usize, 3] {
                let mut backend = NativeBackend::with_threads(mlp.clone(), kind, threads);
                let out = backend.run_batch(&xs, batch, 64).unwrap();
                let model = MultiplierModel::new(kind);
                for b in 0..batch {
                    let want = mlp.forward(&xs[b * 64..(b + 1) * 64], &model);
                    assert_eq!(
                        &out.logits[b * 10..(b + 1) * 10],
                        &want[..],
                        "{kind} threads {threads} row {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let mlp = QuantMlp::random_digits(1);
        let mut backend = NativeBackend::new(mlp, MultiplierKind::Ideal);
        assert!(backend.run_batch(&[0.0; 64], 1, 32).is_err());
        assert!(backend.run_batch(&[0.0; 63], 1, 64).is_err());
    }

    #[test]
    fn scratch_reuse_across_batches_stays_exact() {
        let mlp = QuantMlp::random_digits(2);
        let model = MultiplierModel::new(MultiplierKind::Approx2);
        let mut backend = NativeBackend::with_threads(mlp.clone(), MultiplierKind::Approx2, 2);
        for round in 0..3 {
            let x = vec![0.1 * (round + 1) as f32; 64];
            let mut xs = Vec::new();
            for _ in 0..4 {
                xs.extend_from_slice(&x);
            }
            let out = backend.run_batch(&xs, 4, 64).unwrap();
            let want = mlp.forward(&x, &model);
            for b in 0..4 {
                assert_eq!(
                    &out.logits[b * 10..(b + 1) * 10],
                    &want[..],
                    "round {round} row {b}"
                );
            }
        }
    }

    #[test]
    fn forced_kernel_and_tiling_stay_bit_exact() {
        use crate::nn::{GemmPartition, GemmSimd};
        let mlp = QuantMlp::random_digits(4);
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let xs = vec![0.3f32; 64];
        let want = mlp.forward(&xs, &model);
        for simd in GemmSimd::ALL {
            for partition in GemmPartition::ALL {
                let opts = GemmOptions { threads: 2, simd, partition };
                let mut backend =
                    NativeBackend::with_options(mlp.clone(), MultiplierKind::DncOpt, opts);
                let out = backend.run_batch(&xs, 1, 64).unwrap();
                assert_eq!(&out.logits[..], &want[..], "{simd:?} {partition:?}");
            }
        }
    }

    #[test]
    fn zero_threads_resolves_and_runs() {
        let mlp = QuantMlp::random_digits(3);
        let mut backend = NativeBackend::with_threads(mlp.clone(), MultiplierKind::DncOpt, 0);
        assert!(backend.threads() >= 1);
        let xs = vec![0.5f32; 2 * 64];
        let out = backend.run_batch(&xs, 2, 64).unwrap();
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        assert_eq!(&out.logits[0..10], &mlp.forward(&xs[0..64], &model)[..]);
    }
}
