//! Calibrated-timing execution: native LUT-GEMM numerics plus a replay of
//! the batch on the simulated LUNA fabric.
//!
//! The paper's claim is a *hardware* cost — energy per MAC and
//! LUT-programming overhead in TSMC 65 nm — but a software backend
//! answers at host speed and reports nothing about the CiM fabric. This
//! backend closes that gap on the reply path: every batch first runs
//! through the wrapped [`NativeBackend`] (so logits stay bit-exact with
//! `backend native`), then is scheduled onto a per-worker
//! [`Tiler`] whose weight-stationary fabric state persists across
//! batches — the first batch a worker serves pays LUT programming, later
//! ones mostly [`ScheduleCost::stationary_hits`]. The resulting
//! [`ScheduleCost`] rides back on the [`BatchOutput`] into per-request
//! replies and the serving metrics.
//!
//! `time_scale` maps simulated picoseconds to wall-clock: after pricing,
//! the worker sleeps `latency_ps × time_scale` simulated-ps-as-wall-ps,
//! so the *simulated* CiM latency gates the reply. `0` (the default)
//! reports costs without sleeping; `1.0` would be "real time" (one
//! simulated ps per wall ps — far below timer resolution for this model);
//! values around `1e4`–`1e6` stretch the schedule into the µs–ms range
//! where batching and queueing behaviour under CiM-speed serving becomes
//! observable.

use super::{BatchOutput, ExecBackend, NativeBackend};
use crate::coordinator::tiler::{ScheduleCost, Tiler};
use crate::multiplier::MultiplierKind;
use crate::nn::{GemmOptions, QuantMlp};
use crate::Result;
use std::time::Duration;

/// Native execution wrapped with per-batch `Tiler` schedule replay and
/// optional simulated-latency gating. Owns its fabric state — construct
/// one per worker thread via [`crate::engine::BackendSpec::build`].
pub struct CalibratedBackend {
    inner: NativeBackend,
    tiler: Tiler,
    time_scale: f64,
}

impl CalibratedBackend {
    /// `tiler` carries the (process-shared) [`crate::coordinator::tiler::UnitCosts`]
    /// calibration and this worker's fabric state; `kind` is the *numeric*
    /// multiplier the GEMM computes with (pricing uses the tiler's costs,
    /// which may substitute — see [`Tiler::pricing_kind`]); `gemm` is the
    /// planned-GEMM knob set (thread cap, strip kernel, tiling mode)
    /// forwarded to the wrapped [`NativeBackend`].
    pub fn new(
        mlp: QuantMlp,
        kind: MultiplierKind,
        tiler: Tiler,
        time_scale: f64,
        gemm: GemmOptions,
    ) -> Self {
        Self::from_inner(NativeBackend::with_options(mlp, kind, gemm), tiler, time_scale)
    }

    /// [`CalibratedBackend::new`] over an already-compiled shared plan —
    /// the plan-cache hit path (see [`NativeBackend::from_shared`]). The
    /// tiler's fabric state is still private to this backend.
    pub fn from_shared(
        mlp: std::sync::Arc<QuantMlp>,
        plan: std::sync::Arc<crate::nn::MlpPlan>,
        kind: MultiplierKind,
        tiler: Tiler,
        time_scale: f64,
    ) -> Self {
        Self::from_inner(NativeBackend::from_shared(mlp, plan, kind), tiler, time_scale)
    }

    fn from_inner(inner: NativeBackend, tiler: Tiler, time_scale: f64) -> Self {
        assert!(time_scale >= 0.0 && time_scale.is_finite(), "time_scale must be finite and >= 0");
        CalibratedBackend { inner, tiler, time_scale }
    }

    /// The wall-clock pause a schedule of `latency_ps` maps to (zero in
    /// report-only mode).
    pub fn gate_duration(&self, cost: &ScheduleCost) -> Duration {
        if self.time_scale == 0.0 {
            return Duration::ZERO;
        }
        // simulated ps × scale = wall ps; /1000 → ns for Duration.
        Duration::from_nanos((cost.latency_ps as f64 * self.time_scale / 1000.0) as u64)
    }
}

impl ExecBackend for CalibratedBackend {
    fn name(&self) -> &'static str {
        "calibrated"
    }

    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<BatchOutput> {
        let mut out = self.inner.run_batch(inputs, batch, dim)?;
        // schedule_cost prices off the tiler's reusable scratch, so a
        // warm worker's replay allocates nothing (hot_path_allocs.rs
        // pins the calibrated backend end to end).
        let cost = self.tiler.schedule_cost(self.inner.mlp(), batch);
        let gate = self.gate_duration(&cost);
        if gate > Duration::ZERO {
            std::thread::sleep(gate);
        }
        out.cost = Some(cost);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;
    use crate::coordinator::tiler::UnitCosts;
    use std::time::Instant;

    /// `random_for_study` has 16·12 + 12·8 = 288 weight elements; a
    /// 288-unit fabric holds the whole model (fully weight-stationary
    /// after the first batch).
    const STUDY_ELEMS: usize = 288;

    fn study_tiler(units: usize) -> Tiler {
        let lib = tsmc65_library();
        Tiler::new(units, 1, UnitCosts::measure_cached(MultiplierKind::DncOpt, &lib))
    }

    #[test]
    fn report_only_is_bit_exact_and_priced() {
        let mlp = QuantMlp::random_for_study(41);
        let mut cal = CalibratedBackend::new(
            mlp.clone(),
            MultiplierKind::Approx,
            study_tiler(32),
            0.0,
            GemmOptions::with_threads(2),
        );
        let mut native = NativeBackend::new(mlp.clone(), MultiplierKind::Approx);
        let xs = vec![0.4f32; 3 * 16];
        let got = cal.run_batch(&xs, 3, 16).unwrap();
        let want = native.run_batch(&xs, 3, 16).unwrap();
        assert_eq!(got.logits, want.logits, "calibrated numerics == native numerics");
        let cost = got.cost.unwrap();
        assert_eq!(cost.programs + cost.stationary_hits, STUDY_ELEMS as u64);
        assert!(cost.energy_fj > 0.0 && cost.latency_ps > 0);
    }

    #[test]
    fn fabric_state_persists_across_batches() {
        let mlp = QuantMlp::random_for_study(42);
        let mut cal = CalibratedBackend::new(
            mlp,
            MultiplierKind::DncOpt,
            study_tiler(STUDY_ELEMS),
            0.0,
            GemmOptions::default(),
        );
        let xs = vec![0.2f32; 2 * 16];
        let first = cal.run_batch(&xs, 2, 16).unwrap().cost.unwrap();
        let second = cal.run_batch(&xs, 2, 16).unwrap().cost.unwrap();
        assert!(first.programs > 0, "fresh fabric must program");
        assert_eq!(second.programs, 0, "model fits the fabric: second batch all hits");
        assert_eq!(second.stationary_hits, STUDY_ELEMS as u64);
        assert!(second.energy_fj < first.energy_fj);
    }

    #[test]
    fn time_scale_gates_the_reply_on_simulated_latency() {
        let mlp = QuantMlp::random_for_study(43);
        // probe the schedule cost with an identical fresh tiler
        let probe_ps = study_tiler(64).schedule(&mlp, 2).latency_ps;
        assert!(probe_ps > 0);
        // pick the scale so the gate sleeps ~2 ms wall-clock
        let scale = 2_000_000.0 * 1000.0 / probe_ps as f64;
        let mut cal = CalibratedBackend::new(
            mlp,
            MultiplierKind::DncOpt,
            study_tiler(64),
            scale,
            GemmOptions::default(),
        );
        let xs = vec![0.3f32; 2 * 16];
        let t0 = Instant::now();
        let out = cal.run_batch(&xs, 2, 16).unwrap();
        let elapsed = t0.elapsed();
        let cost = out.cost.unwrap();
        assert_eq!(cost.latency_ps, probe_ps, "same model + fresh fabric = same schedule");
        // sleep() guarantees at least the requested duration
        assert!(
            elapsed >= cal.gate_duration(&cost),
            "reply returned before the simulated gate: {elapsed:?}"
        );
    }

    #[test]
    fn report_only_gate_is_zero() {
        let mlp = QuantMlp::random_for_study(44);
        let cal = CalibratedBackend::new(
            mlp,
            MultiplierKind::DncOpt,
            study_tiler(16),
            0.0,
            GemmOptions::default(),
        );
        let cost = ScheduleCost { latency_ps: u64::MAX, ..Default::default() };
        assert_eq!(cal.gate_duration(&cost), Duration::ZERO);
    }
}
