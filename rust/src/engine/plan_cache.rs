//! Byte-budgeted LRU cache of compiled model plans — the multi-tenant
//! serving core.
//!
//! LUT-PIM is a capacity–computation tradeoff: what stays resident
//! determines throughput. In this stack the expensive compile-once
//! object is the [`MlpPlan`] (16-bucket code-sorted CSR + strip layout,
//! see [`crate::nn::gemm`]); serving a model the coordinator has never
//! seen costs a full plan compile, serving a resident one costs a map
//! lookup. The cache makes that tradeoff explicit and measurable:
//!
//! * **Byte budget.** Every entry is priced at its actual heap
//!   footprint (model weights + compiled plan buffers); the resident
//!   set never exceeds `max_bytes`. An entry larger than the whole
//!   budget is served *uncached* — the caller gets a usable entry, the
//!   invariant holds, and the next request recompiles.
//! * **LRU eviction.** Each hit stamps a monotonic tick; eviction
//!   removes the least-recently-stamped `Ready` entry until the new
//!   entry fits. Evicted entries stay alive (`Arc`) for any in-flight
//!   batch still executing them.
//! * **Single-flight compilation.** The first miss installs a
//!   `Compiling` marker and compiles outside the lock; concurrent
//!   misses for the same model block on a condvar instead of
//!   recompiling, so N concurrent cold requests trigger exactly one
//!   compile. Waiters record their stall time — the compile-stall
//!   latency the loadgen reports as p99.
//! * **Metrics.** Hits, misses, evictions, compiles, compile time,
//!   stall time and residency gauges land on the shared
//!   [`PlanCacheCounters`] and render as the `plan cache` metrics line.
//!
//! The hit path is allocation-free: one mutex lock, one hash lookup,
//! one tick store, one `Arc` clone. This file is covered by the
//! hot-path lint rules (`repro lint`) like the rest of the serving
//! path.

use crate::coordinator::metrics::PlanCacheCounters;
use crate::net::protocol::ModelId;
use crate::nn::{GemmOptions, MlpPlan, QuantMlp};
use crate::Result;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One resident model: the quantized weights, the compiled plan, and
/// the byte price the cache charges for keeping both. Shared read-only
/// (`Arc`) between the cache, the per-shard batch lanes and every
/// worker backend built from it.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub model: ModelId,
    pub mlp: Arc<QuantMlp>,
    pub plan: Arc<MlpPlan>,
    /// Heap bytes of `mlp` + `plan` — the cache's unit of account.
    pub bytes: usize,
}

impl ModelEntry {
    /// Compile `mlp` into an entry (this is the expensive call the
    /// cache exists to amortize). `gemm` is the full `gemm.*` knob set
    /// (thread cap, strip kernel, tiling mode) the plan compiles
    /// against.
    pub fn compile(model: ModelId, mlp: QuantMlp, gemm: GemmOptions) -> Self {
        let plan = mlp.plan_with(gemm);
        let bytes = mlp.heap_bytes() + plan.heap_bytes();
        ModelEntry { model, mlp: Arc::new(mlp), plan: Arc::new(plan), bytes }
    }
}

enum Slot {
    /// Compiled and servable; `tick` is the last-use stamp (LRU key).
    Ready { entry: Arc<ModelEntry>, tick: u64 },
    /// A thread is compiling this model outside the lock; misses wait
    /// on the condvar instead of duplicating the compile.
    Compiling,
}

struct Inner {
    slots: HashMap<ModelId, Slot>,
    /// Total bytes of all `Ready` entries (the budget invariant:
    /// `used <= max_bytes` at every lock release).
    used: usize,
    /// Monotonic LRU clock, bumped on every hit and insert.
    tick: u64,
}

/// Size-bounded, single-flight LRU of compiled model plans. See the
/// module docs for the contract; constructed once per
/// [`crate::coordinator::CoordinatorServer`] and shared (`Arc`) with
/// every submit path.
pub struct PlanCache {
    max_bytes: usize,
    counters: Arc<PlanCacheCounters>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl PlanCache {
    /// `max_bytes` bounds the resident set (must be ≥ 1 — a zero budget
    /// would cache nothing and recompile every request silently).
    /// `counters` is shared with the serving metrics so the `plan
    /// cache` line renders from the same numbers the cache records.
    pub fn new(max_bytes: usize, counters: Arc<PlanCacheCounters>) -> Self {
        assert!(max_bytes >= 1, "plan cache budget must be >= 1 byte");
        PlanCache {
            max_bytes,
            counters,
            inner: Mutex::new(Inner { slots: HashMap::new(), used: 0, tick: 0 }),
            cv: Condvar::new(),
        }
    }

    /// A cache with its own private counters (tests, tools).
    pub fn standalone(max_bytes: usize) -> Self {
        Self::new(max_bytes, Arc::new(PlanCacheCounters::default()))
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    pub fn counters(&self) -> &Arc<PlanCacheCounters> {
        &self.counters
    }

    /// Bytes currently resident (always ≤ [`PlanCache::max_bytes`]).
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().used
    }

    /// Is `model` resident and ready right now?
    pub fn is_resident(&self, model: ModelId) -> bool {
        matches!(self.inner.lock().unwrap().slots.get(&model), Some(Slot::Ready { .. }))
    }

    /// Look up `model`, compiling it with `compile` on a miss.
    ///
    /// * **Hit:** stamps the LRU tick and returns the shared entry —
    ///   one lock, one lookup, one `Arc` clone, no allocation.
    /// * **Miss, first:** installs the single-flight marker, runs
    ///   `compile` *outside* the lock (other models keep hitting
    ///   meanwhile), then inserts under the byte budget, evicting LRU
    ///   entries as needed.
    /// * **Miss, concurrent:** blocks until the in-flight compile
    ///   resolves, recording the stall; every concurrent miss counts as
    ///   a miss but only the compiling thread counts a compile.
    ///
    /// An entry reporting more bytes than the entire budget is returned
    /// uncached (the budget invariant outranks residency). A failed
    /// compile clears the marker and propagates the error; the next
    /// request retries.
    pub fn get_or_compile<F>(&self, model: ModelId, compile: F) -> Result<Arc<ModelEntry>>
    where
        F: FnOnce() -> Result<ModelEntry>,
    {
        let mut counted = false;
        let mut stall_start: Option<Instant> = None;
        let mut inner = self.inner.lock().unwrap();
        loop {
            let now = inner.tick + 1;
            match inner.slots.get_mut(&model) {
                Some(Slot::Ready { entry, tick }) => {
                    *tick = now;
                    let entry = entry.clone();
                    inner.tick = now;
                    match stall_start {
                        // we waited behind another thread's compile:
                        // already counted as a miss, record the stall
                        Some(t0) => self.counters.record_stall_us(t0.elapsed().as_micros() as u64),
                        None => self.counters.record_hit(),
                    }
                    return Ok(entry);
                }
                Some(Slot::Compiling) => {
                    if !counted {
                        counted = true;
                        self.counters.record_miss();
                        stall_start = Some(Instant::now());
                    }
                    inner = self.cv.wait(inner).unwrap();
                }
                None => {
                    if !counted {
                        self.counters.record_miss();
                    }
                    inner.slots.insert(model, Slot::Compiling);
                    break;
                }
            }
        }
        drop(inner);

        let t0 = Instant::now();
        let compiled = compile();
        let compile_us = t0.elapsed().as_micros() as u64;

        let mut inner = self.inner.lock().unwrap();
        let entry = match compiled {
            Ok(entry) => Arc::new(entry),
            Err(e) => {
                // clear the marker so waiters retry (one becomes the
                // next compiler) instead of hanging on a dead compile
                inner.slots.remove(&model);
                self.cv.notify_all();
                return Err(e);
            }
        };
        self.counters.record_compile_us(compile_us);
        if entry.bytes > self.max_bytes {
            // oversize: serve it, but never admit it — the budget
            // invariant holds and the next request recompiles
            inner.slots.remove(&model);
            self.cv.notify_all();
            return Ok(entry);
        }
        while inner.used + entry.bytes > self.max_bytes {
            // LRU victim: the Ready slot with the oldest tick. `used`
            // only counts Ready entries, so whenever the loop runs a
            // victim exists and the loop strictly shrinks `used`.
            let mut victim: Option<(u64, ModelId)> = None;
            for (m, s) in inner.slots.iter() {
                if let Slot::Ready { tick, .. } = s {
                    let older = match victim {
                        Some((t, _)) => *tick < t,
                        None => true,
                    };
                    if older {
                        victim = Some((*tick, *m));
                    }
                }
            }
            let Some((_, m)) = victim else { break };
            if let Some(Slot::Ready { entry: evicted, .. }) = inner.slots.remove(&m) {
                inner.used -= evicted.bytes;
                self.counters.record_eviction();
            }
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.used += entry.bytes;
        inner.slots.insert(model, Slot::Ready { entry: entry.clone(), tick });
        self.publish_gauges(&inner);
        self.cv.notify_all();
        Ok(entry)
    }

    /// Drop `model`'s resident entry (hot-swap retire). In-flight
    /// batches keep their `Arc`; the bytes leave the budget now.
    /// Returns whether an entry was resident. The coordinator only
    /// calls this after draining the model's in-flight requests, so an
    /// in-progress compile marker for it cannot exist here; if one
    /// does (direct API use), it is left for the compiling thread.
    pub fn retire(&self, model: ModelId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.slots.get(&model) {
            Some(Slot::Ready { .. }) => {
                if let Some(Slot::Ready { entry, .. }) = inner.slots.remove(&model) {
                    inner.used -= entry.bytes;
                }
                self.publish_gauges(&inner);
                true
            }
            Some(Slot::Compiling) | None => false,
        }
    }

    fn publish_gauges(&self, inner: &Inner) {
        let models = inner.slots.values().filter(|s| matches!(s, Slot::Ready { .. })).count();
        self.counters.set_resident(models as u64, inner.used as u64);
    }
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("PlanCache")
            .field("max_bytes", &self.max_bytes)
            .field("used", &inner.used)
            .field("models", &inner.slots.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn mid(s: &str) -> ModelId {
        ModelId::new(s).unwrap()
    }

    fn entry(name: &str, seed: u64) -> ModelEntry {
        ModelEntry::compile(mid(name), QuantMlp::random_digits(seed), GemmOptions::default())
    }

    #[test]
    fn hits_share_one_entry_and_count() {
        let cache = PlanCache::standalone(64 << 20);
        let a1 = cache.get_or_compile(mid("a"), || Ok(entry("a", 1))).unwrap();
        let a2 = cache.get_or_compile(mid("a"), || panic!("must not recompile")).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "hit must return the same entry");
        assert_eq!(cache.counters().hits(), 1);
        assert_eq!(cache.counters().misses(), 1);
        assert_eq!(cache.counters().compiles(), 1);
        assert!(cache.is_resident(mid("a")));
        assert_eq!(cache.resident_bytes(), a1.bytes);
    }

    #[test]
    fn budget_evicts_least_recently_used() {
        let one = entry("a", 1).bytes;
        // room for exactly two digit models
        let cache = PlanCache::standalone(2 * one + one / 2);
        cache.get_or_compile(mid("a"), || Ok(entry("a", 1))).unwrap();
        cache.get_or_compile(mid("b"), || Ok(entry("b", 2))).unwrap();
        // touch `a` so `b` is the LRU victim
        cache.get_or_compile(mid("a"), || panic!("resident")).unwrap();
        cache.get_or_compile(mid("c"), || Ok(entry("c", 3))).unwrap();
        assert!(cache.is_resident(mid("a")), "recently used survives");
        assert!(!cache.is_resident(mid("b")), "LRU entry evicted");
        assert!(cache.is_resident(mid("c")));
        assert!(cache.resident_bytes() <= cache.max_bytes());
        assert_eq!(cache.counters().misses(), 3);
    }

    #[test]
    fn oversize_entries_are_served_uncached() {
        let cache = PlanCache::standalone(16); // smaller than any real model
        let e = cache.get_or_compile(mid("big"), || Ok(entry("big", 4))).unwrap();
        assert!(e.bytes > cache.max_bytes());
        assert!(!cache.is_resident(mid("big")));
        assert_eq!(cache.resident_bytes(), 0);
        // next lookup misses again (recompile, still served)
        cache.get_or_compile(mid("big"), || Ok(entry("big", 4))).unwrap();
        assert_eq!(cache.counters().compiles(), 2);
    }

    #[test]
    fn failed_compiles_clear_the_marker_and_retry() {
        let cache = PlanCache::standalone(64 << 20);
        let err = cache.get_or_compile(mid("a"), || anyhow::bail!("no artifact"));
        assert!(err.is_err());
        assert!(!cache.is_resident(mid("a")));
        cache.get_or_compile(mid("a"), || Ok(entry("a", 5))).unwrap();
        assert!(cache.is_resident(mid("a")));
    }

    #[test]
    fn retire_frees_budget_but_keeps_shared_entries_alive() {
        let cache = PlanCache::standalone(64 << 20);
        let held = cache.get_or_compile(mid("a"), || Ok(entry("a", 6))).unwrap();
        assert!(cache.retire(mid("a")));
        assert!(!cache.is_resident(mid("a")));
        assert_eq!(cache.resident_bytes(), 0);
        assert!(!cache.retire(mid("a")), "second retire is a no-op");
        // the in-flight handle still works (Arc keeps the plan alive)
        assert_eq!(held.plan.input_dim(), held.mlp.input_dim());
    }

    #[test]
    fn concurrent_cold_gets_compile_exactly_once() {
        let cache = Arc::new(PlanCache::standalone(64 << 20));
        let compiles = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cache = Arc::clone(&cache);
                let compiles = Arc::clone(&compiles);
                s.spawn(move || {
                    let e = cache
                        .get_or_compile(mid("shared"), || {
                            // ordering: test-only event counter, no
                            // publication — Relaxed is sufficient
                            compiles.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            Ok(entry("shared", 7))
                        })
                        .unwrap();
                    assert_eq!(e.model, mid("shared"));
                });
            }
        });
        assert_eq!(compiles.load(Ordering::Relaxed), 1, "single-flight");
        assert_eq!(cache.counters().compiles(), 1);
        assert_eq!(cache.counters().misses() + cache.counters().hits(), 8);
    }
}
