//! PJRT-backed execution (feature `pjrt`): wraps the AOT-compiled
//! JAX/Pallas HLO-text artifact behind [`ExecBackend`], preserving the
//! original worker semantics (one client + executable per thread).

use super::{BatchOutput, ExecBackend};
use crate::runtime::{CompiledModel, PjrtRuntime};
use crate::Result;
use std::path::Path;

/// One compiled PJRT executable. Not `Send` — build per worker thread
/// via [`crate::engine::BackendSpec::build`].
pub struct PjrtBackend {
    model: CompiledModel,
}

impl PjrtBackend {
    /// Create a CPU client and compile the HLO-text artifact at `hlo`.
    pub fn load(hlo: impl AsRef<Path>) -> Result<Self> {
        let rt = PjrtRuntime::cpu()?;
        Ok(PjrtBackend { model: rt.load_hlo_text(hlo)? })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run_batch(&mut self, inputs: &[f32], batch: usize, dim: usize) -> Result<BatchOutput> {
        let t0 = std::time::Instant::now();
        let mut outputs = self.model.run_f32(&[(inputs, &[batch as i64, dim as i64])])?;
        // The serving artifacts lower to a single-element output tuple;
        // the logits tensor is its first element.
        anyhow::ensure!(!outputs.is_empty(), "executable returned an empty output tuple");
        let mut out = BatchOutput::plain(outputs.swap_remove(0));
        out.host_gemm_us = t0.elapsed().as_micros() as u64;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  add = f32[2,3]{1,0} add(p0, p0)
  ROOT t = (f32[2,3]{1,0}) tuple(add)
}
"#;

    #[test]
    fn pjrt_backend_runs_hlo_text() {
        let dir = crate::util::test_dir("engine-pjrt");
        let path = dir.join("double.hlo.txt");
        std::fs::write(&path, DOUBLE_HLO).unwrap();
        let mut backend = PjrtBackend::load(&path).unwrap();
        let inputs: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let out = backend.run_batch(&inputs, 2, 3).unwrap();
        let expect: Vec<f32> = inputs.iter().map(|v| v * 2.0).collect();
        assert_eq!(out.logits, expect);
        assert!(out.cost.is_none());
    }

    #[test]
    fn missing_artifact_fails() {
        assert!(PjrtBackend::load("/no/such/file.hlo.txt").is_err());
    }
}
