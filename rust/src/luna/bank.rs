//! A LUNA-CiM bank: an 8×8 SRAM array hosting four LUNA units (Fig 17),
//! with the Fig 18 area split and §IV.B energy accounting.

use super::LunaUnit;
use crate::cells::{CellLibrary, CostReport};
use crate::multiplier::MultiplierKind;
use crate::sram::{EnergyLedger, SramArray};

/// The Fig 18 area report.
#[derive(Debug, Clone)]
pub struct BankAreaReport {
    pub array_um2: f64,
    pub unit_um2: f64,
    pub units_total_um2: f64,
    pub total_um2: f64,
    /// LUNA units' share of the total (paper: 32 %).
    pub overhead_fraction: f64,
}

/// An 8×8 SRAM array with four LUNA-CiM units inserted between row pairs
/// (unit `u` takes inputs from row `2u` and writes results to row `2u+1`).
#[derive(Debug, Clone)]
pub struct LunaBank {
    pub array: SramArray,
    pub units: Vec<LunaUnit>,
}

impl LunaBank {
    /// The paper's configuration: 8×8 array + four units of `kind`.
    pub fn paper_config(kind: MultiplierKind) -> Self {
        LunaBank {
            array: SramArray::paper_8x8(),
            units: (0..4).map(|_| LunaUnit::new(kind)).collect(),
        }
    }

    /// Build with an arbitrary number of units.
    pub fn new(kind: MultiplierKind, n_units: usize) -> Self {
        assert!(n_units >= 1 && n_units <= 4, "an 8x8 array hosts 1..=4 units");
        LunaBank {
            array: SramArray::paper_8x8(),
            units: (0..n_units).map(|_| LunaUnit::new(kind)).collect(),
        }
    }

    /// Program unit `u` with weight `w` (LUT write via the array's write
    /// path, charged per bit).
    pub fn program_unit(&mut self, lib: &CellLibrary, u: usize, w: u8) {
        self.units[u].program(lib, w);
    }

    /// Fig 17 dataflow for one multiply on unit `u`: `Y` is written into
    /// the unit's upper row, the unit computes, and the 8-bit product is
    /// written back to the lower row. Returns the product.
    pub fn mac_through_rows(&mut self, lib: &CellLibrary, u: usize, y: u8) -> u8 {
        assert!(u < self.units.len());
        let upper = 2 * u;
        let lower = 2 * u + 1;
        self.array.write_row(lib, upper, y as u64);
        let read_back = self.array.read_row(lib, upper) as u8;
        let out = self.units[u].multiply(lib, read_back);
        self.array.write_row(lib, lower, out as u64);
        out
    }

    /// Fast-path multiply that bypasses the row traffic (the steady-state
    /// weight-stationary mode the coordinator uses; operands stream on
    /// bitlines without full row rewrites).
    pub fn mac(&mut self, lib: &CellLibrary, u: usize, y: u8) -> u8 {
        self.units[u].multiply(lib, y)
    }

    /// Total component inventory: array + units.
    pub fn cost(&self) -> CostReport {
        self.units.iter().fold(self.array.cost(), |acc, u| acc + u.cost())
    }

    /// The Fig 18 pie chart numbers.
    pub fn area_report(&self, lib: &CellLibrary) -> BankAreaReport {
        let array_um2 = self.array.cost().routed_area_um2(lib);
        let unit_um2 = self.units.first().map(|u| u.area_um2(lib)).unwrap_or(0.0);
        let units_total_um2: f64 = self.units.iter().map(|u| u.area_um2(lib)).sum();
        let total_um2 = array_um2 + units_total_um2;
        BankAreaReport {
            array_um2,
            unit_um2,
            units_total_um2,
            total_um2,
            overhead_fraction: units_total_um2 / total_um2,
        }
    }

    /// Merged energy ledger (array accesses + all unit activity).
    pub fn ledger(&self) -> EnergyLedger {
        let mut l = self.array.ledger().clone();
        for u in &self.units {
            l.merge(u.ledger());
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65::{PAPER_TOTAL_AREA_UM2, PAPER_UNIT_AREA_UM2};
    use crate::cells::tsmc65_library;

    #[test]
    fn fig18_area_numbers() {
        let lib = tsmc65_library();
        let bank = LunaBank::paper_config(MultiplierKind::DncOpt);
        let rep = bank.area_report(&lib);
        assert!((rep.unit_um2 - PAPER_UNIT_AREA_UM2).abs() < 0.5, "unit {}", rep.unit_um2);
        assert!(
            (rep.total_um2 - PAPER_TOTAL_AREA_UM2).abs() / PAPER_TOTAL_AREA_UM2 < 0.01,
            "total {}",
            rep.total_um2
        );
        // Paper: 32 % overhead.
        assert!((rep.overhead_fraction - 0.32).abs() < 0.01, "{}", rep.overhead_fraction);
    }

    #[test]
    fn fig17_dataflow_produces_products() {
        let lib = tsmc65_library();
        let mut bank = LunaBank::paper_config(MultiplierKind::DncOpt);
        // The paper's §IV.B stimulus: W = 0110, Y ∈ {1010, 1011, 0011, 1100}.
        bank.program_unit(&lib, 0, 0b0110);
        for (y, expect) in [(0b1010u8, 60u8), (0b1011, 66), (0b0011, 18), (0b1100, 72)] {
            assert_eq!(bank.mac_through_rows(&lib, 0, y), expect);
        }
        // Results persisted in the lower row.
        assert_eq!(bank.array.peek(1, 3), (72 >> 3) & 1 == 1);
    }

    #[test]
    fn energy_ledger_merges_units_and_array() {
        let lib = tsmc65_library();
        let mut bank = LunaBank::new(MultiplierKind::DncOpt, 2);
        bank.program_unit(&lib, 0, 5);
        bank.program_unit(&lib, 1, 9);
        let _ = bank.mac(&lib, 0, 7);
        let _ = bank.mac(&lib, 1, 2);
        let ledger = bank.ledger();
        assert!(ledger.total_fj() > 0.0);
        assert!(ledger.accesses() >= 20, "programming writes recorded");
    }

    #[test]
    #[should_panic]
    fn too_many_units_rejected() {
        let _ = LunaBank::new(MultiplierKind::DncOpt, 5);
    }
}
