//! One LUNA-CiM unit: a programmable LUT multiplier with energy accounting.

use crate::cells::{CellKind, CellLibrary, CostReport};
use crate::logic::{Netlist, Stepper};
use crate::multiplier::MultiplierKind;
use crate::sram::EnergyLedger;

/// A programmed LUT-multiplier instance. Owns its netlist and simulation
/// state; every multiply runs through the gate-level stepper so dynamic
/// energy comes from measured switching activity, and every reprogram is
/// charged at the calibrated SRAM write energy.
#[derive(Debug, Clone)]
pub struct LunaUnit {
    kind: MultiplierKind,
    netlist: Netlist,
    stepper: Stepper,
    programmed: Option<u8>,
    /// Number of multiplies performed since construction.
    pub ops: u64,
    /// Number of (re)programming events.
    pub programs: u64,
    ledger: EnergyLedger,
}

impl LunaUnit {
    /// Create a unit for a netlist-backed configuration.
    ///
    /// # Panics
    /// Panics for [`MultiplierKind::Ideal`], which has no hardware.
    pub fn new(kind: MultiplierKind) -> Self {
        let netlist = kind
            .netlist()
            .unwrap_or_else(|| panic!("{kind} has no hardware netlist"));
        let stepper = Stepper::new(&netlist);
        LunaUnit { kind, netlist, stepper, programmed: None, ops: 0, programs: 0, ledger: EnergyLedger::default() }
    }

    pub fn kind(&self) -> MultiplierKind {
        self.kind
    }

    pub fn programmed_weight(&self) -> Option<u8> {
        self.programmed
    }

    /// Program weight `w` into the unit's LUT. Charges one SRAM write per
    /// stored bit (the paper's per-bit write-energy accounting). A no-op
    /// if the same weight is already programmed (weight-stationary reuse).
    pub fn program(&mut self, lib: &CellLibrary, w: u8) {
        if self.programmed == Some(w) {
            return;
        }
        let image = self.kind.program_image(w).expect("netlist-backed kind");
        for _ in 0..image.len() {
            self.ledger.charge(lib, crate::sram::AccessKind::WriteBit);
        }
        self.stepper.program(&image);
        self.programmed = Some(w);
        self.programs += 1;
    }

    /// Multiply the programmed weight by `y` in the gate-level model.
    /// Charges toggle energy to the multiplier's component class.
    ///
    /// # Panics
    /// Panics if the unit has not been programmed.
    pub fn multiply(&mut self, lib: &CellLibrary, y: u8) -> u8 {
        assert!(self.programmed.is_some(), "unit must be programmed before multiplying");
        assert!(y < 16, "4-bit operand");
        let (out, toggles) = self.stepper.step_fast(&self.netlist, y as u64);
        let fj: f64 = CellKind::ALL
            .iter()
            .map(|&k| toggles[k.index()] as f64 * lib.params(k).energy_per_toggle_fj)
            .sum();
        self.ledger.charge_external(CellKind::Mux2, fj);
        self.ops += 1;
        out as u8
    }

    /// Component inventory (counts from the actual netlist).
    pub fn cost(&self) -> CostReport {
        self.netlist.cost_report()
    }

    /// Routed area of the unit in µm² — 287 µm² for the optimized D&C
    /// configuration under the calibrated library (Fig 18).
    pub fn area_um2(&self, lib: &CellLibrary) -> f64 {
        self.cost().routed_area_um2(lib)
    }

    /// Accumulated energy ledger (programming writes + multiply toggles).
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Average dynamic energy per multiply so far, in femtojoules
    /// (the paper's 47.96 fJ figure for the mux-based multiplier).
    pub fn avg_multiply_energy_fj(&self) -> f64 {
        if self.ops == 0 {
            return 0.0;
        }
        self.ledger.breakdown().get(CellKind::Mux2) / self.ops as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;

    #[test]
    fn unit_multiplies_correctly_for_exact_kinds() {
        let lib = tsmc65_library();
        for kind in [MultiplierKind::DncOpt, MultiplierKind::Dnc, MultiplierKind::Traditional] {
            let mut u = LunaUnit::new(kind);
            for w in [0u8, 3, 6, 15] {
                u.program(&lib, w);
                for y in 0..16u8 {
                    assert_eq!(u.multiply(&lib, y), w * y, "{kind} w={w} y={y}");
                }
            }
        }
    }

    #[test]
    fn reprogramming_same_weight_is_free() {
        let lib = tsmc65_library();
        let mut u = LunaUnit::new(MultiplierKind::DncOpt);
        u.program(&lib, 6);
        let before = u.ledger().total_fj();
        u.program(&lib, 6);
        assert_eq!(u.ledger().total_fj(), before);
        assert_eq!(u.programs, 1);
        u.program(&lib, 7);
        assert!(u.ledger().total_fj() > before);
        assert_eq!(u.programs, 2);
    }

    #[test]
    fn programming_energy_scales_with_lut_bits() {
        let lib = tsmc65_library();
        let mut opt = LunaUnit::new(MultiplierKind::DncOpt); // 10 bits
        let mut trad = LunaUnit::new(MultiplierKind::Traditional); // 128 bits
        opt.program(&lib, 5);
        trad.program(&lib, 5);
        let ratio = trad.ledger().total_fj() / opt.ledger().total_fj();
        assert!((ratio - 12.8).abs() < 1e-9, "128/10 bits, got {ratio}");
    }

    #[test]
    fn unit_area_matches_fig18_for_dnc_opt() {
        let lib = tsmc65_library();
        let u = LunaUnit::new(MultiplierKind::DncOpt);
        let area = u.area_um2(&lib);
        assert!((area - crate::cells::tsmc65::PAPER_UNIT_AREA_UM2).abs() < 0.5, "area {area}");
    }

    #[test]
    fn multiply_energy_is_recorded() {
        let lib = tsmc65_library();
        let mut u = LunaUnit::new(MultiplierKind::DncOpt);
        u.program(&lib, 6);
        // Alternate operands so the mux trees actually switch.
        for y in [10u8, 11, 3, 12, 5, 9, 0, 15] {
            let _ = u.multiply(&lib, y);
        }
        assert!(u.avg_multiply_energy_fj() > 0.0);
        assert_eq!(u.ops, 8);
    }

    #[test]
    fn multiply_energy_calibrated_to_paper_47_96_fj() {
        // §IV.B: 47.96 fJ per multiply under the paper's stimulus
        // (W = 0110, Y cycling 1010/1011/0011/1100).
        let lib = tsmc65_library();
        let mut u = LunaUnit::new(MultiplierKind::DncOpt);
        u.program(&lib, 0b0110);
        for _ in 0..64 {
            for y in [0b1010u8, 0b1011, 0b0011, 0b1100] {
                let _ = u.multiply(&lib, y);
            }
        }
        let e = u.avg_multiply_energy_fj();
        let paper = crate::cells::tsmc65::PAPER_MULT_ENERGY_FJ;
        assert!((e - paper).abs() / paper < 0.05, "measured {e} fJ vs paper {paper}");
    }

    #[test]
    #[should_panic]
    fn multiply_before_programming_panics() {
        let lib = tsmc65_library();
        let mut u = LunaUnit::new(MultiplierKind::DncOpt);
        let _ = u.multiply(&lib, 3);
    }

    #[test]
    #[should_panic]
    fn ideal_has_no_hardware() {
        let _ = LunaUnit::new(MultiplierKind::Ideal);
    }
}
