//! LUNA-CiM units and banks — the paper's Fig 17 integration.
//!
//! A **unit** is one mux-based LUT multiplier embedded between two SRAM
//! rows: it is programmed with a weight (LUT write = SRAM row writes,
//! charged at the array's per-bit write energy), takes `Y` from the upper
//! row and delivers the product to the lower row. A **bank** is an 8×8
//! SRAM array hosting four units (the paper's maximum-overhead
//! configuration), with the Fig 18 area accounting.

mod bank;
mod mapping;
mod unit;

pub use bank::{BankAreaReport, LunaBank};
pub use mapping::{BankFabric, MappedLayerRun};
pub use unit::LunaUnit;
