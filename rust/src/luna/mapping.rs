//! Layer-to-bank mapping with the Fig 17 row dataflow.
//!
//! The tiler (`coordinator::tiler`) prices schedules analytically; this
//! module *executes* them on gate-level banks: every MAC of a quantized
//! linear layer runs through a programmed [`LunaUnit`] inside an 8×8
//! array, with operands and products moving through SRAM rows exactly as
//! Fig 17 draws it. Slow (gate-level), but it closes the loop: the
//! analytic cost model and the functional result are both validated
//! against `nn::QuantLinear` arithmetic.

use super::LunaBank;
use crate::cells::CellLibrary;
use crate::multiplier::MultiplierKind;
use crate::nn::QuantLinear;
use crate::sram::EnergyLedger;

/// Result of executing one layer on the fabric.
#[derive(Debug)]
pub struct MappedLayerRun {
    /// Integer accumulators per output neuron (zero-point corrected) —
    /// must equal `QuantLinear::accumulate`.
    pub acc: Vec<i32>,
    /// MACs executed on units.
    pub macs: u64,
    /// LUT (re)programming events.
    pub programs: u64,
    /// Merged energy ledger of all banks (programming + row traffic +
    /// multiplier switching).
    pub ledger: EnergyLedger,
}

/// A pool of gate-level banks executing layers weight-stationarily.
pub struct BankFabric {
    banks: Vec<LunaBank>,
    kind: MultiplierKind,
}

impl BankFabric {
    pub fn new(kind: MultiplierKind, banks: usize, units_per_bank: usize) -> Self {
        assert!(banks >= 1);
        BankFabric { banks: (0..banks).map(|_| LunaBank::new(kind, units_per_bank)).collect(), kind }
    }

    pub fn total_units(&self) -> usize {
        self.banks.iter().map(|b| b.units.len()).sum()
    }

    pub fn kind(&self) -> MultiplierKind {
        self.kind
    }

    fn unit_mut(&mut self, linear: usize) -> (&mut LunaBank, usize) {
        let per = self.banks[0].units.len();
        let bank = (linear / per) % self.banks.len();
        let unit = linear % per;
        (&mut self.banks[bank], unit)
    }

    /// Execute one layer on the fabric with the Fig 17 row dataflow:
    /// weight codes are assigned to units round-robin (matching the
    /// tiler's placement), each unit is programmed (weight-stationary)
    /// and multiplies its activation operand via its array rows.
    ///
    /// Only exact configurations reproduce `QuantLinear::accumulate`
    /// bit-for-bit; approximate ones reproduce their variant arithmetic.
    pub fn run_layer(&mut self, lib: &CellLibrary, layer: &QuantLinear, xq: &[u8]) -> MappedLayerRun {
        assert_eq!(xq.len(), layer.in_dim);
        let units = self.total_units();
        let x_sum: i32 = xq.iter().map(|&x| x as i32).sum();
        let zp = layer.w_quant.zero_point as i32;
        let mut acc = vec![0i32; layer.out_dim];
        let mut macs = 0u64;
        let mut programs = 0u64;
        for o in 0..layer.out_dim {
            let row = &layer.wq[o * layer.in_dim..(o + 1) * layer.in_dim];
            let mut lut_sum = 0i32;
            for (i, (&w, &x)) in row.iter().zip(xq).enumerate() {
                let linear = (o * layer.in_dim + i) % units;
                let (bank, unit) = self.unit_mut(linear);
                if bank.units[unit].programmed_weight() != Some(w) {
                    bank.program_unit(lib, unit, w);
                    programs += 1;
                }
                // Fig 17 dataflow: operand through the unit's upper row,
                // product written back to its lower row.
                lut_sum += bank.mac_through_rows(lib, unit, x) as i32;
                macs += 1;
            }
            acc[o] = lut_sum - zp * x_sum;
        }
        let mut ledger = EnergyLedger::default();
        for b in &self.banks {
            ledger.merge(&b.ledger());
        }
        MappedLayerRun { acc, macs, programs, ledger }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::tsmc65_library;
    use crate::multiplier::MultiplierModel;
    use crate::nn::QuantMlp;

    #[test]
    fn fabric_reproduces_quantlinear_accumulate_exactly() {
        let lib = tsmc65_library();
        let mlp = QuantMlp::random_for_study(11);
        let layer = &mlp.layers[1]; // 12 -> 8
        let xq: Vec<u8> = (0..layer.in_dim).map(|i| (i as u8 * 5) % 16).collect();
        let mut fabric = BankFabric::new(MultiplierKind::DncOpt, 4, 4);
        let run = fabric.run_layer(&lib, layer, &xq);
        let want = layer.accumulate(&xq, &MultiplierModel::new(MultiplierKind::DncOpt));
        assert_eq!(run.acc, want, "gate-level fabric != integer model");
        assert_eq!(run.macs, (layer.in_dim * layer.out_dim) as u64);
        assert!(run.ledger.total_fj() > 0.0);
    }

    #[test]
    fn fabric_reproduces_approx_variant_arithmetic() {
        let lib = tsmc65_library();
        let mlp = QuantMlp::random_for_study(12);
        let layer = &mlp.layers[1];
        let xq: Vec<u8> = (0..layer.in_dim).map(|i| (3 + i as u8 * 7) % 16).collect();
        let mut fabric = BankFabric::new(MultiplierKind::Approx, 2, 4);
        let run = fabric.run_layer(&lib, layer, &xq);
        let want = layer.accumulate(&xq, &MultiplierModel::new(MultiplierKind::Approx));
        assert_eq!(run.acc, want);
    }

    #[test]
    fn weight_stationary_reuse_reduces_programs_on_second_run() {
        let lib = tsmc65_library();
        let mlp = QuantMlp::random_for_study(13);
        let layer = &mlp.layers[1];
        let xq: Vec<u8> = vec![7; layer.in_dim];
        // fabric big enough to hold the whole layer
        let units_needed = layer.in_dim * layer.out_dim;
        let banks = units_needed.div_ceil(4);
        let mut fabric = BankFabric::new(MultiplierKind::DncOpt, banks, 4);
        let first = fabric.run_layer(&lib, layer, &xq);
        let second = fabric.run_layer(&lib, layer, &xq);
        assert!(first.programs > 0);
        assert_eq!(second.programs, 0, "second pass should be fully stationary");
    }
}
