//! Open-loop load generator for the wire-protocol front-end
//! (`repro loadgen`).
//!
//! Serving papers evaluate at the traffic level — offered load vs
//! throughput, tail latency and rejection — so this drives a
//! [`super::server::NetServer`] over loopback (or any address) with
//! three scenario shapes:
//!
//! * **closed** — `connections` clients in lock-step send→wait→send:
//!   the classic saturation probe (offered load adapts to service rate,
//!   so it measures capacity, not queueing).
//! * **poisson** — open-loop arrivals with exponential gaps at a target
//!   rate, split across connections. The schedule is absolute: a slow
//!   server does **not** slow the generator down (that is the point of
//!   open loop — it exposes queueing and admission behavior that a
//!   closed loop hides by self-throttling).
//! * **bursty** — the same average rate delivered as back-to-back
//!   bursts of `burst` requests, one burst per period: worst-case
//!   batcher pressure and the scenario where retry hints matter most.
//!
//! Open-loop scenarios sweep the configured offered-load levels; each
//! case reports achieved throughput, client-measured wall-latency
//! p50/p99 (exact, from raw samples — not histogram buckets), simulated
//! CiM latency p50/p99 from the response cost fields, and the reject
//! rate with the mean `retry_after_us` hint. `render_json` writes the
//! `BENCH_serve.json` CI artifact.
//!
//! With `--retry` (`loadgen.retry`), the generator honors the server's
//! structured hints: a `Rejected` reply re-sends after sleeping the
//! hinted backoff, up to [`RETRY_ATTEMPTS`] attempts, and the reported
//! **goodput** (successfully served rate) next to the offered load shows
//! what admission control actually delivers under retry storms. Wall
//! latency for a retried request runs from its *first* send, so retry
//! queueing shows up in the percentiles.
//!
//! `addr` may be a comma-separated list (`--addr a,b,c`): connection
//! `i` connects to endpoint `i % len` — client-side round-robin
//! shard-out for measuring a fleet without a router in front. Routed
//! sweeps (`--via-router`) instead point every connection at one
//! [`super::router::RouterServer`] and land the shard-per-process
//! scaling curve in the JSON's `scaling` array ([`ScalePoint`]).
//!
//! **Multi-tenant mixing** (`--models N --mix zipf|uniform`): when
//! `LoadgenOptions::models` lists more than one tenant, every request
//! picks its model from the seeded mix distribution (zipf skews toward
//! the head tenants with p(k) ∝ 1/(k+1); uniform is even) and each case
//! reports per-tenant sent/ok/goodput ([`TenantCase`]). The CLI pairs
//! this with a server-side harvest ([`PlanCacheReport`]): plan-cache
//! hit rate, compile-stall p99 and per-model weight-stationary hit
//! rates land next to the cases in `BENCH_serve.json`.
//!
//! **Server-side scrape** (`--stats`): the CLI pairs a wire `GetStats`
//! scrape before and after the sweep ([`ServerStatsReport`]) so
//! `BENCH_serve.json` carries the fleet's own view of the same window —
//! per-stage time-in-stage counts, admission counters and per-tenant
//! latency — next to the client-measured numbers. Scraping through a
//! router fans out to one entry per reachable backend.
//!
//! lint: allow-file(alloc): the generator is the measuring *client*;
//! its allocations land on loadgen threads, never on the server's
//! serving hot path (which `tests/hot_path_allocs.rs` pins at zero).

use super::client::NetClient;
use super::protocol::{Frame, ModelId};
use crate::coordinator::MetricsSnapshot;
use crate::util::trace::Stage;
use crate::util::Rng;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::fmt::Write as _;
// lint: allow(mpsc): loadgen is the measuring client, not the serving
// hot path — per-send allocation here never touches server steady state.
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Maximum send attempts per logical request under `--retry` (1 initial
/// + up to 2 hint-honoring retries); a request still rejected after the
/// budget counts as a terminal rejection.
pub const RETRY_ATTEMPTS: u32 = 2;

/// Ceiling on how long a retry sleeps on one hint (a pathological hint
/// must not stall the generator).
const MAX_RETRY_BACKOFF: Duration = Duration::from_millis(50);

/// Traffic shape of one loadgen case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    Closed,
    Poisson,
    Bursty,
}

impl Scenario {
    pub const ALL: [Scenario; 3] = [Scenario::Closed, Scenario::Poisson, Scenario::Bursty];

    pub fn slug(self) -> &'static str {
        match self {
            Scenario::Closed => "closed",
            Scenario::Poisson => "poisson",
            Scenario::Bursty => "bursty",
        }
    }

    /// Parse a slug; `all` selects every scenario.
    pub fn parse_arg(s: &str) -> Result<Vec<Scenario>> {
        match s.trim().to_ascii_lowercase().as_str() {
            "closed" => Ok(vec![Scenario::Closed]),
            "poisson" => Ok(vec![Scenario::Poisson]),
            "bursty" => Ok(vec![Scenario::Bursty]),
            "all" => Ok(Scenario::ALL.to_vec()),
            other => anyhow::bail!("unknown scenario `{other}` (closed|poisson|bursty|all)"),
        }
    }
}

/// How requests spread across the tenant models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelMix {
    /// p(k) ∝ 1/(k+1): tenant 0 is hot, the tail is cold — the shape
    /// that exercises plan-cache eviction and recompile stalls.
    Zipf,
    /// Every tenant equally likely.
    Uniform,
}

impl ModelMix {
    pub fn slug(self) -> &'static str {
        match self {
            ModelMix::Zipf => "zipf",
            ModelMix::Uniform => "uniform",
        }
    }

    pub fn from_arg(s: &str) -> Result<ModelMix> {
        match s.trim().to_ascii_lowercase().as_str() {
            "zipf" => Ok(ModelMix::Zipf),
            "uniform" => Ok(ModelMix::Uniform),
            other => anyhow::bail!("unknown mix `{other}` (zipf|uniform)"),
        }
    }

    /// Unnormalized tenant weights for `n` tenants.
    fn weights(self, n: usize) -> Vec<f64> {
        match self {
            ModelMix::Zipf => (0..n).map(|k| 1.0 / (k + 1) as f64).collect(),
            ModelMix::Uniform => vec![1.0; n],
        }
    }
}

/// Draw one tenant index from the (unnormalized) weight vector.
fn pick_tenant(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    let mut u = rng.gen_f64() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len().saturating_sub(1)
}

/// Loadgen knobs (defaults come from [`crate::config::LoadgenConfig`]).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    pub scenarios: Vec<Scenario>,
    /// Offered-load levels for the open-loop scenarios (requests/s).
    pub loads: Vec<u64>,
    pub connections: usize,
    /// Requests per case (split across connections).
    pub requests_per_level: usize,
    /// Burst size for the bursty scenario.
    pub burst: usize,
    /// Workload RNG seed (pixel noise + arrival gaps + tenant picks).
    pub seed: u64,
    /// Honor `retry_after_us` hints with client-side re-sends.
    pub retry: bool,
    /// Tenant models to spread requests over. Empty or one entry =
    /// single-tenant (every request goes to that model, or the default);
    /// tenant 0 should be [`ModelId::DEFAULT`] when the server's default
    /// model is part of the mix.
    pub models: Vec<ModelId>,
    /// Mix distribution over `models` (ignored with < 2 tenants).
    pub mix: ModelMix,
}

/// One measured (scenario, offered-load) case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub scenario: &'static str,
    /// Target offered load (req/s); `0` = closed-loop (self-clocked).
    pub offered_rps: u64,
    pub connections: usize,
    /// Logical requests issued (retries are counted in `retries`, not
    /// here — `sent` is the denominator of `reject_rate`).
    pub sent: usize,
    pub ok: usize,
    /// Terminal rejections (with `--retry`: still rejected after the
    /// retry budget).
    pub rejected: usize,
    pub errors: usize,
    /// Hint-honoring re-sends performed (0 without `--retry`).
    pub retries: usize,
    pub wall_s: f64,
    /// Served throughput (completed / wall).
    pub throughput_rps: f64,
    /// Goodput: successfully served requests per second — what the
    /// clients actually got, next to the offered load (identical to
    /// `throughput_rps`; named separately in the JSON so the
    /// goodput-vs-offered curve reads directly).
    pub goodput_rps: f64,
    /// Client-measured wall latency, exact percentiles (µs).
    pub wall_p50_us: u64,
    pub wall_p99_us: u64,
    /// Simulated CiM latency from the response cost fields (ns).
    pub sim_p50_ns: u64,
    pub sim_p99_ns: u64,
    /// Mean retry hint carried on `Rejected` frames (µs; 0 if none).
    pub mean_retry_after_us: f64,
    /// Per-tenant breakdown (empty when the case ran single-tenant).
    pub tenants: Vec<TenantCase>,
}

/// One tenant's share of a multi-tenant case.
#[derive(Debug, Clone)]
pub struct TenantCase {
    /// Model id (`default` for the default model).
    pub model: String,
    /// Logical requests that terminated against this tenant.
    pub sent: usize,
    pub ok: usize,
    /// This tenant's served rate over the case wall time.
    pub goodput_rps: f64,
}

/// Server-side multi-tenant columns harvested after a sweep (the CLI
/// fills this from the coordinator's metrics when it spawned the server
/// itself; an external endpoint's internals are not observable).
#[derive(Debug, Clone, Default)]
pub struct PlanCacheReport {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub compiles: u64,
    pub compile_p99_us: u64,
    /// p99 time a request stalled behind another request's in-flight
    /// compile of the same model (the single-flight queueing cost).
    pub stall_p99_us: u64,
    /// Per-model weight-stationary hit rate (`default` names the
    /// default model; meaningful on the calibrated backend).
    pub model_stationary: Vec<(String, f64)>,
}

impl PlanCacheReport {
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

/// One scraped endpoint's before/after server snapshots (`--stats`).
/// Counter deltas isolate the sweep's own traffic; percentile fields
/// are the *after*-side since-boot view (histograms do not subtract).
#[derive(Debug, Clone)]
pub struct EndpointStats {
    pub addr: String,
    pub before: MetricsSnapshot,
    pub after: MetricsSnapshot,
}

fn delta(after: u64, before: u64) -> u64 {
    after.saturating_sub(before)
}

impl EndpointStats {
    /// Requests the endpoint served during the sweep window.
    pub fn requests_delta(&self) -> u64 {
        delta(self.after.requests, self.before.requests)
    }

    pub fn accepted_delta(&self) -> u64 {
        delta(self.after.accepted, self.before.accepted)
    }

    pub fn rejected_delta(&self) -> u64 {
        delta(self.after.rejected, self.before.rejected)
    }

    pub fn failed_requests_delta(&self) -> u64 {
        delta(self.after.failed_requests, self.before.failed_requests)
    }

    /// Samples stage `i` (in [`Stage`] pipeline order) absorbed during
    /// the sweep window.
    pub fn stage_count_delta(&self, i: usize) -> u64 {
        delta(self.after.stage_count[i], self.before.stage_count[i])
    }
}

/// Server-side observability harvest for `BENCH_serve.json`
/// (`repro loadgen --stats`): a wire `GetStats` scrape taken before and
/// one taken after the sweep, paired per endpoint.
#[derive(Debug, Clone, Default)]
pub struct ServerStatsReport {
    pub endpoints: Vec<EndpointStats>,
}

impl ServerStatsReport {
    /// Scrape every endpoint behind the (comma-separated) `addr` list.
    /// A server answers with its own snapshot; a router answers with one
    /// snapshot per connected backend (keyed by backend address).
    pub fn scrape(addr: &str) -> Result<Vec<(String, MetricsSnapshot)>> {
        let mut out = Vec::new();
        for ep in endpoints(addr) {
            let mut client = NetClient::connect(ep)
                .with_context(|| format!("connecting stats scrape to {ep}"))?;
            let payload = client.get_stats()?;
            if let Some(server) = payload.server {
                out.push((ep.to_string(), server));
            }
            out.extend(payload.backends);
        }
        Ok(out)
    }

    /// Pair a before and an after scrape by endpoint address. An
    /// endpoint present on only one side is dropped — a backend that
    /// joined or died mid-sweep has no meaningful delta.
    pub fn from_scrapes(
        before: Vec<(String, MetricsSnapshot)>,
        after: Vec<(String, MetricsSnapshot)>,
    ) -> ServerStatsReport {
        let mut eps = Vec::new();
        for (addr, after_snap) in after {
            if let Some((_, before_snap)) = before.iter().find(|(a, _)| *a == addr) {
                eps.push(EndpointStats { addr, before: before_snap.clone(), after: after_snap });
            }
        }
        ServerStatsReport { endpoints: eps }
    }
}

/// JSON/report name for a tenant model id.
pub fn tenant_name(model: ModelId) -> String {
    if model.is_default() {
        "default".to_string()
    } else {
        model.as_str().to_string()
    }
}

impl CaseResult {
    pub fn reject_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.rejected as f64 / self.sent as f64
        }
    }
}

/// One point on the shard-per-process scaling curve (`--router-scale`):
/// the closed-loop case measured through `repro route` fronting
/// `processes` backend stacks.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub processes: usize,
    pub goodput_rps: f64,
    pub wall_p99_us: u64,
    pub sim_p99_ns: u64,
}

/// Weight-stationary hit rates measured with `batcher.affinity` set to
/// `request` vs `connection` — the before/after the shard-affinity
/// follow-up asked for, reported next to the scaling curve.
#[derive(Debug, Clone)]
pub struct AffinityComparison {
    pub request_hit_rate: f64,
    pub connection_hit_rate: f64,
}

/// Split a (possibly comma-separated) `--addr` list.
pub fn endpoints(addr: &str) -> Vec<&str> {
    let eps: Vec<&str> = addr.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if eps.is_empty() {
        vec![addr]
    } else {
        eps
    }
}

/// Per-connection tallies a reader thread accumulates.
#[derive(Default)]
struct ConnTally {
    wall_us: Vec<u64>,
    sim_ns: Vec<u64>,
    ok: usize,
    rejected: usize,
    errors: usize,
    retries: usize,
    retry_hint_sum_us: u64,
    /// Per-tenant terminal/ok counts, indexed like `LoadgenOptions::models`.
    tenant_sent: Vec<usize>,
    tenant_ok: Vec<usize>,
}

impl ConnTally {
    /// A tally with per-tenant slots for `tenants` models.
    fn sized(tenants: usize) -> ConnTally {
        ConnTally {
            tenant_sent: vec![0; tenants.max(1)],
            tenant_ok: vec![0; tenants.max(1)],
            ..ConnTally::default()
        }
    }

    /// Record a terminal reply against tenant index `tenant`. `Rejected`
    /// handling (terminal vs retry) lives at the call sites, which own
    /// the retry policy.
    fn absorb(&mut self, frame: &Frame, sent_at: Option<Instant>, tenant: usize) {
        let tenant = tenant.min(self.tenant_sent.len().saturating_sub(1));
        self.tenant_sent[tenant] += 1;
        match frame {
            Frame::Response { cost, .. } => {
                self.ok += 1;
                self.tenant_ok[tenant] += 1;
                if let Some(t) = sent_at {
                    self.wall_us.push(t.elapsed().as_micros() as u64);
                }
                self.sim_ns.push(cost.latency_ps / 1000);
            }
            Frame::Rejected { retry_after_us, .. } => {
                self.rejected += 1;
                self.retry_hint_sum_us += retry_after_us;
            }
            _ => self.errors += 1,
        }
    }
}

/// Sleep the hinted backoff (bounded by [`MAX_RETRY_BACKOFF`]).
fn backoff(retry_after_us: u64) {
    std::thread::sleep(Duration::from_micros(retry_after_us).min(MAX_RETRY_BACKOFF));
}

/// Execute one re-send order (sender thread): wait out the hint, then
/// send a fresh workload sample carrying the original first-send time
/// and the incremented attempt count.
fn resend(
    tx: &mut super::client::NetSender,
    rng: &mut Rng,
    in_dim: usize,
    models: &[ModelId],
    pending: &Mutex<HashMap<u64, Pending>>,
    order: RetryOrder,
) -> Result<()> {
    sleep_until(order.due);
    let pixels: Vec<f32> = (0..in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
    let id = tx.next_id();
    let model = models.get(order.tenant).copied().unwrap_or(ModelId::DEFAULT);
    pending.lock().unwrap().insert(
        id,
        Pending { first_sent: order.first_sent, attempt: order.attempt, tenant: order.tenant },
    );
    tx.send_model(model, &pixels)?;
    Ok(())
}

/// Send-time bookkeeping per in-flight wire id.
struct Pending {
    /// First attempt's send time — retried requests measure wall
    /// latency from here, so retry queueing shows in the percentiles.
    first_sent: Instant,
    attempt: u32,
    /// Tenant index the request was sent against (retries stick to it).
    tenant: usize,
}

/// A receiver-decided re-send, executed by the sender thread once due.
struct RetryOrder {
    due: Instant,
    attempt: u32,
    first_sent: Instant,
    tenant: usize,
}

/// Run every requested case against `addr` and return the results in
/// execution order (closed first, then each open-loop scenario swept
/// over the load levels).
pub fn run(addr: &str, opts: &LoadgenOptions) -> Result<Vec<CaseResult>> {
    anyhow::ensure!(!opts.scenarios.is_empty(), "no scenarios selected");
    let mut results = Vec::new();
    for &scenario in &opts.scenarios {
        match scenario {
            Scenario::Closed => results.push(run_closed(addr, opts)?),
            Scenario::Poisson | Scenario::Bursty => {
                for &rate in &opts.loads {
                    results.push(run_open(addr, opts, scenario, rate)?);
                }
            }
        }
    }
    Ok(results)
}

fn per_conn_quota(opts: &LoadgenOptions) -> usize {
    (opts.requests_per_level / opts.connections.max(1)).max(1)
}

fn run_closed(addr: &str, opts: &LoadgenOptions) -> Result<CaseResult> {
    let quota = per_conn_quota(opts);
    let retry = opts.retry;
    let eps = endpoints(addr);
    let mut clients = Vec::new();
    for i in 0..opts.connections {
        clients.push(NetClient::connect(eps[i % eps.len()])?);
    }
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for (c, mut client) in clients.into_iter().enumerate() {
        let seed = opts.seed ^ (c as u64).wrapping_mul(0x9E37_79B9);
        let models = opts.models.clone();
        let weights = opts.mix.weights(models.len().max(1));
        threads.push(std::thread::spawn(move || -> Result<ConnTally> {
            let mut rng = Rng::seed_from_u64(seed);
            let in_dim = client.info().in_dim;
            let mut tally = ConnTally::sized(models.len());
            for _ in 0..quota {
                let tenant = pick_tenant(&mut rng, &weights);
                let model = models.get(tenant).copied().unwrap_or(ModelId::DEFAULT);
                let pixels: Vec<f32> = (0..in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
                let sent_at = Instant::now();
                let mut attempt = 0u32;
                loop {
                    let reply = client.infer_model(model, &pixels)?;
                    match &reply {
                        Frame::Rejected { retry_after_us, .. }
                            if retry && attempt < RETRY_ATTEMPTS && *retry_after_us >= 1 =>
                        {
                            attempt += 1;
                            tally.retries += 1;
                            backoff(*retry_after_us);
                        }
                        _ => {
                            tally.absorb(&reply, Some(sent_at), tenant);
                            break;
                        }
                    }
                }
            }
            Ok(tally)
        }));
    }
    let tallies = join_tallies(threads)?;
    Ok(aggregate("closed", 0, opts, quota * opts.connections, t0, tallies))
}

fn run_open(
    addr: &str,
    opts: &LoadgenOptions,
    scenario: Scenario,
    rate_rps: u64,
) -> Result<CaseResult> {
    anyhow::ensure!(rate_rps >= 1, "offered load must be >= 1 req/s");
    let quota = per_conn_quota(opts);
    let rate_conn = rate_rps as f64 / opts.connections.max(1) as f64;
    let eps = endpoints(addr);
    let mut clients = Vec::new();
    for i in 0..opts.connections {
        clients.push(NetClient::connect(eps[i % eps.len()])?);
    }
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for (c, client) in clients.into_iter().enumerate() {
        let seed = opts.seed ^ (c as u64).wrapping_mul(0x517C_C1B7);
        let burst = opts.burst.max(1);
        let retry = opts.retry;
        let models = opts.models.clone();
        let weights = opts.mix.weights(models.len().max(1));
        let tenants = models.len();
        let (mut tx, mut rx, info) = client.split();
        // send-time map shared between the two halves: replies arrive
        // in completion order, so latency is matched by wire id.
        let pending: Arc<Mutex<HashMap<u64, Pending>>> = Arc::new(Mutex::new(HashMap::new()));
        let sender_pending = pending.clone();
        // receiver → sender re-send orders (retry mode); dropping the
        // producer ends the sender's drain loop.
        // lint: allow(mpsc): client-side retry plumbing, off the serving path.
        let (retry_tx, retry_rx) = mpsc::channel::<RetryOrder>();
        let sender = std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::seed_from_u64(seed);
            let mut due = Instant::now();
            let mut in_burst = 0usize;
            // not-yet-due retries parked between scheduled sends
            let mut parked: Vec<RetryOrder> = Vec::new();
            for _ in 0..quota {
                match scenario {
                    Scenario::Poisson => {
                        due += Duration::from_secs_f64(exp_gap_s(&mut rng, rate_conn));
                        sleep_until(due);
                    }
                    Scenario::Bursty => {
                        // `burst` back-to-back sends, then one period of
                        // silence — the same average rate as poisson.
                        if in_burst == 0 {
                            sleep_until(due);
                            due += Duration::from_secs_f64(burst as f64 / rate_conn);
                        }
                        in_burst = (in_burst + 1) % burst;
                    }
                    Scenario::Closed => unreachable!("closed-loop uses run_closed"),
                }
                // service retries that came due during the pacing gap
                // (re-sends interleave at send-loop granularity — the
                // open-loop schedule itself is never delayed by more
                // than one due retry)
                while let Ok(o) = retry_rx.try_recv() {
                    parked.push(o);
                }
                let now = Instant::now();
                let mut i = 0;
                while i < parked.len() {
                    if parked[i].due <= now {
                        let o = parked.swap_remove(i);
                        resend(&mut tx, &mut rng, info.in_dim, &models, &sender_pending, o)?;
                    } else {
                        i += 1;
                    }
                }
                let tenant = pick_tenant(&mut rng, &weights);
                let model = models.get(tenant).copied().unwrap_or(ModelId::DEFAULT);
                let pixels: Vec<f32> =
                    (0..info.in_dim).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
                // record the send time before the frame can be answered
                let id = tx.next_id();
                sender_pending
                    .lock()
                    .unwrap()
                    .insert(id, Pending { first_sent: Instant::now(), attempt: 0, tenant });
                tx.send_model(model, &pixels)?;
            }
            // drain: keep servicing re-send orders until the receiver
            // has its full quota of terminal replies and hangs up
            loop {
                while let Ok(o) = retry_rx.try_recv() {
                    parked.push(o);
                }
                if parked.is_empty() {
                    match retry_rx.recv() {
                        Ok(o) => parked.push(o),
                        Err(_) => break,
                    }
                } else {
                    let next = parked
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, o)| o.due)
                        .map(|(i, _)| i)
                        .unwrap();
                    let o = parked.swap_remove(next);
                    resend(&mut tx, &mut rng, info.in_dim, &models, &sender_pending, o)?;
                }
            }
            Ok(())
        });
        threads.push(std::thread::spawn(move || -> Result<ConnTally> {
            let mut tally = ConnTally::sized(tenants);
            let mut terminals = 0usize;
            while terminals < quota {
                let reply = rx.recv().context("reply stream ended early")?;
                let pend = reply_id(&reply).and_then(|id| pending.lock().unwrap().remove(&id));
                let first_sent = pend.as_ref().map(|p| p.first_sent);
                let attempt = pend.as_ref().map(|p| p.attempt).unwrap_or(0);
                let tenant = pend.as_ref().map(|p| p.tenant).unwrap_or(0);
                if let Frame::Rejected { retry_after_us, .. } = &reply {
                    if retry && attempt < RETRY_ATTEMPTS && *retry_after_us >= 1 {
                        let order = RetryOrder {
                            due: Instant::now()
                                + Duration::from_micros(*retry_after_us).min(MAX_RETRY_BACKOFF),
                            attempt: attempt + 1,
                            first_sent: first_sent.unwrap_or_else(Instant::now),
                            tenant,
                        };
                        if retry_tx.send(order).is_ok() {
                            tally.retries += 1;
                            continue; // not terminal: the re-send answers later
                        }
                    }
                }
                tally.absorb(&reply, first_sent, tenant);
                terminals += 1;
            }
            drop(retry_tx); // ends the sender's drain loop
            match sender.join() {
                Ok(res) => res?,
                Err(_) => anyhow::bail!("sender thread panicked"),
            }
            Ok(tally)
        }));
    }
    let tallies = join_tallies(threads)?;
    Ok(aggregate(scenario.slug(), rate_rps, opts, quota * opts.connections, t0, tallies))
}

fn reply_id(frame: &Frame) -> Option<u64> {
    match frame {
        Frame::Response { id, .. } | Frame::Rejected { id, .. } | Frame::Error { id, .. } => {
            Some(*id)
        }
        _ => None,
    }
}

fn join_tallies(
    threads: Vec<std::thread::JoinHandle<Result<ConnTally>>>,
) -> Result<Vec<ConnTally>> {
    let mut out = Vec::new();
    for t in threads {
        match t.join() {
            Ok(tally) => out.push(tally?),
            Err(_) => anyhow::bail!("loadgen connection thread panicked"),
        }
    }
    Ok(out)
}

fn aggregate(
    scenario: &'static str,
    offered_rps: u64,
    opts: &LoadgenOptions,
    sent: usize,
    t0: Instant,
    tallies: Vec<ConnTally>,
) -> CaseResult {
    let wall_s = t0.elapsed().as_secs_f64();
    let mut wall_us = Vec::new();
    let mut sim_ns = Vec::new();
    let (mut ok, mut rejected, mut errors) = (0usize, 0usize, 0usize);
    let (mut retries, mut hint_sum) = (0usize, 0u64);
    let mut tenant_sent = vec![0usize; opts.models.len()];
    let mut tenant_ok = vec![0usize; opts.models.len()];
    for t in tallies {
        wall_us.extend(t.wall_us);
        sim_ns.extend(t.sim_ns);
        ok += t.ok;
        rejected += t.rejected;
        errors += t.errors;
        retries += t.retries;
        hint_sum += t.retry_hint_sum_us;
        for (i, n) in t.tenant_sent.iter().enumerate().take(tenant_sent.len()) {
            tenant_sent[i] += n;
        }
        for (i, n) in t.tenant_ok.iter().enumerate().take(tenant_ok.len()) {
            tenant_ok[i] += n;
        }
    }
    wall_us.sort_unstable();
    sim_ns.sort_unstable();
    let served_rps = if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 };
    // a single-tenant case carries no per-tenant breakdown
    let tenants = if opts.models.len() > 1 {
        opts.models
            .iter()
            .zip(tenant_sent.iter().zip(&tenant_ok))
            .map(|(m, (&sent, &ok))| TenantCase {
                model: tenant_name(*m),
                sent,
                ok,
                goodput_rps: if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 },
            })
            .collect()
    } else {
        Vec::new()
    };
    CaseResult {
        scenario,
        offered_rps,
        connections: opts.connections,
        sent,
        ok,
        rejected,
        errors,
        retries,
        wall_s,
        throughput_rps: served_rps,
        goodput_rps: served_rps,
        wall_p50_us: percentile(&wall_us, 0.50),
        wall_p99_us: percentile(&wall_us, 0.99),
        sim_p50_ns: percentile(&sim_ns, 0.50),
        sim_p99_ns: percentile(&sim_ns, 0.99),
        mean_retry_after_us: if rejected > 0 { hint_sum as f64 / rejected as f64 } else { 0.0 },
        tenants,
    }
}

/// Exact percentile over a sorted sample set (0 when empty).
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

/// Exponential inter-arrival gap (seconds) for a Poisson process.
fn exp_gap_s(rng: &mut Rng, rate_per_s: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() / rate_per_s
}

/// Sleep until `due`; returns immediately when already behind schedule
/// (open loop: late sends catch up back-to-back, never re-anchor).
fn sleep_until(due: Instant) {
    let now = Instant::now();
    if due > now {
        std::thread::sleep(due - now);
    }
}

/// One human-readable summary line per case.
pub fn render_table(results: &[CaseResult]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<8} {:>10} {:>7} {:>7} {:>7} {:>7} {:>8} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "scenario",
        "offered/s",
        "sent",
        "ok",
        "retry",
        "reject",
        "rate",
        "goodput/s",
        "p50 us",
        "p99 us",
        "sim p50",
        "sim p99"
    );
    for r in results {
        let offered =
            if r.offered_rps == 0 { "closed".to_string() } else { r.offered_rps.to_string() };
        let _ = writeln!(
            out,
            "{:<8} {:>10} {:>7} {:>7} {:>7} {:>7} {:>8.3} {:>10.0} {:>9} {:>9} {:>9} {:>9}",
            r.scenario,
            offered,
            r.sent,
            r.ok,
            r.retries,
            r.rejected,
            r.reject_rate(),
            r.goodput_rps,
            r.wall_p50_us,
            r.wall_p99_us,
            r.sim_p50_ns,
            r.sim_p99_ns,
        );
    }
    out
}

/// Hand-rolled JSON (no serde in this offline image): the
/// `BENCH_serve.json` artifact CI uploads next to `BENCH_lut_gemm.json`.
pub fn render_json(results: &[CaseResult], backend: &str) -> String {
    render_json_full(results, backend, &[], None, None, None)
}

/// [`render_json`] plus the router-tier and multi-tenant columns: the
/// `scaling` array (goodput + wall/sim p99 per backend-process count,
/// routed through `repro route`), the affinity hit-rate comparison, the
/// server-side plan-cache harvest and the wire-scraped before/after
/// stats delta (`--stats`), when measured.
pub fn render_json_full(
    results: &[CaseResult],
    backend: &str,
    scaling: &[ScalePoint],
    affinity: Option<&AffinityComparison>,
    plan: Option<&PlanCacheReport>,
    stats: Option<&ServerStatsReport>,
) -> String {
    let mut out = String::from("{\n  \"bench\": \"serve\",\n");
    let _ = writeln!(out, "  \"backend\": \"{backend}\",");
    out.push_str("  \"cases\": [\n");
    for (i, r) in results.iter().enumerate() {
        let mut tenants = String::new();
        for (j, t) in r.tenants.iter().enumerate() {
            let _ = write!(
                tenants,
                "{{\"model\": \"{}\", \"sent\": {}, \"ok\": {}, \"goodput_rps\": {:.1}}}",
                t.model, t.sent, t.ok, t.goodput_rps,
            );
            if j + 1 < r.tenants.len() {
                tenants.push_str(", ");
            }
        }
        let _ = write!(
            out,
            "    {{\"scenario\": \"{}\", \"offered_rps\": {}, \"connections\": {}, \
             \"sent\": {}, \"ok\": {}, \"rejected\": {}, \"errors\": {}, \"retries\": {}, \
             \"reject_rate\": {:.4}, \"throughput_rps\": {:.1}, \"goodput_rps\": {:.1}, \
             \"wall_s\": {:.3}, \"wall_p50_us\": {}, \"wall_p99_us\": {}, \
             \"sim_p50_ns\": {}, \"sim_p99_ns\": {}, \"mean_retry_after_us\": {:.1}, \
             \"tenants\": [{}]}}",
            r.scenario,
            r.offered_rps,
            r.connections,
            r.sent,
            r.ok,
            r.rejected,
            r.errors,
            r.retries,
            r.reject_rate(),
            r.throughput_rps,
            r.goodput_rps,
            r.wall_s,
            r.wall_p50_us,
            r.wall_p99_us,
            r.sim_p50_ns,
            r.sim_p99_ns,
            r.mean_retry_after_us,
            tenants,
        );
        out.push_str(if i + 1 < results.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"scaling\": [\n");
    for (i, p) in scaling.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"processes\": {}, \"goodput_rps\": {:.1}, \"wall_p99_us\": {}, \
             \"sim_p99_ns\": {}}}",
            p.processes, p.goodput_rps, p.wall_p99_us, p.sim_p99_ns,
        );
        out.push_str(if i + 1 < scaling.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]");
    if let Some(a) = affinity {
        out.push_str(",\n");
        let _ = write!(
            out,
            "  \"affinity_stationary_hit_rate\": {{\"request\": {:.4}, \
             \"connection\": {:.4}}}",
            a.request_hit_rate, a.connection_hit_rate
        );
    }
    if let Some(p) = plan {
        out.push_str(",\n");
        let _ = write!(
            out,
            "  \"plan_cache\": {{\"hits\": {}, \"misses\": {}, \"hit_rate\": {:.4}, \
             \"evictions\": {}, \"compiles\": {}, \"compile_p99_us\": {}, \
             \"stall_p99_us\": {}}},\n",
            p.hits,
            p.misses,
            p.hit_rate(),
            p.evictions,
            p.compiles,
            p.compile_p99_us,
            p.stall_p99_us,
        );
        out.push_str("  \"model_stationary_hit_rate\": {");
        for (j, (model, rate)) in p.model_stationary.iter().enumerate() {
            let _ = write!(out, "\"{model}\": {rate:.4}");
            if j + 1 < p.model_stationary.len() {
                out.push_str(", ");
            }
        }
        out.push('}');
    }
    if let Some(s) = stats {
        out.push_str(",\n  \"server_stats\": [\n");
        for (i, e) in s.endpoints.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"addr\": \"{}\", \"requests\": {}, \"accepted\": {}, \
                 \"rejected\": {}, \"failed_requests\": {}, \"p99_latency_us\": {}, \
                 \"stages\": {{",
                e.addr,
                e.requests_delta(),
                e.accepted_delta(),
                e.rejected_delta(),
                e.failed_requests_delta(),
                e.after.p99_latency_us,
            );
            for (j, stage) in Stage::ALL.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                let _ = write!(
                    out,
                    "\"{}\": {{\"count\": {}, \"p50_us\": {}, \"p99_us\": {}}}",
                    stage.name(),
                    e.stage_count_delta(j),
                    e.after.stage_p50_us[j],
                    e.after.stage_p99_us[j],
                );
            }
            out.push_str("}, \"tenants\": [");
            for (j, t) in e.after.tenants.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                let _ = write!(
                    out,
                    "{{\"name\": \"{}\", \"requests\": {}, \"p99_latency_us\": {}, \
                     \"p99_queue_us\": {}}}",
                    t.name, t.requests, t.p99_latency_us, t.p99_queue_us,
                );
            }
            out.push_str("]}");
            out.push_str(if i + 1 < s.endpoints.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]");
    }
    out.push_str("\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_exact_on_small_samples() {
        let s = vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile(&s, 0.50), 50);
        assert_eq!(percentile(&s, 0.99), 100);
        assert_eq!(percentile(&s, 0.0), 10);
        assert_eq!(percentile(&s, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn exp_gaps_have_the_right_mean() {
        let mut rng = Rng::seed_from_u64(3);
        let rate = 1000.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| exp_gap_s(&mut rng, rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.1 / rate, "mean gap {mean}");
    }

    #[test]
    fn scenario_slugs_roundtrip_and_all_expands() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse_arg(s.slug()).unwrap(), vec![s]);
        }
        assert_eq!(Scenario::parse_arg("all").unwrap().len(), 3);
        assert!(Scenario::parse_arg("warp").is_err());
    }

    #[test]
    fn json_shape_has_required_fields() {
        let r = CaseResult {
            scenario: "poisson",
            offered_rps: 2000,
            connections: 4,
            sent: 100,
            ok: 90,
            rejected: 10,
            errors: 0,
            retries: 7,
            wall_s: 0.05,
            throughput_rps: 1800.0,
            goodput_rps: 1800.0,
            wall_p50_us: 700,
            wall_p99_us: 2100,
            sim_p50_ns: 500,
            sim_p99_ns: 900,
            mean_retry_after_us: 450.0,
            tenants: Vec::new(),
        };
        let json = render_json(&[r.clone(), r], "native");
        for key in [
            "\"bench\": \"serve\"",
            "\"backend\": \"native\"",
            "\"offered_rps\": 2000",
            "\"reject_rate\": 0.1000",
            "\"throughput_rps\": 1800.0",
            "\"goodput_rps\": 1800.0",
            "\"retries\": 7",
            "\"wall_p99_us\": 2100",
            "\"sim_p99_ns\": 900",
            "\"mean_retry_after_us\": 450.0",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(render_table(&[]).contains("scenario"));
        // the plain renderer always carries an (empty) scaling array so
        // downstream consumers can rely on the key
        assert!(json.contains("\"scaling\": ["), "missing scaling array in {json}");
    }

    #[test]
    fn json_scaling_and_affinity_columns_render() {
        let scaling = [
            ScalePoint { processes: 1, goodput_rps: 900.0, wall_p99_us: 1500, sim_p99_ns: 800 },
            ScalePoint { processes: 4, goodput_rps: 3100.0, wall_p99_us: 1700, sim_p99_ns: 820 },
        ];
        let aff = AffinityComparison { request_hit_rate: 0.91, connection_hit_rate: 0.88 };
        let json = render_json_full(&[], "native", &scaling, Some(&aff), None, None);
        for key in [
            "\"scaling\": [",
            "\"processes\": 1",
            "\"processes\": 4",
            "\"goodput_rps\": 3100.0",
            "\"wall_p99_us\": 1700",
            "\"sim_p99_ns\": 820",
            "\"affinity_stationary_hit_rate\": {\"request\": 0.9100, \"connection\": 0.8800}",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    #[test]
    fn mix_slugs_roundtrip_and_weights_shape() {
        for m in [ModelMix::Zipf, ModelMix::Uniform] {
            assert_eq!(ModelMix::from_arg(m.slug()).unwrap(), m);
        }
        assert!(ModelMix::from_arg("pareto").is_err());
        let z = ModelMix::Zipf.weights(3);
        assert!(z[0] > z[1] && z[1] > z[2], "zipf skews to the head: {z:?}");
        assert!((z[0] - 1.0).abs() < 1e-12 && (z[1] - 0.5).abs() < 1e-12);
        assert!(ModelMix::Uniform.weights(4).iter().all(|&w| w == 1.0));
    }

    #[test]
    fn pick_tenant_follows_the_weights() {
        let mut rng = Rng::seed_from_u64(11);
        let weights = ModelMix::Zipf.weights(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[pick_tenant(&mut rng, &weights)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
        // zipf over 3 tenants: p0 = 6/11 ≈ 0.545
        let p0 = counts[0] as f64 / 30_000.0;
        assert!((p0 - 6.0 / 11.0).abs() < 0.02, "p0 {p0}");
        // uniform stays uniform
        let uw = ModelMix::Uniform.weights(3);
        let mut uc = [0usize; 3];
        for _ in 0..30_000 {
            uc[pick_tenant(&mut rng, &uw)] += 1;
        }
        for c in uc {
            assert!((c as f64 / 30_000.0 - 1.0 / 3.0).abs() < 0.02, "{uc:?}");
        }
    }

    #[test]
    fn json_tenant_and_plan_cache_columns_render() {
        let r = CaseResult {
            scenario: "closed",
            offered_rps: 0,
            connections: 2,
            sent: 100,
            ok: 100,
            rejected: 0,
            errors: 0,
            retries: 0,
            wall_s: 1.0,
            throughput_rps: 100.0,
            goodput_rps: 100.0,
            wall_p50_us: 500,
            wall_p99_us: 900,
            sim_p50_ns: 0,
            sim_p99_ns: 0,
            mean_retry_after_us: 0.0,
            tenants: vec![
                TenantCase { model: "default".into(), sent: 67, ok: 67, goodput_rps: 67.0 },
                TenantCase { model: "m1".into(), sent: 33, ok: 33, goodput_rps: 33.0 },
            ],
        };
        let plan = PlanCacheReport {
            hits: 30,
            misses: 10,
            evictions: 4,
            compiles: 6,
            compile_p99_us: 2048,
            stall_p99_us: 512,
            model_stationary: vec![("default".into(), 0.9), ("m1".into(), 0.75)],
        };
        assert!((plan.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PlanCacheReport::default().hit_rate(), 0.0);
        let json = render_json_full(&[r], "calibrated", &[], None, Some(&plan), None);
        for key in [
            "\"tenants\": [{\"model\": \"default\", \"sent\": 67, \"ok\": 67, \
             \"goodput_rps\": 67.0}, {\"model\": \"m1\", \"sent\": 33, \"ok\": 33, \
             \"goodput_rps\": 33.0}]",
            "\"plan_cache\": {\"hits\": 30, \"misses\": 10, \"hit_rate\": 0.7500, \
             \"evictions\": 4, \"compiles\": 6, \"compile_p99_us\": 2048, \"stall_p99_us\": 512}",
            "\"model_stationary_hit_rate\": {\"default\": 0.9000, \"m1\": 0.7500}",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert_eq!(tenant_name(ModelId::DEFAULT), "default");
        assert_eq!(tenant_name(ModelId::new("m1").unwrap()), "m1");
    }

    #[test]
    fn json_server_stats_delta_renders() {
        use crate::coordinator::metrics::sample_snapshot;
        let before = sample_snapshot();
        let mut after = sample_snapshot();
        after.requests += 100;
        after.accepted += 110;
        after.rejected += 10;
        after.stage_count[0] += 100;
        let report = ServerStatsReport::from_scrapes(
            vec![("127.0.0.1:7071".into(), before.clone())],
            vec![
                ("127.0.0.1:7071".into(), after),
                // present only after the sweep: no pair, dropped
                ("127.0.0.1:9999".into(), before),
            ],
        );
        assert_eq!(report.endpoints.len(), 1);
        let e = &report.endpoints[0];
        assert_eq!(e.requests_delta(), 100);
        assert_eq!(e.accepted_delta(), 110);
        assert_eq!(e.rejected_delta(), 10);
        assert_eq!(e.failed_requests_delta(), 0);
        assert_eq!(e.stage_count_delta(0), 100);
        let json = render_json_full(&[], "native", &[], None, None, Some(&report));
        for key in [
            "\"server_stats\": [",
            "\"addr\": \"127.0.0.1:7071\"",
            "\"requests\": 100, \"accepted\": 110, \"rejected\": 10",
            "\"ingress\": {\"count\": 100, \"p50_us\": 2, \"p99_us\": 4}",
            "\"queue_wait\": {\"count\": 0, \"p50_us\": 64, \"p99_us\": 256}",
            "\"tenants\": [{\"name\": \"default\", \"requests\": 10, \
             \"p99_latency_us\": 1024, \"p99_queue_us\": 256}",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // delta saturates instead of wrapping when a counter resets
        let shrunk = EndpointStats {
            addr: "x".into(),
            before: sample_snapshot(),
            after: MetricsSnapshot { requests: 0, ..sample_snapshot() },
        };
        assert_eq!(shrunk.requests_delta(), 0);
    }

    #[test]
    fn endpoints_split_and_roundrobin_assignment() {
        assert_eq!(endpoints("127.0.0.1:9000"), vec!["127.0.0.1:9000"]);
        assert_eq!(endpoints("a:1, b:2 ,c:3"), vec!["a:1", "b:2", "c:3"]);
        assert_eq!(endpoints("a:1,,b:2"), vec!["a:1", "b:2"]);
        assert_eq!(endpoints(""), vec![""]);
    }
}
