//! L4 network layer: the wire-protocol serving subsystem.
//!
//! Everything below this module serves from *in-process* handles; this
//! module is what makes the coordinator an actual service — std-only
//! (hand-rolled framing, std TCP, OS threads; no async runtime or
//! serde exist in this offline image):
//!
//! * [`protocol`] — the versioned length-prefixed binary framing
//!   (normative layout in the crate docs' `## Wire protocol` section);
//! * [`server`] — the TCP front-end: accept loop + per-connection
//!   reader/writer threads feeding
//!   [`crate::coordinator::ServerHandle::submit_with`], 429-style
//!   `Rejected` frames with [`crate::coordinator::Backpressure`] retry
//!   hints, and a graceful drain on shutdown;
//! * [`client`] — the matching client (blocking or split into
//!   send/receive halves for pipelined open-loop traffic);
//! * [`loadgen`] — the `repro loadgen` engine: closed-loop, open-loop
//!   Poisson and bursty arrival processes swept over offered-load
//!   levels, reporting throughput, exact wall p50/p99, simulated-CiM
//!   p50/p99 and reject rate per level (`BENCH_serve.json`);
//! * [`router`] — the `repro route` front tier: consistent-hash or
//!   least-outstanding dispatch over N backends speaking the same
//!   protocol, with health probing, quarantine/recovery, fleet-wide
//!   admission aggregation and no-request-hangs failover.

pub mod client;
pub mod loadgen;
pub mod protocol;
pub mod router;
pub mod server;

pub use client::{handshake, NetClient, NetReceiver, NetSender, ServerInfo};
pub use loadgen::{
    AffinityComparison, CaseResult, EndpointStats, LoadgenOptions, ModelMix, PlanCacheReport,
    ScalePoint, Scenario, ServerStatsReport, TenantCase,
};
pub use protocol::{Frame, ModelId, StatsPayload, WireCost, MAX_MODEL_ID};
pub use router::{mix64, pick_least_outstanding, HashRing, RouterServer};
pub use server::NetServer;
