//! Wire-protocol client: the counterpart of [`super::server::NetServer`].
//!
//! [`NetClient`] is the simple blocking form (send → recv) used by
//! tests and closed-loop load; [`NetClient::split`] separates the send
//! and receive halves onto two owned stream clones so an open-loop
//! generator can keep sending on schedule while another thread drains
//! replies (replies arrive in *completion* order, matched by `id`).
//!
//! [`handshake`] is the one `Hello` → `Info` implementation in the
//! crate: the client connect path and the router's backend health probe
//! both call it, so version negotiation has a single source of truth
//! (the protocol module's versioning rules are exercised through
//! exactly one code path).

use super::protocol::{
    read_frame, read_frame_with, write_frame, write_frame_with, Frame, ModelId, StatsPayload,
};
use crate::util::PooledVec;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Model/serving parameters the server reports in its `Info` frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerInfo {
    pub in_dim: usize,
    pub out_dim: usize,
    pub max_batch: usize,
    pub backend: String,
    /// Sorted non-default model ids the server can serve (minor 2; an
    /// older server reports none). The default model is implicit.
    pub models: Vec<String>,
}

/// The client side of the version handshake, over any frame transport:
/// send `Hello`, read the server's `Info`. Fails on version mismatch
/// (the server answers with an `Error` frame naming its version), on a
/// `Rejected` turn-away, or if the peer is not a LUNA server.
///
/// This is the **only** handshake implementation — [`NetClient::connect`]
/// and the router's health probe ([`crate::net::router`]) both defer
/// here rather than re-implement the `Hello`→`Info` exchange.
pub fn handshake<R: Read, W: Write>(r: &mut R, w: &mut W) -> Result<ServerInfo> {
    write_frame(w, &Frame::Hello)?;
    w.flush().context("flushing Hello")?;
    match read_frame(r)? {
        Some(Frame::Info { in_dim, out_dim, max_batch, backend, models }) => Ok(ServerInfo {
            in_dim: in_dim as usize,
            out_dim: out_dim as usize,
            max_batch: max_batch as usize,
            backend,
            models,
        }),
        Some(Frame::Error { reason, .. }) => bail!("server refused handshake: {reason}"),
        Some(Frame::Rejected { reason, .. }) => bail!("server rejected connection: {reason}"),
        Some(other) => bail!("unexpected handshake reply {other:?}"),
        None => bail!("server closed the connection during handshake"),
    }
}

/// Sending half: owns a buffered stream clone, the id counter and a
/// reusable encode scratch (steady-state sends allocate nothing — the
/// request's pixel buffer comes from the pool, the payload encodes
/// through the scratch).
pub struct NetSender {
    w: BufWriter<TcpStream>,
    next_id: u64,
    scratch: Vec<u8>,
}

/// Receiving half: decodes reply frames through a reusable payload
/// scratch into pooled float buffers (dropping a reply recycles them).
pub struct NetReceiver {
    r: BufReader<TcpStream>,
    scratch: Vec<u8>,
}

/// A connected wire-protocol client (handshake already done).
pub struct NetClient {
    tx: NetSender,
    rx: NetReceiver,
    info: ServerInfo,
}

impl NetClient {
    /// Connect and handshake ([`handshake`]): sends `Hello`, reads the
    /// server `Info`.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connecting to serving endpoint")?;
        let _ = stream.set_nodelay(true);
        let read_half = stream.try_clone().context("cloning stream for receive half")?;
        let mut tx = NetSender { w: BufWriter::new(stream), next_id: 0, scratch: Vec::new() };
        let mut rx = NetReceiver { r: BufReader::new(read_half), scratch: Vec::new() };
        let info = handshake(&mut rx.r, &mut tx.w)?;
        Ok(NetClient { tx, rx, info })
    }

    /// The server's model/serving parameters from the handshake.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// Pipelined send to the default model: returns the wire id the
    /// reply will carry.
    pub fn send(&mut self, pixels: &[f32]) -> Result<u64> {
        self.tx.send(pixels)
    }

    /// Pipelined send against a named model.
    pub fn send_model(&mut self, model: ModelId, pixels: &[f32]) -> Result<u64> {
        self.tx.send_model(model, pixels)
    }

    /// Block for the next reply frame (any pending id).
    pub fn recv(&mut self) -> Result<Frame> {
        self.rx.recv()
    }

    /// Synchronous round-trip: send one request, wait for its reply.
    /// (Only correct with no other requests in flight on this client —
    /// use [`NetClient::split`] for pipelined traffic.)
    pub fn infer(&mut self, pixels: &[f32]) -> Result<Frame> {
        self.infer_model(ModelId::DEFAULT, pixels)
    }

    /// [`infer`](Self::infer) against a named model.
    pub fn infer_model(&mut self, model: ModelId, pixels: &[f32]) -> Result<Frame> {
        let id = self.send_model(model, pixels)?;
        let reply = self.recv()?;
        match reply {
            Frame::Response { id: got, .. }
            | Frame::Rejected { id: got, .. }
            | Frame::Error { id: got, .. }
                if got != id && got != 0 =>
            {
                bail!("reply id {got} for request {id} — interleaved use of infer()?")
            }
            _ => Ok(reply),
        }
    }

    /// Admin round-trip: hot-load the artifacts at `dir` as `model`.
    /// Call with no requests in flight on this client (the ack is
    /// matched by arrival order, not id).
    pub fn load_model(&mut self, model: ModelId, dir: &str) -> Result<()> {
        self.tx.send_frame(&Frame::LoadModel { model, dir: dir.to_string() })?;
        self.recv_admin_ok(model, "load")
    }

    /// Admin round-trip: retire `model`. The server acks only after the
    /// model's in-flight requests have drained, so a returned `Ok` means
    /// the swap window is open. Call with no requests in flight on this
    /// client.
    pub fn retire_model(&mut self, model: ModelId) -> Result<()> {
        self.tx.send_frame(&Frame::RetireModel { model })?;
        self.recv_admin_ok(model, "retire")
    }

    /// Admin round-trip: scrape the peer's structured stats
    /// ([`StatsPayload`]). A server answers with its own
    /// `MetricsSnapshot`; a router answers with its routing snapshot
    /// plus one server snapshot per connected backend. Call with no
    /// requests in flight on this client (matched by arrival order).
    pub fn get_stats(&mut self) -> Result<StatsPayload> {
        self.tx.send_frame(&Frame::GetStats)?;
        match self.recv()? {
            Frame::Stats(payload) => Ok(*payload),
            Frame::Error { reason, .. } => bail!("stats scrape failed: {reason}"),
            other => bail!("unexpected stats reply {other:?}"),
        }
    }

    /// Admin round-trip: dump the peer's flight recorder as
    /// Chrome-trace JSON. Call with no requests in flight on this
    /// client.
    pub fn dump_trace(&mut self) -> Result<String> {
        self.tx.send_frame(&Frame::DumpTrace)?;
        match self.recv()? {
            Frame::Trace { json } => Ok(json),
            Frame::Error { reason, .. } => bail!("trace dump failed: {reason}"),
            other => bail!("unexpected trace reply {other:?}"),
        }
    }

    fn recv_admin_ok(&mut self, model: ModelId, what: &str) -> Result<()> {
        match self.recv()? {
            Frame::AdminOk { model: got } if got == model => Ok(()),
            Frame::Error { reason, .. } => bail!("{what} of model {model} failed: {reason}"),
            other => bail!("unexpected {what} reply {other:?}"),
        }
    }

    /// Split into independently-owned send/receive halves for
    /// open-loop (pipelined) traffic across two threads.
    pub fn split(self) -> (NetSender, NetReceiver, ServerInfo) {
        (self.tx, self.rx, self.info)
    }
}

impl NetSender {
    /// The wire id the next [`NetSender::send`] will use — lets a
    /// caller register send-time bookkeeping *before* the frame goes
    /// out (a reply can otherwise race the bookkeeping).
    pub fn next_id(&self) -> u64 {
        self.next_id
    }

    /// Send one default-model request frame; returns its wire id. The
    /// pixel slice copies into a pooled buffer and the frame encodes
    /// through the sender's scratch — zero allocations once warm.
    pub fn send(&mut self, pixels: &[f32]) -> Result<u64> {
        self.send_model(ModelId::DEFAULT, pixels)
    }

    /// [`send`](Self::send) against a named model. The id is a stack
    /// copy ([`ModelId`] stores its bytes inline), so tagged sends stay
    /// allocation-free too.
    pub fn send_model(&mut self, model: ModelId, pixels: &[f32]) -> Result<u64> {
        self.send_traced(model, pixels, 0)
    }

    /// [`send_model`](Self::send_model) carrying an explicit trace id
    /// (`0` = untraced — the server may still sample one locally; a
    /// nonzero id rides the v0.3 trailing field and is honored as-is).
    pub fn send_traced(&mut self, model: ModelId, pixels: &[f32], trace: u64) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let pixels = PooledVec::from_slice(pixels);
        self.send_frame(&Frame::Request { id, pixels, model, trace })?;
        Ok(id)
    }

    fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        write_frame_with(&mut self.w, frame, &mut self.scratch)?;
        self.w.flush().context("flushing request")?;
        Ok(())
    }
}

impl NetReceiver {
    /// Block for the next server frame. A clean server-side close is an
    /// error here — callers track how many replies they are owed.
    pub fn recv(&mut self) -> Result<Frame> {
        match read_frame_with(&mut self.r, &mut self.scratch)? {
            Some(frame) => Ok(frame),
            None => bail!("server closed the connection"),
        }
    }
}
