//! The LUNA-CiM wire protocol: a hand-rolled, versioned,
//! length-prefixed binary framing (std-only; no serde in this offline
//! image). See the crate docs' `## Wire protocol` section for the
//! normative layout and versioning rules.
//!
//! Every frame is an 8-byte header followed by a bounded payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  "LC" (0x4C 0x43)
//! 2       1     version (currently 1)
//! 3       1     frame type
//! 4       4     payload length, u32 LE (<= MAX_PAYLOAD)
//! 8       n     payload (type-specific, all integers LE)
//! ```
//!
//! Decoding is strict: bad magic, unknown version, unknown frame type,
//! oversized or short payloads, and trailing payload bytes are all hard
//! errors — the transport layer closes the connection rather than
//! resynchronize (a length-prefixed stream has no safe resync point).

use crate::util::PooledVec;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{Read, Write};

/// Frame magic: ASCII "LC".
pub const MAGIC: [u8; 2] = *b"LC";
/// Current protocol version. Bump on ANY layout change (see the
/// versioning rules in the crate docs).
pub const VERSION: u8 = 1;
/// Upper bound on a frame payload (1 MiB) — caps per-connection memory
/// and rejects garbage lengths before allocating.
pub const MAX_PAYLOAD: u32 = 1 << 20;
/// Upper bound on a reason string carried in `Rejected`/`Error` frames.
pub const MAX_REASON: usize = 1024;

const TYPE_REQUEST: u8 = 0x01;
const TYPE_RESPONSE: u8 = 0x02;
const TYPE_REJECTED: u8 = 0x03;
const TYPE_ERROR: u8 = 0x04;
const TYPE_HELLO: u8 = 0x05;
const TYPE_INFO: u8 = 0x06;

/// Simulated CiM cost fields riding on every response — the wire form
/// of [`crate::coordinator::ScheduleCost`] (energy is the per-request
/// share; latency/programs/hits are the request's batch schedule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireCost {
    pub energy_fj: f64,
    pub latency_ps: u64,
    pub programs: u64,
    pub stationary_hits: u64,
}

/// One protocol frame. Clients send `Hello` then `Request`s; servers
/// answer `Info`, then one `Response`, `Rejected` or `Error` per
/// request (matched by `id`, in completion order — not send order).
///
/// The float payloads (`Request` pixels, `Response` logits) live in
/// pooled buffers ([`PooledVec`]; plain `Vec<f32>` converts in with
/// `.into()`): decoding draws from the pool instead of allocating, and
/// dropping a frame after it is handled recycles the buffer — the wire
/// path's half of the zero-allocation hot path.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: classify one image. `id` is client-assigned and
    /// echoed verbatim on the matching reply.
    Request { id: u64, pixels: PooledVec<f32> },
    /// Server → client: the served answer plus the cost model fields.
    Response {
        id: u64,
        label: u32,
        /// Wall-clock enqueue-to-completion time measured server-side (µs).
        latency_us: u64,
        cost: WireCost,
        logits: PooledVec<f32>,
    },
    /// Server → client: 429-style admission rejection. `retry_after_us`
    /// is the structured backoff hint (`0` = unspecified, e.g. a
    /// connection-limit turn-away with no queue state to derive one).
    Rejected { id: u64, retry_after_us: u64, reason: String },
    /// Server → client: the request was admitted but failed (worker
    /// error) or was itself malformed (wrong pixel count).
    Error { id: u64, reason: String },
    /// Client → server: first frame on a connection; the version in the
    /// header doubles as version negotiation.
    Hello,
    /// Server → client: model/serving parameters, answering `Hello`.
    Info { in_dim: u32, out_dim: u32, max_batch: u32, backend: String },
}

impl Frame {
    fn frame_type(&self) -> u8 {
        match self {
            Frame::Request { .. } => TYPE_REQUEST,
            Frame::Response { .. } => TYPE_RESPONSE,
            Frame::Rejected { .. } => TYPE_REJECTED,
            Frame::Error { .. } => TYPE_ERROR,
            Frame::Hello => TYPE_HELLO,
            Frame::Info { .. } => TYPE_INFO,
        }
    }

    fn encode_payload_into(&self, p: &mut Vec<u8>) {
        p.clear();
        match self {
            Frame::Request { id, pixels } => {
                put_u64(p, *id);
                put_u32(p, pixels.len() as u32);
                for &x in pixels.iter() {
                    put_f32(p, x);
                }
            }
            Frame::Response { id, label, latency_us, cost, logits } => {
                put_u64(p, *id);
                put_u32(p, *label);
                put_u64(p, *latency_us);
                put_f64(p, cost.energy_fj);
                put_u64(p, cost.latency_ps);
                put_u64(p, cost.programs);
                put_u64(p, cost.stationary_hits);
                put_u32(p, logits.len() as u32);
                for &x in logits.iter() {
                    put_f32(p, x);
                }
            }
            Frame::Rejected { id, retry_after_us, reason } => {
                put_u64(p, *id);
                put_u64(p, *retry_after_us);
                put_str(p, reason);
            }
            Frame::Error { id, reason } => {
                put_u64(p, *id);
                put_str(p, reason);
            }
            Frame::Hello => {}
            Frame::Info { in_dim, out_dim, max_batch, backend } => {
                put_u32(p, *in_dim);
                put_u32(p, *out_dim);
                put_u32(p, *max_batch);
                put_str(p, backend);
            }
        }
    }

    fn decode_payload(frame_type: u8, payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor { buf: payload, pos: 0 };
        let frame = match frame_type {
            TYPE_REQUEST => {
                let id = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n * 4 == c.remaining(), "request pixel count disagrees with payload");
                let mut pixels = PooledVec::with_capacity(n);
                for _ in 0..n {
                    pixels.push(c.f32()?);
                }
                Frame::Request { id, pixels }
            }
            TYPE_RESPONSE => {
                let id = c.u64()?;
                let label = c.u32()?;
                let latency_us = c.u64()?;
                let cost = WireCost {
                    energy_fj: c.f64()?,
                    latency_ps: c.u64()?,
                    programs: c.u64()?,
                    stationary_hits: c.u64()?,
                };
                let n = c.u32()? as usize;
                ensure!(n * 4 == c.remaining(), "logit count disagrees with payload");
                let mut logits = PooledVec::with_capacity(n);
                for _ in 0..n {
                    logits.push(c.f32()?);
                }
                Frame::Response { id, label, latency_us, cost, logits }
            }
            TYPE_REJECTED => {
                let id = c.u64()?;
                let retry_after_us = c.u64()?;
                let reason = c.str()?;
                Frame::Rejected { id, retry_after_us, reason }
            }
            TYPE_ERROR => {
                let id = c.u64()?;
                let reason = c.str()?;
                Frame::Error { id, reason }
            }
            TYPE_HELLO => Frame::Hello,
            TYPE_INFO => {
                let in_dim = c.u32()?;
                let out_dim = c.u32()?;
                let max_batch = c.u32()?;
                let backend = c.str()?;
                Frame::Info { in_dim, out_dim, max_batch, backend }
            }
            other => bail!("unknown frame type 0x{other:02x}"),
        };
        ensure!(c.remaining() == 0, "{} trailing payload bytes", c.remaining());
        Ok(frame)
    }
}

/// Serialize one frame (header + payload) to the writer. Does not
/// flush — callers batch or flush per their latency needs. Allocates a
/// fresh payload buffer per call; long-lived writers use
/// [`write_frame_with`] with a reusable scratch instead.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let mut scratch = Vec::new();
    write_frame_with(w, frame, &mut scratch)
}

/// [`write_frame`] encoding into a caller-owned scratch buffer (cleared
/// first, capacity retained) — the per-connection writer threads and
/// client senders reuse one scratch across frames, so steady-state
/// serialization allocates nothing.
pub fn write_frame_with<W: Write>(w: &mut W, frame: &Frame, scratch: &mut Vec<u8>) -> Result<()> {
    frame.encode_payload_into(scratch);
    ensure!(
        scratch.len() as u64 <= MAX_PAYLOAD as u64,
        "frame payload {} exceeds MAX_PAYLOAD",
        scratch.len()
    );
    let mut header = [0u8; 8];
    header[0..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = frame.frame_type();
    header[4..8].copy_from_slice(&(scratch.len() as u32).to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(scratch).context("writing frame payload")?;
    Ok(())
}

/// Read one frame. `Ok(None)` is a clean end-of-stream (the peer closed
/// at a frame boundary); any malformed, truncated, oversized or
/// version-mismatched input is an `Err` — the caller must close the
/// connection, since a corrupt length prefix poisons everything after it.
/// Allocates a fresh payload buffer per call; long-lived readers use
/// [`read_frame_with`] with a reusable scratch instead.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut scratch = Vec::new();
    read_frame_with(r, &mut scratch)
}

/// [`read_frame`] decoding through a caller-owned payload scratch
/// (cleared first, capacity retained). Decoded float payloads draw from
/// the buffer pool, so a warm connection reads requests and responses
/// without allocating.
pub fn read_frame_with<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Option<Frame>> {
    let mut header = [0u8; 8];
    match read_exact_or_eof(r, &mut header)? {
        ReadOutcome::CleanEof => return Ok(None),
        ReadOutcome::Filled => {}
    }
    ensure!(header[0..2] == MAGIC, "bad frame magic {:02x}{:02x}", header[0], header[1]);
    ensure!(
        header[2] == VERSION,
        "protocol version {} unsupported (this build speaks {VERSION})",
        header[2]
    );
    let frame_type = header[3];
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap());
    ensure!(len <= MAX_PAYLOAD, "frame payload {len} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})");
    let len = len as usize;
    // high-water scratch: grow (zero-filling) only when a frame exceeds
    // every previous one; otherwise read_exact overwrites in place — no
    // per-frame zeroing pass on the warm path
    if scratch.len() < len {
        scratch.resize(len, 0);
    }
    let payload = &mut scratch[..len];
    r.read_exact(payload).context("reading frame payload (truncated frame?)")?;
    Frame::decode_payload(frame_type, payload)
}

enum ReadOutcome {
    Filled,
    CleanEof,
}

/// `read_exact`, except a clean EOF before the *first* byte is not an
/// error — that is how a peer hangs up between frames.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(ReadOutcome::CleanEof),
            Ok(0) => bail!("connection closed mid-frame ({filled} header bytes read)"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    Ok(ReadOutcome::Filled)
}

fn put_u32(p: &mut Vec<u8>, v: u32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(p: &mut Vec<u8>, v: u64) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(p: &mut Vec<u8>, v: f32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(p: &mut Vec<u8>, v: f64) {
    p.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed UTF-8, truncated to [`MAX_REASON`] bytes on a char
/// boundary (reasons are diagnostics, not data).
fn put_str(p: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(MAX_REASON);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u32(p, end as u32);
    p.extend_from_slice(&s.as_bytes()[..end]);
}

/// Bounds-checked little-endian payload reader.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.remaining();
        ensure!(left >= n, "payload truncated: need {n} bytes, {left} left");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= MAX_REASON, "reason length {n} exceeds MAX_REASON");
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes).context("reason is not UTF-8")?.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut r = &buf[..];
        let back = read_frame(&mut r).unwrap().expect("one frame");
        assert!(r.is_empty(), "frame must consume its exact bytes");
        back
    }

    #[test]
    fn every_frame_kind_roundtrips_bit_exactly() {
        let frames = vec![
            Frame::Hello,
            Frame::Request { id: 7, pixels: vec![0.0, 0.25, -1.5, f32::MIN_POSITIVE].into() },
            Frame::Request { id: u64::MAX, pixels: vec![].into() },
            Frame::Response {
                id: 9,
                label: 3,
                latency_us: 1234,
                cost: WireCost {
                    energy_fj: 1.5e6,
                    latency_ps: 987_654,
                    programs: 42,
                    stationary_hits: 2326,
                },
                logits: vec![-0.5, 0.5, 1e-7].into(),
            },
            Frame::Rejected { id: 11, retry_after_us: 500, reason: "server at capacity".into() },
            Frame::Rejected { id: 0, retry_after_us: 0, reason: String::new() },
            Frame::Error { id: 13, reason: "worker died".into() },
            Frame::Info { in_dim: 64, out_dim: 10, max_batch: 8, backend: "calibrated".into() },
        ];
        for f in frames {
            assert_eq!(roundtrip(f.clone()), f);
        }
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello).unwrap();
        write_frame(&mut buf, &Frame::Request { id: 1, pixels: vec![0.5; 64].into() }).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(Frame::Hello));
        match read_frame(&mut r).unwrap() {
            Some(Frame::Request { id: 1, pixels }) => assert_eq!(pixels.len(), 64),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF after last frame");
    }

    #[test]
    fn clean_eof_is_none_but_midframe_eof_is_error() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none());
        // a truncated header
        let mut short: &[u8] = &[b'L', b'C', VERSION];
        assert!(read_frame(&mut short).is_err());
        // a full header promising more payload than the stream holds
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Request { id: 1, pixels: vec![0.5; 16].into() }).unwrap();
        buf.truncate(buf.len() - 3);
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn bad_magic_version_type_and_oversize_are_rejected() {
        let mut ok = Vec::new();
        write_frame(&mut ok, &Frame::Hello).unwrap();

        let mut bad_magic = ok.clone();
        bad_magic[0] = b'X';
        assert!(read_frame(&mut &bad_magic[..]).is_err());

        let mut bad_version = ok.clone();
        bad_version[2] = VERSION + 1;
        let err = read_frame(&mut &bad_version[..]).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");

        let mut bad_type = ok.clone();
        bad_type[3] = 0x7f;
        assert!(read_frame(&mut &bad_type[..]).is_err());

        let mut oversize = ok;
        oversize[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_frame(&mut &oversize[..]).is_err());
    }

    #[test]
    fn inconsistent_counts_and_trailing_bytes_are_rejected() {
        // request whose pixel count disagrees with the payload length
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Request { id: 1, pixels: vec![1.0, 2.0].into() }).unwrap();
        // corrupt the count (first payload field after the 8-byte id)
        buf[8 + 8] = 9;
        assert!(read_frame(&mut &buf[..]).is_err());

        // hello with trailing payload bytes
        let mut hello = Vec::new();
        write_frame(&mut hello, &Frame::Hello).unwrap();
        hello[4] = 2; // claim 2 payload bytes
        hello.extend_from_slice(&[0, 0]);
        assert!(read_frame(&mut &hello[..]).is_err());
    }

    #[test]
    fn long_reasons_truncate_on_char_boundary() {
        let reason = "é".repeat(MAX_REASON); // 2 bytes per char
        let f = roundtrip(Frame::Error { id: 1, reason });
        match f {
            Frame::Error { reason, .. } => {
                assert!(reason.len() <= MAX_REASON);
                assert!(!reason.is_empty());
                assert!(reason.chars().all(|c| c == 'é'), "no split surrogate");
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
