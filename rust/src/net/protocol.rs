//! The LUNA-CiM wire protocol: a hand-rolled, versioned,
//! length-prefixed binary framing (std-only; no serde in this offline
//! image). See the crate docs' `## Wire protocol` section for the
//! normative layout and versioning rules.
//!
//! Every frame is an 8-byte header followed by a bounded payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  "LC" (0x4C 0x43)
//! 2       1     version: (major << 4) | minor (currently 0x03)
//! 3       1     frame type
//! 4       4     payload length, u32 LE (<= MAX_PAYLOAD)
//! 8       n     payload (type-specific, all integers LE)
//! ```
//!
//! The version byte is split into a 4-bit **major** (incompatible
//! layout changes) and a 4-bit **minor** (append-only field additions).
//! A reader accepts any frame whose major nibble matches its own:
//! same-or-lower minors decode strictly (trailing payload bytes are a
//! hard error), while *higher* minors decode the fields this build
//! knows and tolerate trailing unknown bytes — that is what lets an
//! old server keep serving a newer client. Minor additions must be
//! append-only: a new field goes after every existing one, and once a
//! later field exists every earlier optional field must be encoded.
//!
//! Everything else stays strict: bad magic, major-version mismatch,
//! unknown frame type, oversized or short payloads are all hard errors
//! — the transport layer closes the connection rather than
//! resynchronize (a length-prefixed stream has no safe resync point).
//!
//! This module is the **single source of truth** for version handling:
//! the server front-end, the client, and the router's health probe all
//! move frames exclusively through [`read_frame_with`] /
//! [`write_frame_with`] (the probe shares the client's `Hello`→`Info`
//! helper, [`crate::net::client::handshake`]), so no other module
//! inspects or re-encodes version bytes.

use crate::coordinator::metrics::{BackendStats, MetricsSnapshot, RouterSnapshot, TenantStats};
use crate::util::trace::N_STAGES;
use crate::util::{PoolStats, PooledVec};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::fmt;
use std::io::{Read, Write};

/// Frame magic: ASCII "LC".
pub const MAGIC: [u8; 2] = *b"LC";
/// Protocol major version (high nibble of the wire version byte). Bump
/// only on incompatible layout changes; readers reject any other major.
pub const MAJOR: u8 = 0;
/// Protocol minor version (low nibble). Bump on append-only field
/// additions; readers accept every minor ≥ 1 of their own major
/// (higher minors decode leniently — see the module docs). Minor 2
/// added the optional `Request` model id, the `Info` model list and
/// the `LoadModel`/`RetireModel`/`AdminOk` admin frames. Minor 3 added
/// the optional trailing trace id on `Request`/`Response` and the
/// `GetStats`/`Stats` + `DumpTrace`/`Trace` observability frames.
pub const MINOR: u8 = 3;
/// The version byte this build writes: `(MAJOR << 4) | MINOR`.
pub const VERSION: u8 = (MAJOR << 4) | MINOR;
/// Upper bound on a frame payload (1 MiB) — caps per-connection memory
/// and rejects garbage lengths before allocating.
pub const MAX_PAYLOAD: u32 = 1 << 20;
/// Upper bound on a reason string carried in `Rejected`/`Error` frames.
pub const MAX_REASON: usize = 1024;
/// Upper bound on a model id's byte length. Ids ride on every request
/// and key the plan cache through a fixed-size `Copy` buffer
/// ([`ModelId`]), which is what keeps the tagged hot path
/// allocation-free.
pub const MAX_MODEL_ID: usize = 63;

/// Major nibble of a wire version byte.
pub fn version_major(v: u8) -> u8 {
    v >> 4
}

/// Minor nibble of a wire version byte.
pub fn version_minor(v: u8) -> u8 {
    v & 0x0f
}

const TYPE_REQUEST: u8 = 0x01;
const TYPE_RESPONSE: u8 = 0x02;
const TYPE_REJECTED: u8 = 0x03;
const TYPE_ERROR: u8 = 0x04;
const TYPE_HELLO: u8 = 0x05;
const TYPE_INFO: u8 = 0x06;
const TYPE_LOAD_MODEL: u8 = 0x07;
const TYPE_RETIRE_MODEL: u8 = 0x08;
const TYPE_ADMIN_OK: u8 = 0x09;
const TYPE_GET_STATS: u8 = 0x0a;
const TYPE_STATS: u8 = 0x0b;
const TYPE_DUMP_TRACE: u8 = 0x0c;
const TYPE_TRACE: u8 = 0x0d;

/// A model identifier: at most [`MAX_MODEL_ID`] bytes of UTF-8 stored
/// inline (no heap), so tagging a request, keying the plan cache and
/// carrying an id through the router's routing state are all
/// allocation-free copies. The empty id names the server's **default
/// model** (the one `artifacts_dir` points at) — a v0.1 `Request`,
/// which has no model field at all, decodes to exactly this.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelId {
    len: u8,
    buf: [u8; MAX_MODEL_ID],
}

impl ModelId {
    /// The default-model id (the empty id).
    pub const DEFAULT: ModelId = ModelId { len: 0, buf: [0; MAX_MODEL_ID] };

    /// Construct from a string; errors if it exceeds [`MAX_MODEL_ID`]
    /// bytes. The empty string is the default-model id.
    pub fn new(s: &str) -> Result<ModelId> {
        ensure!(s.len() <= MAX_MODEL_ID, "model id `{s}` exceeds {MAX_MODEL_ID} bytes");
        let mut buf = [0u8; MAX_MODEL_ID];
        buf[..s.len()].copy_from_slice(s.as_bytes());
        Ok(ModelId { len: s.len() as u8, buf })
    }

    /// Does this id name the default model (empty id)?
    pub fn is_default(&self) -> bool {
        self.len == 0
    }

    /// The id as a string slice (`""` for the default model).
    pub fn as_str(&self) -> &str {
        // constructors only copy whole `&str`s in (trailing bytes stay
        // zeroed, keeping derived Eq/Hash sound), so this never fails
        std::str::from_utf8(&self.buf[..self.len as usize]).unwrap_or("")
    }
}

impl Default for ModelId {
    fn default() -> Self {
        Self::DEFAULT
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_default() {
            f.write_str("<default>")
        } else {
            f.write_str(self.as_str())
        }
    }
}

impl fmt::Debug for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ModelId({self})")
    }
}

/// Simulated CiM cost fields riding on every response — the wire form
/// of [`crate::coordinator::ScheduleCost`] (energy is the per-request
/// share; latency/programs/hits are the request's batch schedule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireCost {
    pub energy_fj: f64,
    pub latency_ps: u64,
    pub programs: u64,
    pub stationary_hits: u64,
}

/// One protocol frame. Clients send `Hello` then `Request`s; servers
/// answer `Info`, then one `Response`, `Rejected` or `Error` per
/// request (matched by `id`, in completion order — not send order).
/// `LoadModel`/`RetireModel` are the admin pair for hot model swap,
/// each acknowledged by `AdminOk` (or answered by `Error`).
///
/// The float payloads (`Request` pixels, `Response` logits) live in
/// pooled buffers ([`PooledVec`]; plain `Vec<f32>` converts in with
/// `.into()`): decoding draws from the pool instead of allocating, and
/// dropping a frame after it is handled recycles the buffer — the wire
/// path's half of the zero-allocation hot path.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: classify one image. `id` is client-assigned and
    /// echoed verbatim on the matching reply. `model` picks which of
    /// the server's resident artifacts serves it; it is the minor-2
    /// trailing field, absent on the wire for the default model (so
    /// default traffic keeps the v0.1 byte layout). `trace` is the
    /// minor-3 trailing trace id (`0` = untraced, absent on the wire);
    /// when a router assigned one, the backend records its spans under
    /// it instead of sampling its own — that is what stitches one
    /// request's timeline across processes.
    Request { id: u64, pixels: PooledVec<f32>, model: ModelId, trace: u64 },
    /// Server → client: the served answer plus the cost model fields.
    /// `trace` is the minor-3 trailing trace id echoed from the request
    /// (`0` = untraced, absent on the wire).
    Response {
        id: u64,
        label: u32,
        /// Wall-clock enqueue-to-completion time measured server-side (µs).
        latency_us: u64,
        cost: WireCost,
        logits: PooledVec<f32>,
        trace: u64,
    },
    /// Server → client: 429-style admission rejection. `retry_after_us`
    /// is the structured backoff hint (`0` = unspecified, e.g. a
    /// connection-limit turn-away with no queue state to derive one, or
    /// a retiring model — where no backoff will help).
    Rejected { id: u64, retry_after_us: u64, reason: String },
    /// Server → client: the request was admitted but failed (worker
    /// error) or was itself malformed (wrong pixel count, unknown
    /// model).
    Error { id: u64, reason: String },
    /// Client → server: first frame on a connection; the version in the
    /// header doubles as version negotiation.
    Hello,
    /// Server → client: model/serving parameters, answering `Hello`.
    /// `models` (minor 2) is the sorted list of non-default model ids
    /// currently servable — the router's fleet check compares these.
    Info { in_dim: u32, out_dim: u32, max_batch: u32, backend: String, models: Vec<String> },
    /// Admin → server: install the artifact at `dir` under `model`
    /// without dropping connections (dims must match resident models).
    LoadModel { model: ModelId, dir: String },
    /// Admin → server: retire `model`. In-flight requests drain (the
    /// ack arrives after the drain); new requests get `Rejected`.
    RetireModel { model: ModelId },
    /// Server → admin: the `LoadModel`/`RetireModel` for `model` took
    /// effect.
    AdminOk { model: ModelId },
    /// Admin → server or router (minor 3): scrape the live metrics.
    GetStats,
    /// Server/router → admin: the structured stats reply. A server
    /// fills `server`; a router fills `router` and fans the scrape out
    /// to its healthy backends, aggregating their snapshots into
    /// `backends` (addr → snapshot). Boxed to keep `Frame` small.
    Stats(Box<StatsPayload>),
    /// Admin → server or router (minor 3): dump the process's flight
    /// recorder ([`crate::util::trace::FlightRecorder`]).
    DumpTrace,
    /// Server/router → admin: the Chrome trace-event JSON dump of this
    /// process's recorder. Dumps from several processes merge
    /// client-side ([`crate::util::trace::merge_trace_dumps`]) and
    /// stitch by trace id.
    Trace { json: String },
}

/// The `Stats` frame body: whichever tier answered fills its own
/// snapshot, and a router adds one scraped snapshot per healthy
/// backend. All fields ride the wire as fixed-order scalars (see
/// `encode_metrics`); additions follow the same append-only minor rules
/// as frames.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsPayload {
    /// The answering server's own metrics (servers fill this).
    pub server: Option<MetricsSnapshot>,
    /// The answering router's fleet counters (routers fill this).
    pub router: Option<RouterSnapshot>,
    /// Router only: per-backend scrapes, `(addr, snapshot)`.
    pub backends: Vec<(String, MetricsSnapshot)>,
}

impl Frame {
    fn frame_type(&self) -> u8 {
        match self {
            Frame::Request { .. } => TYPE_REQUEST,
            Frame::Response { .. } => TYPE_RESPONSE,
            Frame::Rejected { .. } => TYPE_REJECTED,
            Frame::Error { .. } => TYPE_ERROR,
            Frame::Hello => TYPE_HELLO,
            Frame::Info { .. } => TYPE_INFO,
            Frame::LoadModel { .. } => TYPE_LOAD_MODEL,
            Frame::RetireModel { .. } => TYPE_RETIRE_MODEL,
            Frame::AdminOk { .. } => TYPE_ADMIN_OK,
            Frame::GetStats => TYPE_GET_STATS,
            Frame::Stats(_) => TYPE_STATS,
            Frame::DumpTrace => TYPE_DUMP_TRACE,
            Frame::Trace { .. } => TYPE_TRACE,
        }
    }

    fn encode_payload_into(&self, p: &mut Vec<u8>) {
        p.clear();
        match self {
            Frame::Request { id, pixels, model, trace } => {
                put_u64(p, *id);
                put_u32(p, pixels.len() as u32);
                for &x in pixels.iter() {
                    put_f32(p, x);
                }
                // minor-2 trailing field, omitted for the default model
                // so untagged traffic keeps the v0.1 byte layout — but
                // the append-only rule forces it back in whenever the
                // later minor-3 trace field is present
                if !model.is_default() || *trace != 0 {
                    put_model(p, model);
                }
                // minor-3 trailing field, omitted when untraced
                if *trace != 0 {
                    put_u64(p, *trace);
                }
            }
            Frame::Response { id, label, latency_us, cost, logits, trace } => {
                put_u64(p, *id);
                put_u32(p, *label);
                put_u64(p, *latency_us);
                put_f64(p, cost.energy_fj);
                put_u64(p, cost.latency_ps);
                put_u64(p, cost.programs);
                put_u64(p, cost.stationary_hits);
                put_u32(p, logits.len() as u32);
                for &x in logits.iter() {
                    put_f32(p, x);
                }
                // minor-3 trailing field, omitted when untraced
                if *trace != 0 {
                    put_u64(p, *trace);
                }
            }
            Frame::Rejected { id, retry_after_us, reason } => {
                put_u64(p, *id);
                put_u64(p, *retry_after_us);
                put_str(p, reason);
            }
            Frame::Error { id, reason } => {
                put_u64(p, *id);
                put_str(p, reason);
            }
            Frame::Hello => {}
            Frame::Info { in_dim, out_dim, max_batch, backend, models } => {
                put_u32(p, *in_dim);
                put_u32(p, *out_dim);
                put_u32(p, *max_batch);
                put_str(p, backend);
                // minor-2 trailing field: always encoded, even when
                // empty (append-only rule — later minors may add
                // fields after it)
                put_u32(p, models.len() as u32);
                for m in models {
                    put_str(p, m);
                }
            }
            Frame::LoadModel { model, dir } => {
                put_model(p, model);
                put_str(p, dir);
            }
            Frame::RetireModel { model } => {
                put_model(p, model);
            }
            Frame::AdminOk { model } => {
                put_model(p, model);
            }
            Frame::GetStats | Frame::DumpTrace => {}
            Frame::Stats(stats) => {
                let mut flags = 0u8;
                if stats.server.is_some() {
                    flags |= 1;
                }
                if stats.router.is_some() {
                    flags |= 2;
                }
                p.push(flags);
                if let Some(s) = &stats.server {
                    encode_metrics(p, s);
                }
                if let Some(r) = &stats.router {
                    encode_router(p, r);
                }
                put_u32(p, stats.backends.len() as u32);
                for (addr, snap) in &stats.backends {
                    put_str(p, addr);
                    encode_metrics(p, snap);
                }
            }
            Frame::Trace { json } => {
                put_blob(p, json.as_bytes());
            }
        }
    }

    fn decode_payload(frame_type: u8, version: u8, payload: &[u8]) -> Result<Frame> {
        let minor = version_minor(version);
        let mut c = Cursor { buf: payload, pos: 0 };
        let frame = match frame_type {
            TYPE_REQUEST => {
                let id = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n * 4 <= c.remaining(), "request pixel count disagrees with payload");
                let mut pixels = PooledVec::with_capacity(n);
                for _ in 0..n {
                    pixels.push(c.f32()?);
                }
                // the optional minor-2 model id: absent = default model
                // (which is also what every v0.1 request decodes to)
                let model = if minor >= 2 && c.remaining() > 0 {
                    c.model()?
                } else {
                    ensure!(
                        minor >= 2 || c.remaining() == 0,
                        "request pixel count disagrees with payload"
                    );
                    ModelId::DEFAULT
                };
                // the optional minor-3 trace id: absent = untraced
                let trace = if minor >= 3 && c.remaining() > 0 { c.u64()? } else { 0 };
                Frame::Request { id, pixels, model, trace }
            }
            TYPE_RESPONSE => {
                let id = c.u64()?;
                let label = c.u32()?;
                let latency_us = c.u64()?;
                let cost = WireCost {
                    energy_fj: c.f64()?,
                    latency_ps: c.u64()?,
                    programs: c.u64()?,
                    stationary_hits: c.u64()?,
                };
                let n = c.u32()? as usize;
                ensure!(n * 4 <= c.remaining(), "logit count disagrees with payload");
                let mut logits = PooledVec::with_capacity(n);
                for _ in 0..n {
                    logits.push(c.f32()?);
                }
                // the optional minor-3 trace id: absent = untraced
                let trace = if minor >= 3 && c.remaining() > 0 { c.u64()? } else { 0 };
                Frame::Response { id, label, latency_us, cost, logits, trace }
            }
            TYPE_REJECTED => {
                let id = c.u64()?;
                let retry_after_us = c.u64()?;
                let reason = c.str()?;
                Frame::Rejected { id, retry_after_us, reason }
            }
            TYPE_ERROR => {
                let id = c.u64()?;
                let reason = c.str()?;
                Frame::Error { id, reason }
            }
            TYPE_HELLO => Frame::Hello,
            TYPE_INFO => {
                let in_dim = c.u32()?;
                let out_dim = c.u32()?;
                let max_batch = c.u32()?;
                let backend = c.str()?;
                // minor-2 trailing field; a v0.1 Info simply has none
                let mut models = Vec::new();
                if minor >= 2 && c.remaining() > 0 {
                    let n = c.u32()? as usize;
                    ensure!(n <= 4096, "model list length {n} is implausible");
                    models.reserve(n);
                    for _ in 0..n {
                        models.push(c.str()?);
                    }
                }
                Frame::Info { in_dim, out_dim, max_batch, backend, models }
            }
            TYPE_LOAD_MODEL => {
                let model = c.model()?;
                let dir = c.str()?;
                Frame::LoadModel { model, dir }
            }
            TYPE_RETIRE_MODEL => Frame::RetireModel { model: c.model()? },
            TYPE_ADMIN_OK => Frame::AdminOk { model: c.model()? },
            TYPE_GET_STATS => Frame::GetStats,
            TYPE_STATS => {
                let flags = c.take(1)?[0];
                let server = if flags & 1 != 0 { Some(decode_metrics(&mut c)?) } else { None };
                let router = if flags & 2 != 0 { Some(decode_router(&mut c)?) } else { None };
                let n = c.u32()? as usize;
                ensure!(n <= 4096, "stats backend count {n} is implausible");
                let mut backends = Vec::with_capacity(n); // lint: allow(alloc): cold admin path
                for _ in 0..n {
                    let addr = c.str()?;
                    backends.push((addr, decode_metrics(&mut c)?));
                }
                Frame::Stats(Box::new(StatsPayload { server, router, backends }))
            }
            TYPE_DUMP_TRACE => Frame::DumpTrace,
            TYPE_TRACE => {
                let bytes = c.blob()?;
                let json = std::str::from_utf8(bytes)
                    .context("trace dump is not UTF-8")?
                    .to_string();
                Frame::Trace { json }
            }
            other => bail!("unknown frame type 0x{other:02x}"),
        };
        // strict for our own minor and below; a *newer* minor may carry
        // append-only fields this build does not know — tolerate them
        if minor <= MINOR {
            ensure!(c.remaining() == 0, "{} trailing payload bytes", c.remaining());
        }
        Ok(frame)
    }
}

/// Serialize one frame (header + payload) to the writer. Does not
/// flush — callers batch or flush per their latency needs. Allocates a
/// fresh payload buffer per call; long-lived writers use
/// [`write_frame_with`] with a reusable scratch instead.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let mut scratch = Vec::new();
    write_frame_with(w, frame, &mut scratch)
}

/// [`write_frame`] encoding into a caller-owned scratch buffer (cleared
/// first, capacity retained) — the per-connection writer threads and
/// client senders reuse one scratch across frames, so steady-state
/// serialization allocates nothing.
pub fn write_frame_with<W: Write>(w: &mut W, frame: &Frame, scratch: &mut Vec<u8>) -> Result<()> {
    frame.encode_payload_into(scratch);
    ensure!(
        scratch.len() as u64 <= MAX_PAYLOAD as u64,
        "frame payload {} exceeds MAX_PAYLOAD",
        scratch.len()
    );
    let mut header = [0u8; 8];
    header[0..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = frame.frame_type();
    header[4..8].copy_from_slice(&(scratch.len() as u32).to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(scratch).context("writing frame payload")?;
    Ok(())
}

/// Read one frame. `Ok(None)` is a clean end-of-stream (the peer closed
/// at a frame boundary); any malformed, truncated, oversized or
/// major-version-mismatched input is an `Err` — the caller must close
/// the connection, since a corrupt length prefix poisons everything
/// after it. Same-major frames of a *higher* minor decode leniently
/// (see the module docs). Allocates a fresh payload buffer per call;
/// long-lived readers use [`read_frame_with`] with a reusable scratch
/// instead.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut scratch = Vec::new();
    read_frame_with(r, &mut scratch)
}

/// [`read_frame`] decoding through a caller-owned payload scratch
/// (cleared first, capacity retained). Decoded float payloads draw from
/// the buffer pool, so a warm connection reads requests and responses
/// without allocating.
pub fn read_frame_with<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Option<Frame>> {
    let mut header = [0u8; 8];
    match read_exact_or_eof(r, &mut header)? {
        ReadOutcome::CleanEof => return Ok(None),
        ReadOutcome::Filled => {}
    }
    ensure!(header[0..2] == MAGIC, "bad frame magic {:02x}{:02x}", header[0], header[1]);
    let version = header[2];
    ensure!(
        version_major(version) == MAJOR && version_minor(version) >= 1,
        "protocol version {version:#04x} unsupported (this build speaks major {MAJOR} \
         minor {MINOR}, plus every other minor of that major)"
    );
    let frame_type = header[3];
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap());
    ensure!(len <= MAX_PAYLOAD, "frame payload {len} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})");
    let len = len as usize;
    // high-water scratch: grow (zero-filling) only when a frame exceeds
    // every previous one; otherwise read_exact overwrites in place — no
    // per-frame zeroing pass on the warm path
    if scratch.len() < len {
        scratch.resize(len, 0);
    }
    let payload = &mut scratch[..len];
    r.read_exact(payload).context("reading frame payload (truncated frame?)")?;
    Frame::decode_payload(frame_type, version, payload)
}

enum ReadOutcome {
    Filled,
    CleanEof,
}

/// `read_exact`, except a clean EOF before the *first* byte is not an
/// error — that is how a peer hangs up between frames.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(ReadOutcome::CleanEof),
            Ok(0) => bail!("connection closed mid-frame ({filled} header bytes read)"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    Ok(ReadOutcome::Filled)
}

fn put_u32(p: &mut Vec<u8>, v: u32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(p: &mut Vec<u8>, v: u64) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(p: &mut Vec<u8>, v: f32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(p: &mut Vec<u8>, v: f64) {
    p.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed UTF-8, truncated to [`MAX_REASON`] bytes on a char
/// boundary (reasons are diagnostics, not data).
fn put_str(p: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(MAX_REASON);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u32(p, end as u32);
    p.extend_from_slice(&s.as_bytes()[..end]);
}

/// A model id on the wire: one length byte (≤ [`MAX_MODEL_ID`]) + that
/// many bytes of UTF-8. Compact because it rides on every request.
fn put_model(p: &mut Vec<u8>, m: &ModelId) {
    let s = m.as_str();
    p.push(s.len() as u8);
    p.extend_from_slice(s.as_bytes());
}

/// Length-prefixed bytes for payloads too big for [`put_str`]'s
/// [`MAX_REASON`] cap (trace dumps). Bounded only by [`MAX_PAYLOAD`],
/// which [`write_frame_with`] enforces on the whole frame.
fn put_blob(p: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(p, bytes.len() as u32);
    p.extend_from_slice(bytes);
}

/// A [`MetricsSnapshot`] on the wire: every scalar in declaration
/// order, then the three per-stage arrays, the tenant list and the
/// pool counters. Fixed order — additions go at the end under the
/// append-only minor rules.
fn encode_metrics(p: &mut Vec<u8>, s: &MetricsSnapshot) {
    put_u64(p, s.requests);
    put_u64(p, s.batches);
    put_u64(p, s.padded_slots);
    put_u64(p, s.accepted);
    put_u64(p, s.rejected);
    put_u64(p, s.retry_hints);
    put_u64(p, s.failed_batches);
    put_u64(p, s.failed_requests);
    put_f64(p, s.mean_latency_us);
    put_u64(p, s.p50_latency_us);
    put_u64(p, s.p99_latency_us);
    put_u64(p, s.max_latency_us);
    put_f64(p, s.throughput_rps);
    put_f64(p, s.sim_energy_fj);
    put_u64(p, s.sim_p50_latency_ns);
    put_u64(p, s.sim_p99_latency_ns);
    put_u64(p, s.sim_programs);
    put_u64(p, s.sim_stationary_hits);
    put_f64(p, s.host_gemm_mean_us);
    put_u64(p, s.host_gemm_p50_us);
    put_u64(p, s.host_gemm_p99_us);
    put_u64(p, s.plan_hits);
    put_u64(p, s.plan_misses);
    put_u64(p, s.plan_evictions);
    put_u64(p, s.plan_compiles);
    put_u64(p, s.plan_resident);
    put_u64(p, s.plan_resident_bytes);
    put_u64(p, s.plan_compile_p99_us);
    put_u64(p, s.plan_stall_p99_us);
    for i in 0..N_STAGES {
        put_u64(p, s.stage_count[i]);
    }
    for i in 0..N_STAGES {
        put_u64(p, s.stage_p50_us[i]);
    }
    for i in 0..N_STAGES {
        put_u64(p, s.stage_p99_us[i]);
    }
    put_u32(p, s.tenants.len() as u32);
    for t in &s.tenants {
        put_str(p, &t.name);
        put_u64(p, t.requests);
        put_u64(p, t.p50_latency_us);
        put_u64(p, t.p99_latency_us);
        put_u64(p, t.p50_queue_us);
        put_u64(p, t.p99_queue_us);
    }
    put_u64(p, s.pool.hits);
    put_u64(p, s.pool.misses);
    put_u64(p, s.pool.recycled);
}

fn decode_metrics(c: &mut Cursor<'_>) -> Result<MetricsSnapshot> {
    let requests = c.u64()?;
    let batches = c.u64()?;
    let padded_slots = c.u64()?;
    let accepted = c.u64()?;
    let rejected = c.u64()?;
    let retry_hints = c.u64()?;
    let failed_batches = c.u64()?;
    let failed_requests = c.u64()?;
    let mean_latency_us = c.f64()?;
    let p50_latency_us = c.u64()?;
    let p99_latency_us = c.u64()?;
    let max_latency_us = c.u64()?;
    let throughput_rps = c.f64()?;
    let sim_energy_fj = c.f64()?;
    let sim_p50_latency_ns = c.u64()?;
    let sim_p99_latency_ns = c.u64()?;
    let sim_programs = c.u64()?;
    let sim_stationary_hits = c.u64()?;
    let host_gemm_mean_us = c.f64()?;
    let host_gemm_p50_us = c.u64()?;
    let host_gemm_p99_us = c.u64()?;
    let plan_hits = c.u64()?;
    let plan_misses = c.u64()?;
    let plan_evictions = c.u64()?;
    let plan_compiles = c.u64()?;
    let plan_resident = c.u64()?;
    let plan_resident_bytes = c.u64()?;
    let plan_compile_p99_us = c.u64()?;
    let plan_stall_p99_us = c.u64()?;
    let mut stage_count = [0u64; N_STAGES];
    for s in stage_count.iter_mut() {
        *s = c.u64()?;
    }
    let mut stage_p50_us = [0u64; N_STAGES];
    for s in stage_p50_us.iter_mut() {
        *s = c.u64()?;
    }
    let mut stage_p99_us = [0u64; N_STAGES];
    for s in stage_p99_us.iter_mut() {
        *s = c.u64()?;
    }
    let n = c.u32()? as usize;
    ensure!(n <= 4096, "tenant count {n} is implausible");
    let mut tenants = Vec::new();
    tenants.reserve(n);
    for _ in 0..n {
        tenants.push(TenantStats {
            name: c.str()?,
            requests: c.u64()?,
            p50_latency_us: c.u64()?,
            p99_latency_us: c.u64()?,
            p50_queue_us: c.u64()?,
            p99_queue_us: c.u64()?,
        });
    }
    let pool = PoolStats { hits: c.u64()?, misses: c.u64()?, recycled: c.u64()? };
    Ok(MetricsSnapshot {
        requests,
        batches,
        padded_slots,
        accepted,
        rejected,
        retry_hints,
        failed_batches,
        failed_requests,
        mean_latency_us,
        p50_latency_us,
        p99_latency_us,
        max_latency_us,
        throughput_rps,
        sim_energy_fj,
        sim_p50_latency_ns,
        sim_p99_latency_ns,
        sim_programs,
        sim_stationary_hits,
        host_gemm_mean_us,
        host_gemm_p50_us,
        host_gemm_p99_us,
        plan_hits,
        plan_misses,
        plan_evictions,
        plan_compiles,
        plan_resident,
        plan_resident_bytes,
        plan_compile_p99_us,
        plan_stall_p99_us,
        stage_count,
        stage_p50_us,
        stage_p99_us,
        tenants,
        pool,
    })
}

/// A [`RouterSnapshot`] on the wire: fleet counters then one block per
/// backend, same fixed-order rules as `encode_metrics`.
fn encode_router(p: &mut Vec<u8>, r: &RouterSnapshot) {
    put_u64(p, r.terminal_rejections);
    put_u32(p, r.backends.len() as u32);
    for b in &r.backends {
        put_str(p, &b.addr);
        put_u64(p, b.routed);
        put_u64(p, b.rejected);
        put_u64(p, b.failed_over);
        put_u64(p, b.quarantines);
        put_u64(p, b.recoveries);
    }
}

fn decode_router(c: &mut Cursor<'_>) -> Result<RouterSnapshot> {
    let terminal_rejections = c.u64()?;
    let n = c.u32()? as usize;
    ensure!(n <= 4096, "router backend count {n} is implausible");
    let mut backends = Vec::new();
    backends.reserve(n);
    for _ in 0..n {
        backends.push(BackendStats {
            addr: c.str()?,
            routed: c.u64()?,
            rejected: c.u64()?,
            failed_over: c.u64()?,
            quarantines: c.u64()?,
            recoveries: c.u64()?,
        });
    }
    Ok(RouterSnapshot { backends, terminal_rejections })
}

/// Bounds-checked little-endian payload reader.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.remaining();
        ensure!(left >= n, "payload truncated: need {n} bytes, {left} left");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= MAX_REASON, "reason length {n} exceeds MAX_REASON");
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes).context("reason is not UTF-8")?.to_string())
    }

    fn model(&mut self) -> Result<ModelId> {
        let n = self.take(1)?[0] as usize;
        ensure!(n <= MAX_MODEL_ID, "model id length {n} exceeds {MAX_MODEL_ID}");
        let bytes = self.take(n)?;
        ModelId::new(std::str::from_utf8(bytes).context("model id is not UTF-8")?)
    }

    /// Length-prefixed bytes written by [`put_blob`] (bounded by
    /// [`MAX_PAYLOAD`] rather than [`MAX_REASON`]).
    fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()?;
        ensure!(n <= MAX_PAYLOAD, "blob length {n} exceeds MAX_PAYLOAD");
        self.take(n as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut r = &buf[..];
        let back = read_frame(&mut r).unwrap().expect("one frame");
        assert!(r.is_empty(), "frame must consume its exact bytes");
        back
    }

    fn mid(s: &str) -> ModelId {
        ModelId::new(s).unwrap()
    }

    #[test]
    fn every_frame_kind_roundtrips_bit_exactly() {
        let frames = vec![
            Frame::Hello,
            Frame::Request {
                id: 7,
                pixels: vec![0.0, 0.25, -1.5, f32::MIN_POSITIVE].into(),
                model: ModelId::DEFAULT,
                trace: 0,
            },
            Frame::Request {
                id: u64::MAX,
                pixels: vec![].into(),
                model: ModelId::DEFAULT,
                trace: 0,
            },
            Frame::Request { id: 3, pixels: vec![0.5; 8].into(), model: mid("tenant-a"), trace: 0 },
            Frame::Request {
                id: 4,
                pixels: vec![0.5; 8].into(),
                model: mid("tenant-a"),
                trace: 0xdead_beef_cafe_f00d,
            },
            // a traced request for the *default* model still encodes the
            // model field (append-only: trace comes after it)
            Frame::Request { id: 5, pixels: vec![].into(), model: ModelId::DEFAULT, trace: 17 },
            Frame::Response {
                id: 9,
                label: 3,
                latency_us: 1234,
                cost: WireCost {
                    energy_fj: 1.5e6,
                    latency_ps: 987_654,
                    programs: 42,
                    stationary_hits: 2326,
                },
                logits: vec![-0.5, 0.5, 1e-7].into(),
                trace: 0,
            },
            Frame::Response {
                id: 10,
                label: 1,
                latency_us: 77,
                cost: WireCost {
                    energy_fj: 2.0,
                    latency_ps: 1,
                    programs: 0,
                    stationary_hits: 0,
                },
                logits: vec![].into(),
                trace: 0xdead_beef_cafe_f00d,
            },
            Frame::Rejected { id: 11, retry_after_us: 500, reason: "server at capacity".into() },
            Frame::Rejected { id: 0, retry_after_us: 0, reason: String::new() },
            Frame::Error { id: 13, reason: "worker died".into() },
            Frame::Info {
                in_dim: 64,
                out_dim: 10,
                max_batch: 8,
                backend: "calibrated".into(),
                models: vec![],
            },
            Frame::Info {
                in_dim: 64,
                out_dim: 10,
                max_batch: 8,
                backend: "native".into(),
                models: vec!["tenant-a".into(), "tenant-b".into()],
            },
            Frame::LoadModel { model: mid("m1"), dir: "/tmp/artifacts-m1".into() },
            Frame::RetireModel { model: mid("m1") },
            Frame::AdminOk { model: mid("m1") },
            Frame::GetStats,
            Frame::Stats(Box::default()),
            Frame::Stats(Box::new(StatsPayload {
                server: Some(crate::coordinator::metrics::sample_snapshot()),
                router: None,
                backends: vec![],
            })),
            Frame::Stats(Box::new(StatsPayload {
                server: None,
                router: Some(crate::coordinator::metrics::sample_router_snapshot()),
                backends: vec![
                    ("127.0.0.1:7071".into(), crate::coordinator::metrics::sample_snapshot()),
                    ("127.0.0.1:7072".into(), crate::coordinator::metrics::sample_snapshot()),
                ],
            })),
            Frame::DumpTrace,
            Frame::Trace { json: String::new() },
            Frame::Trace { json: "{\"traceEvents\":[]}".repeat(200) },
        ];
        for f in frames {
            assert_eq!(roundtrip(f.clone()), f);
        }
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello).unwrap();
        let req = Frame::Request {
            id: 1,
            pixels: vec![0.5; 64].into(),
            model: ModelId::DEFAULT,
            trace: 0,
        };
        write_frame(&mut buf, &req).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(Frame::Hello));
        match read_frame(&mut r).unwrap() {
            Some(Frame::Request { id: 1, pixels, model, trace: 0 }) => {
                assert_eq!(pixels.len(), 64);
                assert!(model.is_default());
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF after last frame");
    }

    #[test]
    fn clean_eof_is_none_but_midframe_eof_is_error() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none());
        // a truncated header
        let mut short: &[u8] = &[b'L', b'C', VERSION];
        assert!(read_frame(&mut short).is_err());
        // a full header promising more payload than the stream holds
        let mut buf = Vec::new();
        let req = Frame::Request {
            id: 1,
            pixels: vec![0.5; 16].into(),
            model: ModelId::DEFAULT,
            trace: 0,
        };
        write_frame(&mut buf, &req).unwrap();
        buf.truncate(buf.len() - 3);
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn bad_magic_version_type_and_oversize_are_rejected() {
        let mut ok = Vec::new();
        write_frame(&mut ok, &Frame::Hello).unwrap();

        let mut bad_magic = ok.clone();
        bad_magic[0] = b'X';
        assert!(read_frame(&mut &bad_magic[..]).is_err());

        // a different *major* nibble is a hard error...
        let mut bad_major = ok.clone();
        bad_major[2] = ((MAJOR + 1) << 4) | MINOR;
        let err = read_frame(&mut &bad_major[..]).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // ...as is minor 0 (no such protocol was ever spoken)
        let mut bad_minor = ok.clone();
        bad_minor[2] = MAJOR << 4;
        assert!(read_frame(&mut &bad_minor[..]).is_err());

        let mut bad_type = ok.clone();
        bad_type[3] = 0x7f;
        assert!(read_frame(&mut &bad_type[..]).is_err());

        let mut oversize = ok;
        oversize[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_frame(&mut &oversize[..]).is_err());
    }

    #[test]
    fn v01_requests_decode_to_the_default_model_and_stay_strict() {
        // a minor-1 request carries no model field and decodes to the
        // default model — backward compatibility for old clients
        let mut buf = Vec::new();
        let req = Frame::Request {
            id: 5,
            pixels: vec![1.0, 2.0].into(),
            model: ModelId::DEFAULT,
            trace: 0,
        };
        write_frame(&mut buf, &req).unwrap();
        buf[2] = (MAJOR << 4) | 1; // relabel as a v0.1 frame (same bytes)
        match read_frame(&mut &buf[..]).unwrap() {
            Some(Frame::Request { id: 5, pixels, model, trace: 0 }) => {
                assert_eq!(pixels.len(), 2);
                assert!(model.is_default());
            }
            other => panic!("unexpected {other:?}"),
        }
        // ...but a v0.1 frame is still decoded strictly: trailing bytes
        // (here: what would be a minor-2 model field) are an error
        let mut tagged = Vec::new();
        let req =
            Frame::Request { id: 5, pixels: vec![1.0, 2.0].into(), model: mid("a"), trace: 0 };
        write_frame(&mut tagged, &req).unwrap();
        tagged[2] = (MAJOR << 4) | 1;
        assert!(read_frame(&mut &tagged[..]).is_err());
    }

    #[test]
    fn v02_frames_decode_traceless_and_stay_strict() {
        // an untraced v0.3 request is byte-identical to a v0.2 one, so a
        // relabeled frame decodes cleanly with trace 0 — v0.2 clients
        // keep working unchanged
        let mut buf = Vec::new();
        let req =
            Frame::Request { id: 6, pixels: vec![1.0].into(), model: mid("tenant-a"), trace: 0 };
        write_frame(&mut buf, &req).unwrap();
        buf[2] = (MAJOR << 4) | 2;
        match read_frame(&mut &buf[..]).unwrap() {
            Some(Frame::Request { id: 6, pixels, model, trace: 0 }) => {
                assert_eq!(pixels.len(), 1);
                assert_eq!(model, mid("tenant-a"));
            }
            other => panic!("unexpected {other:?}"),
        }
        // ...but a frame *claiming* v0.2 while carrying the minor-3
        // trace bytes is rejected strictly (same rule minor 2 applied
        // to minor-1 frames with model bytes)
        let mut traced = Vec::new();
        let req =
            Frame::Request { id: 6, pixels: vec![1.0].into(), model: mid("tenant-a"), trace: 9 };
        write_frame(&mut traced, &req).unwrap();
        traced[2] = (MAJOR << 4) | 2;
        assert!(read_frame(&mut &traced[..]).is_err());

        // the same pair for responses
        let resp = Frame::Response {
            id: 8,
            label: 0,
            latency_us: 10,
            cost: WireCost { energy_fj: 0.0, latency_ps: 0, programs: 0, stationary_hits: 0 },
            logits: vec![0.25].into(),
            trace: 0,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &resp).unwrap();
        buf[2] = (MAJOR << 4) | 2;
        match read_frame(&mut &buf[..]).unwrap() {
            Some(Frame::Response { id: 8, trace: 0, .. }) => {}
            other => panic!("unexpected {other:?}"),
        }
        let traced_resp = Frame::Response {
            id: 8,
            label: 0,
            latency_us: 10,
            cost: WireCost { energy_fj: 0.0, latency_ps: 0, programs: 0, stationary_hits: 0 },
            logits: vec![0.25].into(),
            trace: 9,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &traced_resp).unwrap();
        buf[2] = (MAJOR << 4) | 2;
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn traced_default_model_requests_keep_the_append_only_layout() {
        // trace != 0 forces the earlier optional model field onto the
        // wire even for the default model: header + id + count + model
        // length byte + 8 trace bytes
        let f = Frame::Request { id: 1, pixels: vec![].into(), model: ModelId::DEFAULT, trace: 5 };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        assert_eq!(buf.len(), 8 + 8 + 4 + 1 + 8);
        assert_eq!(roundtrip(f.clone()), f);
        // while an untraced default-model request keeps the bare v0.1
        // layout with no optional fields at all
        let bare =
            Frame::Request { id: 1, pixels: vec![].into(), model: ModelId::DEFAULT, trace: 0 };
        let mut buf = Vec::new();
        write_frame(&mut buf, &bare).unwrap();
        assert_eq!(buf.len(), 8 + 8 + 4);
    }

    #[test]
    fn higher_minor_frames_with_trailing_unknown_bytes_are_accepted() {
        // the forward-compat rule from the crate docs' `## Wire
        // protocol`: a v-next *minor* may append fields we don't know;
        // decode the fields we do know and tolerate the rest
        let next = (MAJOR << 4) | (MINOR + 1);

        let mut hello = Vec::new();
        write_frame(&mut hello, &Frame::Hello).unwrap();
        hello[2] = next;
        hello[4] = 3; // claim 3 payload bytes of future fields
        hello.extend_from_slice(&[0xde, 0xad, 0xbf]);
        assert_eq!(read_frame(&mut &hello[..]).unwrap(), Some(Frame::Hello));

        let mut info = Vec::new();
        let f = Frame::Info {
            in_dim: 64,
            out_dim: 10,
            max_batch: 8,
            backend: "native".into(),
            models: vec!["tenant-a".into()],
        };
        write_frame(&mut info, &f).unwrap();
        info[2] = next;
        let len = u32::from_le_bytes(info[4..8].try_into().unwrap()) + 5;
        info[4..8].copy_from_slice(&len.to_le_bytes());
        info.extend_from_slice(&[1, 2, 3, 4, 5]);
        assert_eq!(read_frame(&mut &info[..]).unwrap(), Some(f));

        // same-minor frames stay strict
        let mut strict = Vec::new();
        write_frame(&mut strict, &Frame::Hello).unwrap();
        strict[4] = 2;
        strict.extend_from_slice(&[0, 0]);
        assert!(read_frame(&mut &strict[..]).is_err());
    }

    #[test]
    fn inconsistent_counts_and_trailing_bytes_are_rejected() {
        // request whose pixel count disagrees with the payload length
        let mut buf = Vec::new();
        let req = Frame::Request {
            id: 1,
            pixels: vec![1.0, 2.0].into(),
            model: ModelId::DEFAULT,
            trace: 0,
        };
        write_frame(&mut buf, &req).unwrap();
        // corrupt the count (first payload field after the 8-byte id)
        buf[8 + 8] = 9;
        assert!(read_frame(&mut &buf[..]).is_err());

        // hello with trailing payload bytes
        let mut hello = Vec::new();
        write_frame(&mut hello, &Frame::Hello).unwrap();
        hello[4] = 2; // claim 2 payload bytes
        hello.extend_from_slice(&[0, 0]);
        assert!(read_frame(&mut &hello[..]).is_err());
    }

    #[test]
    fn model_ids_are_bounded_and_inline() {
        assert!(ModelId::new(&"x".repeat(MAX_MODEL_ID)).is_ok());
        assert!(ModelId::new(&"x".repeat(MAX_MODEL_ID + 1)).is_err());
        assert!(ModelId::new("").unwrap().is_default());
        assert_eq!(mid("tenant-a").as_str(), "tenant-a");
        assert_eq!(mid("tenant-a"), mid("tenant-a"));
        assert_ne!(mid("tenant-a"), mid("tenant-b"));
        // a wire model id longer than the cap is rejected at decode
        let mut buf = Vec::new();
        let req = Frame::Request { id: 1, pixels: vec![].into(), model: mid("a"), trace: 0 };
        write_frame(&mut buf, &req).unwrap();
        let model_len_at = 8 + 8 + 4; // header + id + pixel count
        buf[model_len_at] = (MAX_MODEL_ID + 1) as u8;
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn long_reasons_truncate_on_char_boundary() {
        let reason = "é".repeat(MAX_REASON); // 2 bytes per char
        let f = roundtrip(Frame::Error { id: 1, reason });
        match f {
            Frame::Error { reason, .. } => {
                assert!(reason.len() <= MAX_REASON);
                assert!(!reason.is_empty());
                assert!(reason.chars().all(|c| c == 'é'), "no split surrogate");
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
