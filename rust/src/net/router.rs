//! The front-tier router (`repro route`): multi-process shard-out of
//! the wire protocol.
//!
//! [`RouterServer`] speaks the same versioned protocol on both sides.
//! Clients connect to it exactly as they would to a single
//! [`super::server::NetServer`] (Hello → Info handshake, pipelined
//! `Request`/`Response` frames); behind it, N `repro serve --listen`
//! backends each hold one multiplexed **link** the router demultiplexes
//! replies from. Per-process lane sharding (`batcher.shards`) scales one
//! process; this tier scales across processes and hosts.
//!
//! **Dispatch policies** (`router.policy`):
//! * `hash` (default) — consistent hash of the client connection id
//!   over a [`HashRing`] of `router.vnodes` virtual nodes per backend.
//!   One connection's requests stick to one backend, keeping that
//!   backend's batcher lanes and weight-stationary fabric warm, and
//!   removing a backend remaps only ~1/N of connections (the ring walk
//!   skips dead backends, so the minimal-disruption invariant holds
//!   under failure too — `tests/router_properties.rs`).
//! * `least-outstanding` — the connected backend with the fewest
//!   in-flight requests wins: best spreading, no affinity.
//!
//! **Health / drain state machine.** Each backend is `connected` or
//! `quarantined`. A link failure (read error, EOF, write failure, or a
//! connection-scoped `Error` frame) moves the backend to quarantined:
//! the socket closes, and **every in-flight request parked on that link
//! resolves immediately with a retryable `Rejected` frame** (hint
//! [`FAILOVER_RETRY_US`] ≥ 1 — hint-honoring clients like `repro
//! loadgen --retry` re-send; nothing ever hangs). A prober thread then
//! re-connects with exponential backoff (`router.probe_ms` doubling up
//! to `router.max_backoff_ms`); a successful Hello/Info handshake
//! (through [`crate::net::client::handshake`], the one implementation
//! in the crate) — which must agree with the fleet's model dimensions
//! *and model set* — promotes the fresh connection to the live link and
//! clears the quarantine.
//!
//! **Multi-tenant routing.** Requests carry their model id through
//! unchanged (re-encoded on every forward and failover hop); the probe's
//! model-set agreement check is what makes that sound — a tagged
//! request is servable wherever the policy lands it. `LoadModel`/
//! `RetireModel` admin frames are *not* routable (they would apply to an
//! arbitrary subset of the fleet); the router answers them with an
//! `Error` — administer each backend directly.
//!
//! **Fleet-wide admission rule.** A backend answering `Rejected` does
//! not end the request: the router remembers the smallest
//! `retry_after_us` hint seen and re-dispatches to the next connected
//! backend it has not tried. Only when *all* backends rejected (or none
//! are connected) does the client see `Rejected` — carrying that
//! minimum hint, so fleet-wide backpressure stays exactly as meaningful
//! as single-process backpressure.
//!
//! **Observability.** A client's `GetStats` fans out: the router
//! scrapes every connected backend over a short-lived admin connection
//! and answers with its own routing snapshot plus one
//! [`crate::coordinator::MetricsSnapshot`] per backend. `DumpTrace`
//! answers with this process's local flight-recorder dump only —
//! `repro trace` merges router and backend dumps client-side. Requests
//! arriving untraced are sampled *here*, at the fleet's front door;
//! the id rides the protocol's v0.3 trailing field to the backend, so
//! both processes' spans stitch into one timeline by trace id.
//!
//! Ordering audit: every atomic here is Relaxed by design — connection
//! counters, monitoring counters, and cooperative flags (`stopping`,
//! `connected`) whose consumers tolerate staleness by construction
//! (a stale `connected` just costs one extra tried-and-failed dispatch
//! hop). Links are published via `Mutex<Option<Arc<Link>>>`, never
//! through an atomic.

use super::client::{handshake, NetClient, ServerInfo};
use super::protocol::{
    read_frame_with, write_frame, write_frame_with, Frame, ModelId, StatsPayload,
};
use super::server::WRITE_TIMEOUT;
use crate::config::{DispatchPolicy, RouterConfig, TraceConfig};
use crate::coordinator::RouterMetrics;
use crate::util::trace::{FlightRecorder, Stage};
use crate::util::{queue, PooledVec};
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write as _};
use std::net::{
    IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs,
};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Retry hint (µs) on frames that resolve requests lost to a dying
/// backend or to router shutdown. Always ≥ 1, so hint-honoring clients
/// treat the loss as retryable backpressure rather than a hard error.
pub const FAILOVER_RETRY_US: u64 = 2_000;

/// Retry hint (µs) when no backend is connected at all — longer than
/// [`FAILOVER_RETRY_US`] because recovery needs a health probe to
/// succeed first.
pub const NO_BACKEND_RETRY_US: u64 = 10_000;

/// Backend connect timeout during a health probe.
const PROBE_CONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// Backend handshake read timeout during a health probe (cleared once
/// the link is promoted — demux reads then block indefinitely).
const PROBE_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Ring-point salt: vnode points are `mix64(SALT ^ ((backend << 32) |
/// vnode))`. Without the salt, backend 0's low-vnode points are exactly
/// `mix64(small)` — i.e. the hashes of small sequential keys — and
/// every such key would structurally collide onto backend 0.
const RING_SALT: u64 = 0x5249_4E47_5F50_4E54; // b"RING_PNT"

/// SplitMix64 finalizer: a cheap, well-mixed u64 → u64 permutation
/// (the same finalizer [`crate::util::rng::Rng`] uses per step).
pub fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Consistent-hash ring: `vnodes` pseudo-random points per backend on
/// the u64 circle; a key belongs to the first point clockwise from its
/// hash. Dead backends are skipped by walking further clockwise, which
/// is exactly the minimal-disruption remap (keys owned by live backends
/// do not move).
pub struct HashRing {
    /// (ring point, backend index), sorted by point.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    pub fn new(backends: usize, vnodes: usize) -> HashRing {
        let mut points = Vec::new(); // lint: allow(alloc): construction, not a request path
        for b in 0..backends {
            for v in 0..vnodes {
                let point = mix64(RING_SALT ^ (((b as u64) << 32) | v as u64));
                points.push((point, b));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    /// First backend clockwise from `key_hash` for which `alive`
    /// returns true; `None` when none is. Pass the key through
    /// [`mix64`] first — raw small integers are not uniform on the
    /// circle.
    pub fn pick_where(&self, key_hash: u64, alive: impl Fn(usize) -> bool) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let start = self.points.partition_point(|&(p, _)| p < key_hash);
        let n = self.points.len();
        for off in 0..n {
            let (_, b) = self.points[(start + off) % n];
            if alive(b) {
                return Some(b);
            }
        }
        None
    }
}

/// The backend with the smallest load among those `alive` (first wins
/// ties); `None` when none is alive. Pure so the property tests can pin
/// it: a quarantined (non-alive) backend is never picked, whatever its
/// load.
pub fn pick_least_outstanding(loads: &[u64], alive: impl Fn(usize) -> bool) -> Option<usize> {
    let mut best: Option<(u64, usize)> = None;
    for (i, &load) in loads.iter().enumerate() {
        if !alive(i) {
            continue;
        }
        match best {
            Some((b, _)) if b <= load => {}
            _ => best = Some((load, i)),
        }
    }
    best.map(|(_, i)| i)
}

/// One request in flight to a backend, parked in that link's inflight
/// map until its reply (or the link's death) resolves it.
struct Route {
    /// The client connection's writer queue.
    client_tx: queue::Sender<Frame>,
    /// The client's wire id, echoed on whatever frame resolves this.
    client_id: u64,
    /// Client connection id — the hash-policy key.
    conn_key: u64,
    /// Retained so a `Rejected` backend can be failed over to the next.
    pixels: PooledVec<f32>,
    /// Which model the request addressed (re-encoded on every forward;
    /// inline `Copy`, so failover never allocates for it).
    model: ModelId,
    /// Bitmask of backends already tried for this request.
    tried: u64,
    /// Smallest `retry_after_us` seen from a rejecting backend.
    min_hint: u64,
    /// Trace id the request entered the fleet with (`0` = untraced);
    /// re-encoded on every forward and failover hop, like the model.
    trace: u64,
}

struct LinkWriter {
    w: BufWriter<TcpStream>,
    /// Reused frame-encode scratch (steady-state forwards allocate only
    /// the pooled pixel copy).
    scratch: Vec<u8>,
}

struct Inflight {
    /// Set (under this mutex) when the link dies: dispatch must not
    /// insert past the failover drain, or the route would leak.
    closed: bool,
    map: HashMap<u64, Route>,
}

/// One live multiplexed connection to a backend. Replaced wholesale on
/// reconnect; `gen` guards against a stale failure report tearing down
/// the replacement.
struct Link {
    gen: u64,
    /// For `Shutdown::Both` on failure (reads and writes both unblock).
    stream: TcpStream,
    writer: Mutex<LinkWriter>,
    inflight: Mutex<Inflight>,
    /// Backend-side wire ids (independent of client wire ids).
    next_id: AtomicU64,
}

struct Backend {
    addr: String,
    link: Mutex<Option<Arc<Link>>>,
    connected: AtomicBool,
    /// In-flight requests on this backend (least-outstanding's load).
    outstanding: AtomicU64,
    /// Consecutive probe/link failures (drives the backoff exponent).
    failures: AtomicU64,
    /// Earliest next probe, ms since router start.
    next_probe_at_ms: AtomicU64,
    /// True while quarantined; the transition edges feed the
    /// quarantine/recovery counters exactly once each.
    was_quarantined: AtomicBool,
    /// Link generation counter.
    gen: AtomicU64,
}

/// One live client connection's handles.
struct Conn {
    stream: TcpStream,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

struct RouterShared {
    policy: DispatchPolicy,
    ring: HashRing,
    probe_ms: u64,
    max_backoff_ms: u64,
    started: Instant,
    /// Fleet model info from the first successful probe; later probes
    /// must agree on dimensions. Served to clients on Hello.
    info: Mutex<Option<ServerInfo>>,
    backends: Vec<Backend>,
    metrics: Arc<RouterMetrics>,
    /// Front-door flight recorder: ingress sampling plus this process's
    /// spans for routed requests ([`crate::util::trace`]).
    recorder: Arc<FlightRecorder>,
    stopping: AtomicBool,
    live: AtomicUsize,
    next_conn: AtomicU64,
    conns: Mutex<Vec<Conn>>,
    /// Demux-thread handles (a failed link's demux thread can't join
    /// itself; shutdown joins them all here).
    graveyard: Mutex<Vec<JoinHandle<()>>>,
}

fn now_ms(shared: &RouterShared) -> u64 {
    shared.started.elapsed().as_millis() as u64
}

/// The router front tier. Bind with [`RouterServer::bind`]; shut down
/// with [`RouterServer::shutdown`] (resolves any parked request with a
/// retryable frame — never hangs a client).
pub struct RouterServer {
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
    shared: Arc<RouterShared>,
}

impl RouterServer {
    /// Bind the front tier and probe every backend once synchronously
    /// (unreachable backends start quarantined on the prober's backoff
    /// schedule — the router comes up even with the whole fleet down).
    /// Uses default flight-recorder settings; `repro route` passes the
    /// config's `trace.*` keys through [`bind_traced`](Self::bind_traced).
    pub fn bind(cfg: &RouterConfig) -> Result<RouterServer> {
        RouterServer::bind_traced(cfg, &TraceConfig::default())
    }

    /// [`bind`](Self::bind) with explicit flight-recorder settings
    /// (ring capacity and ingress sampling rate).
    pub fn bind_traced(cfg: &RouterConfig, trace: &TraceConfig) -> Result<RouterServer> {
        ensure!(!cfg.backends.is_empty(), "router needs at least one backend");
        ensure!(cfg.backends.len() <= 64, "router supports at most 64 backends");
        ensure!(cfg.vnodes >= 1, "router.vnodes must be >= 1");
        ensure!(cfg.max_connections >= 1, "need at least one connection slot");
        ensure!(cfg.probe_ms >= 1, "router.probe_ms must be >= 1");
        let listen = if cfg.listen.is_empty() { "127.0.0.1:0" } else { cfg.listen.as_str() };
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding router.listen {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        // lint: allow(alloc): construction, not a request path.
        let mut backends = Vec::with_capacity(cfg.backends.len());
        for addr in &cfg.backends {
            backends.push(Backend {
                addr: addr.clone(),
                link: Mutex::new(None),
                connected: AtomicBool::new(false),
                outstanding: AtomicU64::new(0),
                failures: AtomicU64::new(0),
                next_probe_at_ms: AtomicU64::new(0),
                was_quarantined: AtomicBool::new(false),
                gen: AtomicU64::new(0),
            });
        }
        let shared = Arc::new(RouterShared {
            policy: cfg.policy,
            ring: HashRing::new(cfg.backends.len(), cfg.vnodes),
            probe_ms: cfg.probe_ms,
            max_backoff_ms: cfg.max_backoff_ms.max(cfg.probe_ms),
            started: Instant::now(),
            info: Mutex::new(None),
            backends,
            metrics: Arc::new(RouterMetrics::new(&cfg.backends)),
            recorder: FlightRecorder::new("router", trace.ring_capacity, trace.sample_every),
            stopping: AtomicBool::new(false),
            live: AtomicUsize::new(0),
            next_conn: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
            graveyard: Mutex::new(Vec::new()),
        });
        for idx in 0..shared.backends.len() {
            if let Err(e) = probe_backend(&shared, idx) {
                note_probe_failure(&shared, idx);
                eprintln!(
                    "router: backend {} unavailable at start: {e:#}",
                    shared.backends[idx].addr
                );
            }
        }
        let prober = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("luna-router-prober".into())
                .spawn(move || prober_main(shared))
                .context("spawning prober thread")?
        };
        let accept = {
            let shared = shared.clone();
            let max_connections = cfg.max_connections;
            std::thread::Builder::new()
                .name("luna-router-accept".into())
                .spawn(move || accept_loop(listener, shared, max_connections))
                .context("spawning accept thread")?
        };
        Ok(RouterServer { addr, accept: Some(accept), prober: Some(prober), shared })
    }

    /// The actually-bound front-tier address (resolves port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Client connections currently open.
    pub fn live_connections(&self) -> usize {
        self.shared.live.load(Ordering::Relaxed)
    }

    /// Per-backend routed/rejected/failed-over/quarantine counters.
    pub fn metrics(&self) -> Arc<RouterMetrics> {
        self.shared.metrics.clone()
    }

    /// Whether backend `idx` currently holds a live link.
    pub fn backend_connected(&self, idx: usize) -> bool {
        self.shared.backends[idx].connected.load(Ordering::Relaxed)
    }

    /// Drain and stop: no new connections or probes, client read halves
    /// close (no new requests), in-flight replies flush, anything still
    /// parked on a backend link resolves with a retryable `Rejected`
    /// frame. No client is ever left waiting.
    pub fn shutdown(mut self) {
        self.shared.stopping.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(wake_addr(self.addr));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        if let Some(p) = self.prober.take() {
            let _ = p.join();
        }
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        // lint: allow(alloc): shutdown path, never per-request.
        let mut writers = Vec::with_capacity(conns.len());
        for c in conns {
            let _ = c.reader.join();
            writers.push(c.writer);
        }
        // Readers are gone, so no new dispatches from clients; resolve
        // whatever is still parked, closing every link (their demux
        // threads exit on the socket shutdown).
        close_all_links(&self.shared, "router shutting down");
        // Writers exit once every route's sender clone is resolved and
        // the queue drains — i.e. after every client got its answer.
        for w in writers {
            let _ = w.join();
        }
        let demux = std::mem::take(&mut *self.shared.graveyard.lock().unwrap());
        for d in demux {
            let _ = d.join();
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        // shutdown() consumed self in the normal path; this covers
        // early drops (error unwinding) so the accept/prober/demux
        // threads do not linger. Client connection threads exit when
        // their peers disconnect.
        if self.accept.is_some() || self.prober.is_some() {
            self.shared.stopping.store(true, Ordering::Relaxed);
            let _ = TcpStream::connect(wake_addr(self.addr));
            if let Some(a) = self.accept.take() {
                let _ = a.join();
            }
            if let Some(p) = self.prober.take() {
                let _ = p.join();
            }
            close_all_links(&self.shared, "router dropped");
        }
    }
}

fn wake_addr(bound: SocketAddr) -> SocketAddr {
    let ip = match bound.ip() {
        ip if !ip.is_unspecified() => ip,
        IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
    };
    SocketAddr::new(ip, bound.port())
}

// ---------------------------------------------------------------------
// Backend side: probing, links, demux, failover
// ---------------------------------------------------------------------

/// Connect + handshake one backend and promote the connection to its
/// live link. The Info must agree with the fleet's model dimensions.
fn probe_backend(shared: &Arc<RouterShared>, idx: usize) -> Result<()> {
    let backend = &shared.backends[idx];
    let sa = backend
        .addr
        .to_socket_addrs()
        .with_context(|| format!("resolving backend {}", backend.addr))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("backend {} resolves to nothing", backend.addr))?;
    let stream = TcpStream::connect_timeout(&sa, PROBE_CONNECT_TIMEOUT)
        .with_context(|| format!("connecting backend {}", backend.addr))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let _ = stream.set_read_timeout(Some(PROBE_READ_TIMEOUT));
    let read_half = stream.try_clone().context("cloning backend stream")?;
    let write_half = stream.try_clone().context("cloning backend stream")?;
    let mut w = BufWriter::new(write_half);
    let mut r = BufReader::new(read_half);
    // single source of truth for the Hello→Info exchange — the probe
    // speaks the handshake through the same helper the client does, so
    // version negotiation has exactly one implementation
    let info = handshake(&mut r, &mut w)
        .with_context(|| format!("handshaking backend {}", backend.addr))?;
    {
        let mut agg = shared.info.lock().unwrap();
        match agg.as_ref() {
            Some(have) => {
                ensure!(
                    have.in_dim == info.in_dim && have.out_dim == info.out_dim,
                    "backend {} serves a {}→{} model, fleet serves {}→{}",
                    backend.addr,
                    info.in_dim,
                    info.out_dim,
                    have.in_dim,
                    have.out_dim
                );
                // fleet model-set check: a model-tagged request must be
                // servable wherever the policy lands it, so every
                // backend has to agree on the model list. Apply hot
                // swaps fleet-wide before a backend reconnects.
                ensure!(
                    have.models == info.models,
                    "backend {} serves models {:?}, fleet serves {:?}",
                    backend.addr,
                    info.models,
                    have.models
                );
            }
            None => *agg = Some(info),
        }
    }
    // Handshake timeouts off: demux reads block until traffic or death.
    let _ = stream.set_read_timeout(None);
    let gen = backend.gen.fetch_add(1, Ordering::Relaxed) + 1;
    let link = Arc::new(Link {
        gen,
        stream,
        writer: Mutex::new(LinkWriter { w, scratch: Vec::new() }),
        inflight: Mutex::new(Inflight { closed: false, map: HashMap::new() }),
        next_id: AtomicU64::new(0),
    });
    let demux = {
        let shared = shared.clone();
        let link = link.clone();
        std::thread::Builder::new()
            .name(format!("luna-router-demux-{idx}"))
            .spawn(move || demux_main(shared, idx, link, r))
            .context("spawning backend demux thread")?
    };
    shared.graveyard.lock().unwrap().push(demux);
    *backend.link.lock().unwrap() = Some(link);
    backend.connected.store(true, Ordering::Relaxed);
    backend.failures.store(0, Ordering::Relaxed);
    if backend.was_quarantined.swap(false, Ordering::Relaxed) {
        shared.metrics.record_recovery(idx);
    }
    Ok(())
}

/// Schedule the next probe with exponential backoff and count the
/// healthy→quarantined edge (once per outage).
fn note_probe_failure(shared: &Arc<RouterShared>, idx: usize) {
    let backend = &shared.backends[idx];
    let fails = backend.failures.fetch_add(1, Ordering::Relaxed) + 1;
    let backoff = shared
        .probe_ms
        .saturating_mul(1u64 << (fails - 1).min(16))
        .min(shared.max_backoff_ms);
    backend.next_probe_at_ms.store(now_ms(shared).saturating_add(backoff), Ordering::Relaxed);
    if !backend.was_quarantined.swap(true, Ordering::Relaxed) {
        shared.metrics.record_quarantine(idx);
    }
}

fn prober_main(shared: Arc<RouterShared>) {
    let tick = Duration::from_millis(shared.probe_ms.clamp(5, 50));
    loop {
        std::thread::sleep(tick);
        if shared.stopping.load(Ordering::Relaxed) {
            return;
        }
        let now = now_ms(&shared);
        for idx in 0..shared.backends.len() {
            let backend = &shared.backends[idx];
            if backend.connected.load(Ordering::Relaxed)
                || now < backend.next_probe_at_ms.load(Ordering::Relaxed)
            {
                continue;
            }
            if shared.stopping.load(Ordering::Relaxed) {
                return;
            }
            if probe_backend(&shared, idx).is_err() {
                note_probe_failure(&shared, idx);
            }
        }
    }
}

/// Tear a dead link down (generation-guarded: a stale failure report
/// never kills a replacement link) and resolve every request parked on
/// it with a retryable `Rejected` frame — the no-request-hangs
/// guarantee. During shutdown the teardown still resolves routes but
/// skips the quarantine bookkeeping.
fn fail_link(shared: &Arc<RouterShared>, idx: usize, gen: u64, why: &str) {
    let backend = &shared.backends[idx];
    let link = {
        let mut guard = backend.link.lock().unwrap();
        match guard.as_ref() {
            Some(l) if l.gen == gen => guard.take(),
            _ => return,
        }
    };
    let Some(link) = link else { return };
    backend.connected.store(false, Ordering::Relaxed);
    if !shared.stopping.load(Ordering::Relaxed) {
        note_probe_failure(shared, idx);
        eprintln!("router: backend {} quarantined: {why}", backend.addr);
    }
    let _ = link.stream.shutdown(Shutdown::Both);
    // Drain under the inflight lock (closed stops racing inserts), then
    // resolve outside it — sends must not run under the map lock.
    let drained: Vec<(u64, Route)> = {
        let mut inf = link.inflight.lock().unwrap();
        inf.closed = true;
        inf.map.drain().collect()
    };
    for (_, route) in drained {
        backend.outstanding.fetch_sub(1, Ordering::Relaxed);
        shared.metrics.record_failed_over(idx);
        let _ = route.client_tx.send(Frame::Rejected {
            id: route.client_id,
            retry_after_us: FAILOVER_RETRY_US,
            reason: format!("backend {} lost mid-request ({why}) — safe to retry", backend.addr),
        });
    }
}

/// Close every live link (shutdown path).
fn close_all_links(shared: &Arc<RouterShared>, why: &str) {
    for idx in 0..shared.backends.len() {
        let gen = { shared.backends[idx].link.lock().unwrap().as_ref().map(|l| l.gen) };
        if let Some(gen) = gen {
            fail_link(shared, idx, gen, why);
        }
    }
}

fn take_route(link: &Link, id: u64) -> Option<Route> {
    link.inflight.lock().unwrap().map.remove(&id)
}

/// Per-link reply pump: demultiplex backend frames back onto the owning
/// client connections' writer queues. Exits by failing the link.
fn demux_main(shared: Arc<RouterShared>, idx: usize, link: Arc<Link>, mut r: BufReader<TcpStream>) {
    let mut scratch = Vec::new();
    loop {
        let frame = match read_frame_with(&mut r, &mut scratch) {
            Ok(Some(f)) => f,
            Ok(None) => return fail_link(&shared, idx, link.gen, "connection closed"),
            Err(e) => return fail_link(&shared, idx, link.gen, &format!("{e:#}")),
        };
        match frame {
            Frame::Response { id, label, latency_us, cost, logits, trace } => {
                if let Some(route) = take_route(&link, id) {
                    shared.backends[idx].outstanding.fetch_sub(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    let _ = route.client_tx.send(Frame::Response {
                        id: route.client_id,
                        label,
                        latency_us,
                        cost,
                        logits,
                        trace,
                    });
                    // the router's own write-back: reply forwarded onto
                    // the client connection's writer queue
                    shared.recorder.record(trace, Stage::WriteBack, t0, Instant::now());
                }
            }
            Frame::Rejected { id, retry_after_us, .. } => {
                if let Some(mut route) = take_route(&link, id) {
                    shared.backends[idx].outstanding.fetch_sub(1, Ordering::Relaxed);
                    shared.metrics.record_backend_rejection(idx);
                    // fleet admission rule: remember the smallest hint,
                    // try the remaining backends before telling the
                    // client anything
                    route.min_hint = route.min_hint.min(retry_after_us.max(1));
                    dispatch(&shared, route);
                }
            }
            Frame::Error { id, reason } => {
                if id == 0 {
                    // connection-scoped backend error: link poisoned
                    let why = format!("backend error: {reason}");
                    return fail_link(&shared, idx, link.gen, &why);
                }
                if let Some(route) = take_route(&link, id) {
                    shared.backends[idx].outstanding.fetch_sub(1, Ordering::Relaxed);
                    let _ = route.client_tx.send(Frame::Error { id: route.client_id, reason });
                }
            }
            other => {
                let why = format!("unexpected backend frame {other:?}");
                return fail_link(&shared, idx, link.gen, &why);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Client side: accept loop, per-connection reader/writer, dispatch
// ---------------------------------------------------------------------

/// Route one request: pick a backend by policy (skipping quarantined
/// and already-tried ones), park the route on its link, forward the
/// request. Loops on rejection/write failure until a backend takes it
/// or every backend has been tried — then the client gets a `Rejected`
/// carrying the minimum hint seen (fleet admission aggregation).
fn dispatch(shared: &Arc<RouterShared>, mut route: Route) {
    loop {
        let idx = {
            let alive = |b: usize| {
                route.tried & (1u64 << b) == 0
                    && shared.backends[b].connected.load(Ordering::Relaxed)
            };
            match shared.policy {
                DispatchPolicy::Hash => shared.ring.pick_where(mix64(route.conn_key), &alive),
                DispatchPolicy::LeastOutstanding => {
                    let mut loads = [0u64; 64];
                    for (i, b) in shared.backends.iter().enumerate() {
                        loads[i] = b.outstanding.load(Ordering::Relaxed);
                    }
                    pick_least_outstanding(&loads[..shared.backends.len()], &alive)
                }
            }
        };
        let Some(idx) = idx else {
            let (hint, reason) = if route.tried == 0 {
                (NO_BACKEND_RETRY_US, "no healthy backends behind the router".to_string())
            } else {
                let hint =
                    if route.min_hint == u64::MAX { FAILOVER_RETRY_US } else { route.min_hint };
                (hint, "all backends at capacity".to_string())
            };
            shared.metrics.record_terminal_rejection();
            let _ = route.client_tx.send(Frame::Rejected {
                id: route.client_id,
                retry_after_us: hint,
                reason,
            });
            return;
        };
        route.tried |= 1u64 << idx;
        let link = { shared.backends[idx].link.lock().unwrap().clone() };
        let Some(link) = link else { continue };
        let bid;
        let pixels;
        let model = route.model;
        let trace = route.trace;
        {
            let mut inf = link.inflight.lock().unwrap();
            if inf.closed {
                continue; // raced a failover; the tried bit is set, move on
            }
            bid = link.next_id.fetch_add(1, Ordering::Relaxed);
            pixels = PooledVec::from_slice(&route.pixels);
            inf.map.insert(bid, route);
        }
        shared.backends[idx].outstanding.fetch_add(1, Ordering::Relaxed);
        let wrote = {
            let mut guard = link.writer.lock().unwrap();
            let lw = &mut *guard;
            let frame = Frame::Request { id: bid, pixels, model, trace };
            let sent = write_frame_with(&mut lw.w, &frame, &mut lw.scratch);
            sent.is_ok() && lw.w.flush().is_ok()
        };
        if wrote {
            shared.metrics.record_routed(idx);
            return;
        }
        // Broken link: reclaim the route if the failover drain has not
        // already resolved it, fail the link (resolving its other
        // in-flight requests), and try the next backend.
        let reclaimed = take_route(&link, bid);
        fail_link(shared, idx, link.gen, "write failed");
        match reclaimed {
            Some(r) => {
                shared.backends[idx].outstanding.fetch_sub(1, Ordering::Relaxed);
                route = r;
            }
            None => return, // fail_link's drain already answered the client
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<RouterShared>, max_connections: usize) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.stopping.load(Ordering::Relaxed) {
                    return; // the shutdown wake-up (or a racing client)
                }
                prune_finished(&shared);
                if shared.live.load(Ordering::Relaxed) >= max_connections {
                    reject_connection(stream);
                    continue;
                }
                match spawn_connection(stream, shared.clone()) {
                    Ok(conn) => shared.conns.lock().unwrap().push(conn),
                    Err(e) => eprintln!("router: connection setup failed: {e:#}"),
                }
            }
            Err(e) => {
                if shared.stopping.load(Ordering::Relaxed) {
                    return;
                }
                eprintln!("router: accept error: {e:#}");
            }
        }
    }
}

/// Join and drop registry entries whose threads have exited.
fn prune_finished(shared: &RouterShared) {
    let mut conns = shared.conns.lock().unwrap();
    // lint: allow(alloc): accept-loop housekeeping between connections,
    // never on a request's path.
    let mut kept = Vec::with_capacity(conns.len());
    for c in conns.drain(..) {
        if c.reader.is_finished() && c.writer.is_finished() {
            let _ = c.reader.join();
            let _ = c.writer.join();
        } else {
            kept.push(c);
        }
    }
    *conns = kept;
}

/// Over-capacity turn-away, mirroring the backend front-end's.
fn reject_connection(stream: TcpStream) {
    let mut w = BufWriter::new(&stream);
    let frame =
        Frame::Rejected { id: 0, retry_after_us: 0, reason: "connection limit reached".into() };
    let _ = write_frame(&mut w, &frame);
    let _ = w.flush();
}

fn spawn_connection(stream: TcpStream, shared: Arc<RouterShared>) -> Result<Conn> {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let reader_stream = stream.try_clone().context("cloning stream for reader")?;
    let writer_stream = stream.try_clone().context("cloning stream for writer")?;
    let (tx, rx) = queue::channel::<Frame>();
    shared.live.fetch_add(1, Ordering::Relaxed);
    let writer_shared = shared.clone();
    let writer_spawn = std::thread::Builder::new().name("luna-rt-writer".into()).spawn(move || {
        {
            let mut w = BufWriter::new(&writer_stream);
            // reused across frames, exactly as on the backend front-end
            let mut scratch = Vec::new();
            // Exits when every sender is gone: the reader's plus one
            // clone per route still in flight — i.e. after every
            // request this connection sent has been resolved.
            while let Some(frame) = rx.recv() {
                if write_frame_with(&mut w, &frame, &mut scratch).is_err() || w.flush().is_err() {
                    break;
                }
            }
        }
        let _ = writer_stream.shutdown(Shutdown::Both);
        writer_shared.live.fetch_sub(1, Ordering::Relaxed);
    });
    let writer = match writer_spawn {
        Ok(w) => w,
        Err(e) => {
            shared.live.fetch_sub(1, Ordering::Relaxed);
            return Err(e).context("spawning connection writer");
        }
    };
    let conn_key = shared.next_conn.fetch_add(1, Ordering::Relaxed);
    let reader = std::thread::Builder::new()
        .name("luna-router-reader".into())
        .spawn(move || conn_reader(shared, reader_stream, tx, conn_key))
        .context("spawning connection reader")?;
    Ok(Conn { stream, reader, writer })
}

fn conn_reader(
    shared: Arc<RouterShared>,
    stream: TcpStream,
    tx: queue::Sender<Frame>,
    conn_key: u64,
) {
    let mut r = BufReader::new(&stream);
    let mut scratch = Vec::new();
    loop {
        match read_frame_with(&mut r, &mut scratch) {
            Ok(Some(Frame::Hello)) => {
                let info = { shared.info.lock().unwrap().clone() };
                match info {
                    Some(info) => {
                        let frame = Frame::Info {
                            in_dim: info.in_dim as u32,
                            out_dim: info.out_dim as u32,
                            max_batch: info.max_batch as u32,
                            backend: info.backend,
                            models: info.models,
                        };
                        if tx.send(frame).is_err() {
                            return;
                        }
                    }
                    None => {
                        // No backend has ever handshaken: nothing to
                        // serve and no model info to report.
                        let reason = "router has no healthy backend yet".to_string();
                        let _ = tx.send(Frame::Error { id: 0, reason });
                        return;
                    }
                }
            }
            Ok(Some(Frame::Request { id, pixels, model, trace })) => {
                let t0 = Instant::now();
                // Untraced requests are sampled here, at the fleet's
                // front door; the id rides the wire to the backend so
                // both processes' spans share it. A nonzero incoming id
                // is honored as-is, never reassigned.
                let trace = if trace == 0 { shared.recorder.sample() } else { trace };
                let route = Route {
                    client_tx: tx.clone(),
                    client_id: id,
                    conn_key,
                    pixels,
                    model,
                    tried: 0,
                    min_hint: u64::MAX,
                    trace,
                };
                dispatch(&shared, route);
                shared.recorder.record(trace, Stage::Ingress, t0, Instant::now());
            }
            Ok(Some(Frame::GetStats)) => {
                // Cold admin path: fan a fresh scrape out to every
                // connected backend over a short-lived admin connection
                // (the multiplexed data links only demux request
                // replies), then aggregate under the router snapshot.
                let mut backends = Vec::new(); // lint: allow(alloc): cold admin path
                for b in &shared.backends {
                    if !b.connected.load(Ordering::Relaxed) {
                        continue;
                    }
                    let scraped = NetClient::connect(&b.addr).and_then(|mut c| c.get_stats());
                    if let Ok(stats) = scraped {
                        if let Some(server) = stats.server {
                            backends.push((b.addr.clone(), server));
                        }
                    }
                }
                let stats = StatsPayload {
                    server: None,
                    router: Some(shared.metrics.snapshot()),
                    backends,
                };
                if tx.send(Frame::Stats(Box::new(stats))).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::DumpTrace)) => {
                // local spans only — `repro trace` merges the router's
                // and the backends' dumps client-side
                let json = shared.recorder.dump_json();
                if tx.send(Frame::Trace { json }).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::LoadModel { .. })) | Ok(Some(Frame::RetireModel { .. })) => {
                // Admin frames address one backend's registry; routed,
                // they would apply to an arbitrary subset of the fleet
                // and silently break the model-set agreement the probe
                // enforces. Administer each backend directly.
                let reason =
                    "admin frames are not routable — administer backends directly".to_string();
                let _ = tx.send(Frame::Error { id: 0, reason });
                return;
            }
            Ok(Some(other)) => {
                let reason = format!("unexpected client frame {other:?}");
                let _ = tx.send(Frame::Error { id: 0, reason });
                return;
            }
            Ok(None) => return, // peer hung up cleanly
            Err(e) => {
                let reason = format!("protocol error: {e:#}");
                let _ = tx.send(Frame::Error { id: 0, reason });
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_a_permutation_sample() {
        // distinct inputs → distinct outputs on a decent sample
        let mut seen = std::collections::HashSet::new();
        for x in 0..10_000u64 {
            assert!(seen.insert(mix64(x)));
        }
        // and it actually moves small integers
        assert_ne!(mix64(1), 1);
        assert_ne!(mix64(2), 2);
    }

    #[test]
    fn ring_salt_defeats_structural_collisions() {
        // Unsalted, backend 0's vnode points would equal the hashes of
        // small keys; salted, sequential keys spread across backends.
        let ring = HashRing::new(4, 160);
        let mut hit = [0usize; 4];
        for key in 0..64u64 {
            hit[ring.pick_where(mix64(key), |_| true).unwrap()] += 1;
        }
        assert!(hit.iter().all(|&h| h > 0), "sequential keys all on one backend: {hit:?}");
    }

    #[test]
    fn ring_walk_skips_dead_backends() {
        let ring = HashRing::new(3, 64);
        for key in 0..200u64 {
            let h = mix64(key);
            let full = ring.pick_where(h, |_| true).unwrap();
            let alive = ring.pick_where(h, |b| b != full).unwrap();
            assert_ne!(alive, full);
            // keys not owned by the dead backend do not move
            if let Some(other) = ring.pick_where(h, |b| b != ((full + 1) % 3)) {
                if full != (full + 1) % 3 {
                    assert_eq!(other, full);
                }
            }
        }
        assert_eq!(ring.pick_where(42, |_| false), None);
    }

    #[test]
    fn least_outstanding_picks_min_and_respects_alive() {
        assert_eq!(pick_least_outstanding(&[5, 2, 9], |_| true), Some(1));
        assert_eq!(pick_least_outstanding(&[5, 2, 9], |b| b != 1), Some(0));
        assert_eq!(pick_least_outstanding(&[3, 3, 3], |_| true), Some(0), "first wins ties");
        assert_eq!(pick_least_outstanding(&[1, 2], |_| false), None);
        assert_eq!(pick_least_outstanding(&[], |_| true), None);
    }
}
