//! The TCP front-end: turns a [`ServerHandle`] into a network service.
//!
//! Concurrency model (std threads, matching the coordinator): one
//! accept-loop thread; per connection, one **reader** thread decoding
//! frames and feeding [`ServerHandle::submit_with`], and one **writer**
//! thread serializing reply frames from an allocation-free queue
//! ([`crate::util::queue`]). Completions are reply-queue registrations
//! ([`Completion::Frame`]), not blocked threads, so a single connection
//! can keep the whole admission window in flight while costing two OS
//! threads total — and a warm connection's read → submit → reply →
//! write cycle performs zero heap allocations: the reader decodes
//! through a reusable payload scratch into pooled pixel buffers, the
//! coordinator answers with pooled-logit frames, and the writer encodes
//! through its own scratch before the frame drops back into the pool.
//!
//! Replies go out in *completion* order (the `id` field matches them to
//! requests), so a pipelined client never suffers head-of-line blocking
//! behind a slower batch.
//!
//! Multi-tenant requests ride the `Request` frame's optional model id
//! (absent = default model) and land on
//! [`ServerHandle::submit_model_from`]; a retiring model's requests come
//! back as retryable `Rejected` frames, an unknown model's as terminal
//! `Error`s. The `LoadModel`/`RetireModel` admin frames map onto
//! [`ServerHandle::load_model`]/[`ServerHandle::retire_model`] — the
//! retire ack is sent only after the drain completes, so an admin client
//! can treat `AdminOk` as "the swap window is open". No connection is
//! ever dropped by a swap.
//!
//! Observability: `GetStats` answers with this server's full
//! [`crate::coordinator::MetricsSnapshot`] on a `Stats` frame, and
//! `DumpTrace` answers with the process flight recorder's Chrome-trace
//! JSON on a `Trace` frame. A `Request` carrying a nonzero trace id
//! (protocol v0.3) gets its ingress span recorded here and keeps that
//! id through the coordinator, so the spans a router and a backend
//! record for one routed request stitch into a single timeline.
//!
//! Failure containment: a malformed or truncated frame closes that one
//! connection (best-effort `Error` frame first) — the coordinator and
//! every other connection are untouched, because the reader owns
//! nothing but its socket and a cloned handle. Admission rejections ride
//! the 429-style `Rejected` frame with the structured
//! [`Backpressure`] retry hint.
//!
//! Shutdown drains: `shutdown()` stops accepting, closes every
//! connection's read half (no new requests), then joins the writers —
//! which exit only after every in-flight completion has been written.
//! In-flight requests therefore always get their response before the
//! socket closes. Call it *before* `CoordinatorServer::shutdown`. The
//! drain is bounded: pending partial batches flush within the
//! batcher's `max_wait` (the deadline flusher), and a peer that stops
//! reading its socket cannot pin a writer forever — every connection
//! carries a [`WRITE_TIMEOUT`], after which the stalled write fails
//! and the writer closes that connection.

use super::protocol::{read_frame_with, write_frame, write_frame_with, Frame, StatsPayload};
use crate::coordinator::{Backpressure, Completion, ModelUnavailable, ServerHandle};
use crate::util::queue;
use crate::util::trace::Stage;
use crate::Result;
use anyhow::Context;
use std::io::{BufReader, BufWriter, Write as _};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-connection socket write timeout. Reply frames are small, so any
/// write that stalls this long means the peer stopped draining its
/// receive buffer; the writer then drops the connection instead of
/// blocking forever — this is what keeps [`NetServer::shutdown`]'s
/// drain (which joins every writer) bounded against stalled or
/// malicious clients.
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// One live connection's handles, kept so shutdown can close and join it.
struct Conn {
    /// Extra stream clone for `Shutdown::Read` during drain.
    stream: TcpStream,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

struct NetShared {
    stopping: AtomicBool,
    /// Connections currently open (admission-checked against
    /// `net.max_connections` in the accept loop).
    live: AtomicUsize,
    /// Monotonic connection id: each accepted connection gets the next
    /// value and submits through [`ServerHandle::submit_from`] with it,
    /// so `batcher.affinity connection` can pin its lane.
    next_conn: AtomicU64,
    conns: Mutex<Vec<Conn>>,
}

/// The wire-protocol serving front-end. Bind with [`NetServer::bind`];
/// every accepted connection serves the [`ServerHandle`] given there.
pub struct NetServer {
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    state: Arc<NetShared>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:7077`, or port `0` for an
    /// OS-assigned port — see [`NetServer::local_addr`]) and start
    /// accepting connections that serve `handle`.
    pub fn bind(handle: ServerHandle, listen: &str, max_connections: usize) -> Result<NetServer> {
        anyhow::ensure!(max_connections >= 1, "need at least one connection slot");
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding net.listen {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let state = Arc::new(NetShared {
            stopping: AtomicBool::new(false),
            live: AtomicUsize::new(0),
            next_conn: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let state = state.clone();
            std::thread::Builder::new()
                .name("luna-net-accept".into())
                .spawn(move || accept_loop(listener, handle, state, max_connections))
                .context("spawning accept thread")?
        };
        Ok(NetServer { addr, accept: Some(accept), state })
    }

    /// The actually-bound address (resolves port `0` bindings).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections currently open.
    pub fn live_connections(&self) -> usize {
        self.state.live.load(Ordering::Relaxed)
    }

    /// Graceful drain: stop accepting, close every connection's read
    /// half, then join the per-connection threads — writers finish only
    /// after every in-flight request's reply has been written, so
    /// admitted work is never silently dropped. Bounded by the batcher's
    /// `max_wait` (pending partial batches flush on that deadline).
    pub fn shutdown(mut self) {
        self.state.stopping.store(true, Ordering::Relaxed);
        // Wake the blocking accept() with a throwaway connection; the
        // loop sees `stopping` and exits. Unspecified listen addresses
        // (0.0.0.0 / ::) are dialed back on the loopback of the family.
        let _ = TcpStream::connect(wake_addr(self.addr));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let conns = std::mem::take(&mut *self.state.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        for c in conns {
            let _ = c.reader.join();
            let _ = c.writer.join();
        }
    }

    /// Ungraceful kill: close every socket (both halves) immediately —
    /// in-flight requests get no reply, peers see a dead connection.
    /// This simulates a crashed backend process; the router's failover
    /// tests use it. For production teardown use
    /// [`shutdown`](Self::shutdown), which drains.
    pub fn abort(mut self) {
        self.state.stopping.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(wake_addr(self.addr));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let conns = std::mem::take(&mut *self.state.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Both);
        }
        for c in conns {
            let _ = c.reader.join();
            let _ = c.writer.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // `shutdown()` consumed self and already cleaned up in the
        // normal path; this covers early-drop (e.g. error unwinding) so
        // the accept thread does not linger on a dead listener.
        if let Some(a) = self.accept.take() {
            self.state.stopping.store(true, Ordering::Relaxed);
            let _ = TcpStream::connect(wake_addr(self.addr));
            let _ = a.join();
        }
    }
}

fn wake_addr(bound: SocketAddr) -> SocketAddr {
    let ip = match bound.ip() {
        ip if !ip.is_unspecified() => ip,
        IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
    };
    SocketAddr::new(ip, bound.port())
}

fn accept_loop(
    listener: TcpListener,
    handle: ServerHandle,
    state: Arc<NetShared>,
    max_connections: usize,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if state.stopping.load(Ordering::Relaxed) {
                    return; // the shutdown wake-up (or a racing client)
                }
                prune_finished(&state);
                if state.live.load(Ordering::Relaxed) >= max_connections {
                    reject_connection(stream, &handle);
                    continue;
                }
                match spawn_connection(stream, handle.clone(), state.clone()) {
                    Ok(conn) => state.conns.lock().unwrap().push(conn),
                    Err(e) => eprintln!("net: connection setup failed: {e:#}"),
                }
            }
            Err(e) => {
                if state.stopping.load(Ordering::Relaxed) {
                    return;
                }
                eprintln!("net: accept error: {e:#}");
            }
        }
    }
}

/// Join and drop registry entries whose threads have exited, so a
/// long-lived server does not accumulate dead handles.
fn prune_finished(state: &NetShared) {
    let mut conns = state.conns.lock().unwrap();
    // lint: allow(alloc): accept-loop housekeeping between connections,
    // never on a request's path.
    let mut kept = Vec::with_capacity(conns.len());
    for c in conns.drain(..) {
        if c.reader.is_finished() && c.writer.is_finished() {
            let _ = c.reader.join();
            let _ = c.writer.join();
        } else {
            kept.push(c);
        }
    }
    *conns = kept;
}

/// Over-capacity turn-away: one best-effort `Rejected` frame (id 0 =
/// connection-scoped, no retry hint derivable without queue state),
/// then close.
fn reject_connection(stream: TcpStream, handle: &ServerHandle) {
    handle.metrics().record_rejection(0);
    let mut w = BufWriter::new(&stream);
    let frame =
        Frame::Rejected { id: 0, retry_after_us: 0, reason: "connection limit reached".into() };
    let _ = write_frame(&mut w, &frame);
    let _ = w.flush();
}

fn spawn_connection(
    stream: TcpStream,
    handle: ServerHandle,
    state: Arc<NetShared>,
) -> Result<Conn> {
    // Request/response frames are small and latency-bound.
    let _ = stream.set_nodelay(true);
    // A peer that stops reading must not pin the writer (and thereby
    // shutdown's join) forever.
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let reader_stream = stream.try_clone().context("cloning stream for reader")?;
    let writer_stream = stream.try_clone().context("cloning stream for writer")?;
    let (tx, rx) = queue::channel::<Frame>();
    state.live.fetch_add(1, Ordering::Relaxed);
    let writer_state = state.clone();
    let writer_spawn = std::thread::Builder::new().name("luna-net-writer".into()).spawn(move || {
        {
            let mut w = BufWriter::new(&writer_stream);
            // reused across frames: steady-state encoding allocates
            // nothing, and the frame's pooled payload recycles on drop
            let mut scratch = Vec::new();
            // Exits when every sender is gone: the reader's plus one
            // clone per in-flight completion — i.e. after the drain.
            while let Some(frame) = rx.recv() {
                if write_frame_with(&mut w, &frame, &mut scratch).is_err() || w.flush().is_err() {
                    break;
                }
            }
        }
        // Last one out closes the socket for every clone (the
        // registry still holds one, so Drop alone would not).
        let _ = writer_stream.shutdown(Shutdown::Both);
        writer_state.live.fetch_sub(1, Ordering::Relaxed);
    });
    let writer = match writer_spawn {
        Ok(w) => w,
        Err(e) => {
            // The writer closure never ran, so its decrement never
            // will: undo the increment or the slot leaks forever.
            state.live.fetch_sub(1, Ordering::Relaxed);
            return Err(e).context("spawning connection writer");
        }
    };
    let conn_id = state.next_conn.fetch_add(1, Ordering::Relaxed);
    let reader = std::thread::Builder::new()
        .name("luna-net-reader".into())
        .spawn(move || reader_main(reader_stream, tx, handle, conn_id))
        .context("spawning connection reader")?;
    Ok(Conn { stream, reader, writer })
}

fn reader_main(stream: TcpStream, tx: queue::Sender<Frame>, handle: ServerHandle, conn_id: u64) {
    let mut r = BufReader::new(&stream);
    let recorder = handle.recorder();
    let metrics = handle.metrics();
    // reused payload scratch: a warm connection decodes every frame
    // through this buffer and pooled pixel vecs — no allocation per read
    let mut scratch = Vec::new();
    loop {
        match read_frame_with(&mut r, &mut scratch) {
            Ok(Some(Frame::Hello)) => {
                let info = Frame::Info {
                    in_dim: handle.input_dim() as u32,
                    out_dim: handle.output_dim() as u32,
                    max_batch: handle.max_batch() as u32,
                    backend: handle.backend_slug().to_string(),
                    models: handle.models(),
                };
                if tx.send(info).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::Request { id, pixels, model, trace })) => {
                let t0 = Instant::now();
                // the coordinator builds the Response/Error frame itself
                // (pooled logits) and pushes it onto this connection's
                // writer queue — no boxed closure, no allocation
                let done = Completion::Frame { tx: tx.clone(), wire_id: id };
                if let Err(e) = handle.submit_traced(conn_id, model, pixels, trace, done) {
                    let frame = if let Some(bp) = e.downcast_ref::<Backpressure>() {
                        Frame::Rejected {
                            id,
                            retry_after_us: bp.retry_after_us,
                            reason: e.to_string(),
                        }
                    } else if e.downcast_ref::<ModelUnavailable>().is_some_and(|m| m.retiring) {
                        // transient by design: the model may come back
                        // after the swap, so this is a retryable
                        // Rejected (hint 0 — no queue-derived backoff),
                        // not a terminal Error
                        Frame::Rejected { id, retry_after_us: 0, reason: e.to_string() }
                    } else {
                        // unknown model, wrong pixel count, compile
                        // failure: terminal for this request
                        Frame::Error { id, reason: format!("{e:#}") }
                    };
                    if tx.send(frame).is_err() {
                        return;
                    }
                }
                // Ingress covers decoded-to-submitted. The span lands
                // only for a trace id assigned upstream (router or
                // client); locally sampled requests start their
                // timeline at admission inside the coordinator.
                let now = Instant::now();
                let ingress_us = now.duration_since(t0).as_micros() as u64;
                metrics.record_stage_us(Stage::Ingress, ingress_us);
                recorder.record(trace, Stage::Ingress, t0, now);
            }
            Ok(Some(Frame::LoadModel { model, dir })) => {
                let reply = match handle.load_model(model, &dir) {
                    Ok(()) => Frame::AdminOk { model },
                    Err(e) => Frame::Error { id: 0, reason: format!("{e:#}") },
                };
                if tx.send(reply).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::RetireModel { model })) => {
                // retire_model drains the model's in-flight requests
                // before returning, so this ack doubles as the "swap
                // window open" signal. Blocking this reader is fine —
                // other connections have their own.
                let reply = match handle.retire_model(model) {
                    Ok(()) => Frame::AdminOk { model },
                    Err(e) => Frame::Error { id: 0, reason: format!("{e:#}") },
                };
                if tx.send(reply).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::GetStats)) => {
                // cold admin path: snapshot and reply allocate freely
                let snap = metrics.snapshot();
                let stats = StatsPayload { server: Some(snap), ..Default::default() };
                if tx.send(Frame::Stats(Box::new(stats))).is_err() {
                    return;
                }
            }
            Ok(Some(Frame::DumpTrace)) => {
                if tx.send(Frame::Trace { json: recorder.dump_json() }).is_err() {
                    return;
                }
            }
            Ok(Some(other)) => {
                // Server-to-client frame types from a client are a
                // protocol violation; close rather than guess.
                let reason = format!("unexpected client frame {other:?}");
                let _ = tx.send(Frame::Error { id: 0, reason });
                return;
            }
            Ok(None) => return, // peer hung up cleanly
            Err(e) => {
                // Malformed/truncated input: best-effort diagnostic,
                // then close this connection only — the coordinator and
                // other connections never see the bad bytes.
                let reason = format!("protocol error: {e:#}");
                let _ = tx.send(Frame::Error { id: 0, reason });
                return;
            }
        }
    }
}
