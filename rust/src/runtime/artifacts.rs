//! Artifact directory layout shared with `python/compile/aot.py`.
//!
//! ```text
//! artifacts/
//!   manifest.txt        # kv metadata (dims, batch, variants, ...)
//!   weights.txt         # quantized MLP (util::kv format, luna-mlp-v1)
//!   testset.bin         # exported test set (binary, see nn::DigitsDataset)
//!   mlp_<variant>.hlo.txt   # batched MLP per multiplier variant
//!   mult_<variant>.hlo.txt  # standalone elementwise 4b multiplier kernel
//! ```

use crate::multiplier::MultiplierKind;
use crate::util::kv::KvMap;
use crate::Result;
use anyhow::ensure;
use std::path::{Path, PathBuf};

/// Metadata about the exported model, from `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Layer dimensions, e.g. `[64, 32, 10]`.
    pub dims: Vec<usize>,
    /// Batch size every HLO variant was lowered with.
    pub batch: usize,
    /// Variants exported (kebab-case kind slugs).
    pub variants: Vec<String>,
    /// Test accuracy reported by `aot.py` (float32, pre-quantization).
    pub train_accuracy: f64,
    /// Number of test samples in `testset.bin`.
    pub test_samples: usize,
}

impl ModelMeta {
    /// Render in the manifest kv format.
    pub fn to_text(&self) -> String {
        let mut m = KvMap::new();
        m.set("dims", self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","));
        m.set("batch", self.batch);
        m.set("variants", self.variants.join(","));
        m.set("train_accuracy", self.train_accuracy);
        m.set("test_samples", self.test_samples);
        m.render()
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let m = KvMap::parse(text)?;
        let meta = ModelMeta {
            dims: m.get_usize_list("dims")?,
            batch: m.get_usize("batch")?,
            variants: m.get_str_list("variants")?,
            train_accuracy: m.get_f64("train_accuracy")?,
            test_samples: m.get_usize("test_samples")?,
        };
        ensure!(meta.dims.len() >= 2, "manifest dims too short");
        ensure!(meta.batch > 0, "manifest batch must be positive");
        Ok(meta)
    }
}

/// Resolver for the `artifacts/` directory produced by `make artifacts`.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        ArtifactStore { root: root.into() }
    }

    /// Default location relative to the repo root / current directory.
    pub fn default_location() -> Self {
        ArtifactStore::new("artifacts")
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn exists(&self) -> bool {
        self.manifest_path().exists()
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.txt")
    }

    /// HLO text for the batched MLP under a multiplier variant.
    pub fn mlp_hlo(&self, kind: MultiplierKind) -> PathBuf {
        self.root.join(format!("mlp_{}.hlo.txt", kind.slug()))
    }

    /// HLO text for the standalone element-wise 4b multiplier kernel
    /// (used for bit-accuracy cross-checks).
    pub fn mult_hlo(&self, kind: MultiplierKind) -> PathBuf {
        self.root.join(format!("mult_{}.hlo.txt", kind.slug()))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.root.join("weights.txt")
    }

    pub fn testset_path(&self) -> PathBuf {
        self.root.join("testset.bin")
    }

    /// Load and validate the manifest.
    pub fn manifest(&self) -> Result<ModelMeta> {
        ensure!(
            self.exists(),
            "artifacts missing at {} — run `make artifacts`",
            self.root.display()
        );
        let text = std::fs::read_to_string(self.manifest_path())?;
        ModelMeta::from_text(&text)
    }

    /// Load the quantized weights exported by `aot.py`.
    pub fn load_mlp(&self) -> Result<crate::nn::QuantMlp> {
        let text = std::fs::read_to_string(self.weights_path())?;
        crate::nn::QuantMlp::from_text(&text)
    }

    /// Load the exported test set.
    pub fn load_testset(&self) -> Result<crate::nn::DigitsDataset> {
        let bytes = std::fs::read(self.testset_path())?;
        crate::nn::DigitsDataset::from_binary(&bytes)
    }

    /// Write a complete **synthetic** artifact directory for `mlp`:
    /// manifest (dims derived from the model), weights and test set —
    /// everything the native/calibrated backends need, with no Python
    /// exporter and no HLO files. The integration suites and
    /// `repro loadgen --synthetic` share this one writer, so the
    /// synthesized layout cannot drift from what the loaders expect.
    pub fn write_synthetic(
        &self,
        mlp: &crate::nn::QuantMlp,
        testset: &crate::nn::DigitsDataset,
        batch: usize,
    ) -> Result<()> {
        let mut dims = vec![mlp.input_dim()];
        dims.extend(mlp.layers.iter().map(|l| l.out_dim));
        let meta = ModelMeta {
            dims,
            batch,
            variants: vec!["ideal".into()],
            train_accuracy: 0.0,
            test_samples: testset.len(),
        };
        std::fs::create_dir_all(self.root())?;
        std::fs::write(self.manifest_path(), meta.to_text())?;
        std::fs::write(self.weights_path(), mlp.to_text())?;
        std::fs::write(self.testset_path(), testset.to_binary())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_stable() {
        let s = ArtifactStore::new("/tmp/a");
        assert_eq!(s.mlp_hlo(MultiplierKind::DncOpt), PathBuf::from("/tmp/a/mlp_dnc-opt.hlo.txt"));
        assert_eq!(
            s.mult_hlo(MultiplierKind::Approx2),
            PathBuf::from("/tmp/a/mult_approx2.hlo.txt")
        );
    }

    #[test]
    fn missing_manifest_is_clear_error() {
        let s = ArtifactStore::new("/nonexistent-artifacts");
        let err = s.manifest().unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = crate::util::test_dir("artifacts");
        let s = ArtifactStore::new(&dir);
        let meta = ModelMeta {
            dims: vec![64, 32, 10],
            batch: 8,
            variants: vec!["ideal".into(), "dnc-opt".into()],
            train_accuracy: 0.97,
            test_samples: 200,
        };
        std::fs::write(s.manifest_path(), meta.to_text()).unwrap();
        let back = s.manifest().unwrap();
        assert_eq!(back.dims, vec![64, 32, 10]);
        assert_eq!(back.batch, 8);
        assert_eq!(back.variants, vec!["ideal", "dnc-opt"]);
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(ModelMeta::from_text(
            "dims 64\nbatch 8\nvariants x\ntrain_accuracy 1\ntest_samples 1\n"
        )
        .is_err());
        assert!(ModelMeta::from_text("batch 8\n").is_err());
    }
}
