//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers the L2 model to **HLO text** (the
//! interchange format that survives the jax≥0.5 / xla_extension 0.5.1
//! proto-id mismatch — see DESIGN.md). This module wraps the `xla` crate:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. PJRT handles are not `Send`; the coordinator therefore gives
//! each worker *thread* its own [`PjrtRuntime`] (see
//! [`crate::coordinator::worker`]).

mod artifacts;
mod client;

pub use artifacts::{ArtifactStore, ModelMeta};
pub use client::{CompiledModel, PjrtRuntime};
