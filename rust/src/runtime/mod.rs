//! Artifact store + optional PJRT runtime for the AOT-compiled
//! JAX/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers the L2 model to **HLO text** (the
//! interchange format that survives the jax≥0.5 / xla_extension 0.5.1
//! proto-id mismatch — see DESIGN.md). The [`ArtifactStore`] (always
//! available) resolves the artifact layout; the PJRT client wrapper is
//! gated behind the `pjrt` cargo feature so the default build has zero
//! external dependencies — serving then uses the native LUT-GEMM backend
//! ([`crate::engine::NativeBackend`]). With `--features pjrt` this module
//! wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. PJRT handles
//! are not `Send`; the coordinator therefore gives each worker *thread*
//! its own [`PjrtRuntime`] (see [`crate::coordinator::worker`]).

mod artifacts;
#[cfg(feature = "pjrt")]
mod client;

pub use artifacts::{ArtifactStore, ModelMeta};
#[cfg(feature = "pjrt")]
pub use client::{CompiledModel, PjrtRuntime};
