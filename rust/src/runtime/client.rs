//! Thin, checked wrapper over the `xla` crate's PJRT CPU client.

use crate::Result;
use anyhow::{ensure, Context};
use std::path::Path;

/// A PJRT CPU client plus compilation helpers. Not `Send` — construct one
/// per worker thread.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<CompiledModel> {
        let path = path.as_ref();
        ensure!(path.exists(), "HLO artifact {} not found — run `make artifacts`", path.display());
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledModel { exe, name: path.display().to_string() })
    }
}

/// A compiled executable with typed f32 execution helpers.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl CompiledModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 inputs of the given shapes; returns every tuple
    /// element of the (tupled) output as a flat `Vec<f32>`.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the
    /// single output literal is always a tuple (possibly of one element).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let numel: i64 = dims.iter().product();
                ensure!(
                    numel as usize == data.len(),
                    "input length {} != shape {:?}",
                    data.len(),
                    dims
                );
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        ensure!(!result.is_empty() && !result[0].is_empty(), "no output buffers");
        let out = result[0][0].to_literal_sync().context("fetching output literal")?;
        let elems = out.to_tuple().context("output is not a tuple")?;
        elems
            .iter()
            .map(|lit| lit.to_vec::<f32>().context("output element not f32"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-written HLO module: f(x) = (x + x,) over f32[2].
    const DOUBLE_HLO: &str = r#"HloModule double, entry_computation_layout={(f32[2]{0})->(f32[2]{0})}

ENTRY main {
  p0 = f32[2]{0} parameter(0)
  add = f32[2]{0} add(p0, p0)
  ROOT t = (f32[2]{0}) tuple(add)
}
"#;

    #[test]
    fn load_and_run_handwritten_hlo() {
        let dir = crate::util::test_dir("runtime-client");
        let path = dir.join("double.hlo.txt");
        std::fs::write(&path, DOUBLE_HLO).unwrap();

        let rt = PjrtRuntime::cpu().unwrap();
        assert!(!rt.platform_name().is_empty());
        let model = rt.load_hlo_text(&path).unwrap();
        let out = model.run_f32(&[(&[1.5f32, -2.0], &[2])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![3.0f32, -4.0]);
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        let err = match rt.load_hlo_text("/nonexistent/model.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let dir = crate::util::test_dir("runtime-client2");
        let path = dir.join("double.hlo.txt");
        std::fs::write(&path, DOUBLE_HLO).unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        let model = rt.load_hlo_text(&path).unwrap();
        assert!(model.run_f32(&[(&[1.0f32], &[2])]).is_err());
    }
}
