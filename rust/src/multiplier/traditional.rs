//! Traditional (unoptimized) LUT-based multiplier — paper Fig 1 / Table I.
//!
//! For a k-bit × k-bit multiply with a fixed weight `W`, all `2^k` products
//! are precomputed into SRAM (each `2k` bits wide) and a `2^k:1` word mux
//! selects by the input `Y`. Storage: `2^k · 2k` bits; select logic:
//! `(2^k − 1) · 2k` one-bit 2:1 muxes — exactly the Table I columns.

use crate::cells::{CellKind, CostReport};
use crate::logic::{to_bits, Netlist};

/// Number of SRAM bits required (Table I column 2).
pub fn sram_bits(k: u32) -> u64 {
    (1u64 << k) * (2 * k as u64)
}

/// Number of 1-bit 2:1 muxes required (Table I column 3).
pub fn mux_count(k: u32) -> u64 {
    ((1u64 << k) - 1) * (2 * k as u64)
}

/// Component cost of the traditional k-bit LUT multiplier.
pub fn cost(k: u32) -> CostReport {
    CostReport::from_pairs(&[(CellKind::SramCell, sram_bits(k)), (CellKind::Mux2, mux_count(k))])
}

/// Behavioural model: LUT lookup == exact product.
pub fn value(w: u8, y: u8) -> u8 {
    super::ideal_value(w, y)
}

/// Structural netlist of the k-bit traditional LUT multiplier.
///
/// Inputs: bus `Y` (k bits). SRAM: `2^k` words of `2k` bits (programming
/// order: word 0 first, little-endian bits). Output: bus `OUT` (2k bits).
pub fn netlist(k: u32) -> Netlist {
    assert!((1..=8).contains(&k), "supported widths: 1..=8");
    let mut n = Netlist::default();
    let y = n.input_bus("Y", k as usize);
    let out_w = 2 * k as usize;
    // SRAM words, one per possible Y value.
    let words: Vec<Vec<crate::logic::NetId>> =
        (0..(1usize << k)).map(|_| n.sram_bus(out_w)).collect();
    // Per output bit, a 2^k:1 mux tree over the stored words.
    let mut out = Vec::with_capacity(out_w);
    for bit in 0..out_w {
        let ins: Vec<_> = words.iter().map(|wd| wd[bit]).collect();
        out.push(n.mux_tree(&ins, &y));
    }
    n.output_bus("OUT", out);
    n
}

/// Programming image for weight `w`: the `2^k` products, little-endian
/// bits, word-major — matches the netlist's SRAM programming order.
pub fn program_image(k: u32, w: u64) -> Vec<bool> {
    assert!(w < (1u64 << k));
    let out_w = 2 * k as usize;
    (0..(1u64 << k)).flat_map(|y| to_bits(w * y, out_w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn table1_counts() {
        // Paper Table I rows, 3b..8b.
        let expect = [(3, 48, 42), (4, 128, 120), (5, 320, 310), (6, 768, 756), (7, 1792, 1778), (8, 4096, 4080)];
        for (k, srams, muxes) in expect {
            assert_eq!(sram_bits(k), srams, "sram k={k}");
            assert_eq!(mux_count(k), muxes, "mux k={k}");
        }
    }

    #[test]
    fn netlist_cost_matches_formulas() {
        for k in [2u32, 3, 4] {
            let n = netlist(k);
            let r = n.cost_report();
            assert_eq!(r.count(CellKind::SramCell), sram_bits(k));
            assert_eq!(r.count(CellKind::Mux2), mux_count(k));
            assert_eq!(r.count(CellKind::HalfAdder), 0);
            assert_eq!(r.count(CellKind::FullAdder), 0);
        }
    }

    #[test]
    fn netlist_matches_behavioural_exhaustively_4b() {
        let n = netlist(4);
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(4, w as u64));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(from_bits(&res.outputs) as u8, value(w, y), "w={w} y={y}");
            }
        }
    }

    #[test]
    fn netlist_matches_behavioural_3b() {
        let n = netlist(3);
        let mut st = Stepper::new(&n);
        for w in 0..8u64 {
            st.program(&program_image(3, w));
            for y in 0..8u64 {
                let res = st.step(&n, &to_bits(y, 3));
                assert_eq!(from_bits(&res.outputs), w * y, "w={w} y={y}");
            }
        }
    }
}
