//! LUT-based multiplier configurations — the paper's core contribution.
//!
//! Each configuration provides three faces:
//!
//! 1. a **behavioural model** (`value(w, y)`) — the arithmetic the paper's
//!    MATLAB analysis uses (Figs 5–8, 11–13);
//! 2. a **structural netlist** built from [`crate::logic`] primitives —
//!    the circuit the paper lays out (Figs 1–4, 9, 10), functionally
//!    verified against the behavioural model exhaustively in tests;
//! 3. a **cost report** — SRAM/mux/adder counts (Tables I, II) and the
//!    transistor/area/energy views (Figs 15, 16, 18).
//!
//! Configurations:
//!
//! | module          | paper figure | idea |
//! |-----------------|--------------|------|
//! | [`traditional`] | Fig 1        | full 2ᵏ-entry LUT |
//! | [`dnc`]         | Fig 2        | two 4b×2b LUTs + ripple add |
//! | [`dnc_opt`]     | Fig 3        | shared/derived LUT rows |
//! | [`approx`]      | Figs 4 & 9   | Z_LSB ≈ fixed (0 optimal) |
//! | [`approx2`]     | Fig 10       | Z_LSB ≈ W |
//! | [`generic`]     | Table II     | optimized D&C at any even width |
//! | [`array_mult`]  | (baseline)   | conventional digital array multiplier |

pub mod approx;
pub mod approx2;
pub mod array_mult;
pub mod dnc;
pub mod dnc_opt;
pub mod generic;
pub mod traditional;

mod kind;
pub(crate) mod parts;

pub use kind::{MultiplierKind, MultiplierModel};

/// 4-bit operand mask helper.
pub(crate) fn check4(x: u8) -> u8 {
    assert!(x < 16, "operand {x} out of 4-bit range");
    x
}

/// Exact product of two 4-bit operands ("IDEAL" in the paper's Fig 13).
pub fn ideal_value(w: u8, y: u8) -> u8 {
    check4(w) * check4(y)
}

/// Z_MSB of the D&C split: `w * (y >> 2)` — the 4b×2b MSB-side product.
pub fn z_msb(w: u8, y: u8) -> u8 {
    check4(w) * (check4(y) >> 2)
}

/// Z_LSB of the D&C split: `w * (y & 3)` — the 4b×2b LSB-side product.
pub fn z_lsb(w: u8, y: u8) -> u8 {
    check4(w) * (check4(y) & 3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dnc_identity_holds_exhaustively() {
        for w in 0..16u8 {
            for y in 0..16u8 {
                assert_eq!(((z_msb(w, y) as u16) << 2) + z_lsb(w, y) as u16, (w as u16) * (y as u16));
            }
        }
    }

    #[test]
    #[should_panic]
    fn out_of_range_operand_panics() {
        let _ = ideal_value(16, 0);
    }
}
