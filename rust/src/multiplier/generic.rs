//! Optimized D&C LUT multiplier generalized to any even width — Table II.
//!
//! For an n-bit × n-bit multiply (n even) the input `Y` is split into
//! `n/2` two-bit chunks; each chunk has a 4:1 word-mux unit of width
//! `n + 2` (3·(n+2) one-bit muxes). The shared-row LUT stores `2n + 2`
//! bits per copy; following the paper's fan-out note ("the number of
//! actual SRAMs will depend on Fanout considerations"), **one LUT copy
//! drives two chunk units** — the replication that reproduces Table II's
//! SRAM column exactly (4b: 10, 8b: 36, 16b: 136).
//!
//! Chunk products are combined by a **binary tree** of shifted ripple
//! adders ([`super::parts::add_shifted`]); this tree shape — not a linear
//! chain — is what reproduces Table II's HA/FA columns (8b: 11/21,
//! 16b: 31/105).

use super::parts;
use crate::cells::{CellKind, CostReport};
use crate::logic::{Bus, Netlist};

/// Closed-form component counts for the optimized D&C multiplier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DncCounts {
    pub srams: u64,
    pub muxes: u64,
    pub has: u64,
    pub fas: u64,
}

/// Closed-form counts (validated against the constructed netlist in tests).
pub fn counts(n: u32) -> DncCounts {
    assert!(n >= 4 && n % 2 == 0, "width must be even and >= 4");
    let chunks = (n / 2) as u64;
    let copies = chunks.div_ceil(2);
    let srams = copies * (2 * n as u64 + 2);
    let muxes = chunks * 3 * (n as u64 + 2);
    // binary adder tree: at level ℓ (0-based) operands are m_ℓ bits wide
    // with relative shift s_ℓ = 2^(ℓ+1); each adder costs (s+1) HA +
    // (m − s − 1) FA; widths grow by s per level.
    let (mut has, mut fas) = (0u64, 0u64);
    let mut width = n as u64 + 2;
    let mut adders = chunks / 2;
    let mut shift = 2u64;
    while adders >= 1 {
        has += adders * (shift + 1);
        fas += adders * (width - shift - 1);
        width += shift;
        shift *= 2;
        adders /= 2;
    }
    DncCounts { srams, muxes, has, fas }
}

/// Expected cost report from the closed forms.
pub fn cost(n: u32) -> CostReport {
    let c = counts(n);
    CostReport::from_pairs(&[
        (CellKind::SramCell, c.srams),
        (CellKind::Mux2, c.muxes),
        (CellKind::HalfAdder, c.has),
        (CellKind::FullAdder, c.fas),
    ])
}

/// Behavioural model — exact product of two n-bit operands.
pub fn value(n: u32, w: u64, y: u64) -> u64 {
    assert!(w < (1 << n) && y < (1 << n));
    w * y
}

/// Structural netlist of the n-bit optimized D&C multiplier.
///
/// Inputs: `Y` (n bits). SRAM: `⌈n/4⌉` copies of the shared-row LUT
/// (copy-major programming order, see [`program_image`]). Output: `OUT`
/// (2n bits).
pub fn netlist(n: u32) -> Netlist {
    assert!(n >= 4 && n % 2 == 0, "width must be even and >= 4");
    let chunks = (n / 2) as usize;
    let mut net = Netlist::default();
    let y = net.input_bus("Y", n as usize);

    // LUT copies: one per two chunk units (paper's fan-out rule).
    let copies: Vec<parts::SharedLut> =
        (0..chunks.div_ceil(2)).map(|_| parts::lut4_shared(&mut net, n as usize)).collect();

    // Chunk units: unit c selects with y[2c], y[2c+1] from copy c/2.
    let mut products: Vec<Bus> = Vec::with_capacity(chunks);
    for c in 0..chunks {
        let lut = &copies[c / 2];
        let entries = lut.entries.clone();
        products.push(parts::chunk_unit(&mut net, &entries, y[2 * c], y[2 * c + 1]));
    }

    // Binary adder tree; at each level adjacent partials differ by a
    // relative shift that doubles per level.
    let mut level: Vec<Bus> = products;
    let mut shift = 2usize;
    while level.len() > 1 {
        assert!(level.len() % 2 == 0, "chunk count is a power of two for supported widths");
        let mut next: Vec<Bus> = Vec::with_capacity(level.len() / 2);
        for pair in level.chunks(2) {
            next.push(parts::add_shifted(&mut net, &pair[0], &pair[1], shift));
        }
        level = next;
        shift *= 2;
    }
    net.output_bus("OUT", level.pop().expect("at least one partial"));
    net
}

/// Programming image for weight `w`: the shared-LUT image repeated once
/// per copy.
pub fn program_image(n: u32, w: u64) -> Vec<bool> {
    assert!(w < (1 << n));
    let chunks = (n / 2) as usize;
    let one = parts::lut4_shared_image(w, n as usize);
    (0..chunks.div_ceil(2)).flat_map(|_| one.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn table2_closed_forms() {
        // Paper Table II, optimized D&C columns.
        assert_eq!(counts(4), DncCounts { srams: 10, muxes: 36, has: 3, fas: 3 });
        assert_eq!(counts(8), DncCounts { srams: 36, muxes: 120, has: 11, fas: 21 });
        assert_eq!(counts(16), DncCounts { srams: 136, muxes: 432, has: 31, fas: 105 });
    }

    #[test]
    fn netlist_counts_match_closed_forms() {
        for n in [4u32, 8, 16] {
            let r = netlist(n).cost_report();
            let c = counts(n);
            assert_eq!(r.count(CellKind::SramCell), c.srams, "sram n={n}");
            assert_eq!(r.count(CellKind::Mux2), c.muxes, "mux n={n}");
            assert_eq!(r.count(CellKind::HalfAdder), c.has, "ha n={n}");
            assert_eq!(r.count(CellKind::FullAdder), c.fas, "fa n={n}");
        }
    }

    #[test]
    fn netlist_4b_is_exact_exhaustively() {
        let n = netlist(4);
        let mut st = Stepper::new(&n);
        for w in 0..16u64 {
            st.program(&program_image(4, w));
            for y in 0..16u64 {
                let res = st.step(&n, &to_bits(y, 4));
                assert_eq!(from_bits(&res.outputs), w * y, "w={w} y={y}");
            }
        }
    }

    #[test]
    fn netlist_8b_is_exact_sampled() {
        let n = netlist(8);
        let mut st = Stepper::new(&n);
        for w in [0u64, 1, 2, 17, 85, 170, 200, 255] {
            st.program(&program_image(8, w));
            for y in [0u64, 1, 3, 16, 99, 128, 254, 255] {
                let res = st.step(&n, &to_bits(y, 8));
                assert_eq!(from_bits(&res.outputs), w * y, "w={w} y={y}");
            }
        }
    }

    #[test]
    fn netlist_16b_is_exact_sampled() {
        let n = netlist(16);
        let mut st = Stepper::new(&n);
        for w in [0u64, 1, 255, 4097, 40000, 65535] {
            st.program(&program_image(16, w));
            for y in [0u64, 1, 2, 513, 32768, 65535] {
                let res = st.step(&n, &to_bits(y, 16));
                assert_eq!(from_bits(&res.outputs), w * y, "w={w} y={y}");
            }
        }
    }

    #[test]
    fn area_benefit_vs_traditional_grows_with_width() {
        // Paper abstract: "up to approximately 3.7× less area" for the
        // D&C approach; at the transistor level the ratio keeps growing
        // with width (Table II: 16b traditional is astronomically larger).
        let lib = crate::cells::tsmc65_library();
        let t4 = super::super::traditional::cost(4).transistors(&lib);
        let d4 = cost(4).transistors(&lib);
        assert!(t4 as f64 / d4 as f64 > 2.0);
        let t8 = super::super::traditional::cost(8).transistors(&lib);
        let d8 = cost(8).transistors(&lib);
        assert!(t8 as f64 / d8 as f64 > 10.0);
    }
}
