//! Divide-and-conquer LUT multiplier — paper Fig 2.
//!
//! The 4b×4b product is split into two 4b×2b LUT lookups sharing one
//! 4-entry × 6-bit LUT (both sub-multiplications use the same weight `W`,
//! so the stored products are identical): `Z = (Z_MSB << 2) + Z_LSB`.
//!
//! Paper totals: **24 SRAM, 36 × 2:1 mux, 3 HA, 3 FA**.

use super::parts;
use crate::cells::{CellKind, CostReport};
use crate::logic::Netlist;

/// Behavioural model — exact (the D&C identity holds).
pub fn value(w: u8, y: u8) -> u8 {
    (super::z_msb(w, y) << 2) + super::z_lsb(w, y)
}

/// Paper component counts (Fig 2 caption).
pub fn cost() -> CostReport {
    CostReport::from_pairs(&[
        (CellKind::SramCell, 24),
        (CellKind::Mux2, 36),
        (CellKind::HalfAdder, 3),
        (CellKind::FullAdder, 3),
    ])
}

/// Structural netlist. Inputs: `Y` (4 bits). SRAM: 24 bits (4 entries × 6
/// bits, entry-major — see [`program_image`]). Output: `OUT` (8 bits).
pub fn netlist() -> Netlist {
    let mut n = Netlist::default();
    let y = n.input_bus("Y", 4);
    let entries = parts::lut4_plain(&mut n, 6);
    let z_lsb = parts::chunk_unit(&mut n, &entries, y[0], y[1]);
    let z_msb = parts::chunk_unit(&mut n, &entries, y[2], y[3]);
    let out = parts::add_shifted(&mut n, &z_lsb, &z_msb, 2);
    n.output_bus("OUT", out);
    n
}

/// Programming image for weight `w`: the four 6-bit products `w·0 … w·3`.
pub fn program_image(w: u8) -> Vec<bool> {
    parts::lut4_plain_image(super::check4(w) as u64, 6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn behavioural_equals_ideal_exhaustively() {
        for w in 0..16u8 {
            for y in 0..16u8 {
                assert_eq!(value(w, y), super::super::ideal_value(w, y));
            }
        }
    }

    #[test]
    fn netlist_cost_matches_paper_fig2() {
        let r = netlist().cost_report();
        assert_eq!(r.count(CellKind::SramCell), 24);
        assert_eq!(r.count(CellKind::Mux2), 36);
        assert_eq!(r.count(CellKind::HalfAdder), 3);
        assert_eq!(r.count(CellKind::FullAdder), 3);
        assert_eq!(r, cost());
    }

    #[test]
    fn netlist_matches_behavioural_exhaustively() {
        let n = netlist();
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(w));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(from_bits(&res.outputs) as u8, value(w, y), "w={w} y={y}");
            }
        }
    }
}
