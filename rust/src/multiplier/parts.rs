//! Shared structural building blocks for the LUT multiplier netlists.

use crate::logic::{Bus, Netlist};

/// Four LUT entry buses of width `w` for a 4-entry (2-bit-select) LUT,
/// each entry fully stored in SRAM — the *unoptimized* D&C storage of
/// Fig 2 (`4 · w` SRAM bits).
pub fn lut4_plain(n: &mut Netlist, width: usize) -> [Bus; 4] {
    [n.sram_bus(width), n.sram_bus(width), n.sram_bus(width), n.sram_bus(width)]
}

/// Programming image for [`lut4_plain`]: the four products `w·0 … w·3`,
/// little-endian, entry-major.
pub fn lut4_plain_image(w: u64, width: usize) -> Vec<bool> {
    (0..4u64).flat_map(|y| crate::logic::to_bits(w * y, width)).collect()
}

/// The optimized shared-row LUT of Fig 3 for an `nw`-bit weight.
///
/// Stores `2·nw + 2` SRAM bits: one zero rail `z0`, the `nw` bits of `W`
/// (the `W×01` row), and the `nw+1` MSBs of `W×11` (its LSB is `W₀`,
/// reused). The `W×10` row is the stored `W` left-shifted *by wiring*.
/// Returns the four `(nw+2)`-bit entry buses.
pub struct SharedLut {
    pub entries: [Bus; 4],
    /// Number of SRAM bits this LUT stores (2·nw + 2).
    pub sram_bits: usize,
}

pub fn lut4_shared(n: &mut Netlist, nw: usize) -> SharedLut {
    let width = nw + 2;
    let z0 = n.sram_bit(); // programmed to 0
    let w = n.sram_bus(nw); // W×01 row
    let t11 = n.sram_bus(nw + 1); // W×11 row, bits 1..=nw+1

    // e00 = 0…0 (all bits from the zero rail)
    let e00: Bus = vec![z0; width];
    // e01 = W zero-extended
    let mut e01: Bus = w.clone();
    e01.extend([z0, z0]);
    // e10 = W << 1 (wired shift of the stored W row)
    let mut e10: Bus = vec![z0];
    e10.extend(w.iter().copied());
    e10.push(z0);
    // e11 = {t11, W₀}: LSB reuses the stored W₀
    let mut e11: Bus = vec![w[0]];
    e11.extend(t11.iter().copied());

    SharedLut { entries: [e00, e01, e10, e11], sram_bits: 2 * nw + 2 }
}

/// Programming image for [`lut4_shared`]: `[z0=0, W bits, (3W)>>1 bits]`.
pub fn lut4_shared_image(w: u64, nw: usize) -> Vec<bool> {
    let mut bits = vec![false]; // z0
    bits.extend(crate::logic::to_bits(w, nw));
    bits.extend(crate::logic::to_bits((3 * w) >> 1, nw + 1));
    bits
}

/// One D&C chunk unit: a 4:1 word mux over the LUT entries, selected by a
/// 2-bit chunk of `Y`. Costs `3 · width` `Mux2` cells.
pub fn chunk_unit(n: &mut Netlist, entries: &[Bus; 4], s0: crate::logic::NetId, s1: crate::logic::NetId) -> Bus {
    n.mux4_bus([&entries[0], &entries[1], &entries[2], &entries[3]], s0, s1)
}

/// Ripple combine `a + (b << shift)` the way the paper sizes its adders:
///
/// * bits `0 .. shift` pass through from `a`;
/// * the first overlapping column is a half adder, the remaining
///   `overlap − 1` columns are full adders (carry chain);
/// * the top `shift` columns (bits of `b` above `a`) are half adders
///   absorbing the carry;
/// * the final carry-out is dropped — in every use the true result fits
///   the output width (the paper's "max Z_MSB = 101101" argument).
///
/// Requires `a.len() == b.len()`; returns `a.len() + shift` bits.
/// Cost: `(shift + 1)` HA + `(a.len() − shift − 1)` FA.
pub fn add_shifted(n: &mut Netlist, a: &Bus, b: &Bus, shift: usize) -> Bus {
    assert_eq!(a.len(), b.len(), "add_shifted operands must be equal width");
    let m = a.len();
    assert!(shift >= 1 && shift < m);
    let mut out = Vec::with_capacity(m + shift);
    out.extend(a[..shift].iter().copied());
    // first overlap column: HA
    let (s, mut carry) = n.half_adder(a[shift], b[0]);
    out.push(s);
    // remaining overlap columns: FA
    for i in (shift + 1)..m {
        let (s, c) = n.full_adder(a[i], b[i - shift], carry);
        out.push(s);
        carry = c;
    }
    // top columns: HA absorbing the carry
    for i in (m - shift)..m {
        let (s, c) = n.half_adder(b[i], carry);
        out.push(s);
        carry = c;
    }
    // final carry dropped by construction (result fits m + shift bits)
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Netlist, Stepper};

    #[test]
    fn shared_lut_produces_all_four_products() {
        for w in 0..16u64 {
            let mut n = Netlist::default();
            let sel = n.input_bus("sel", 2);
            let lut = lut4_shared(&mut n, 4);
            let out = chunk_unit(&mut n, &lut.entries, sel[0], sel[1]);
            n.output_bus("OUT", out);
            let mut st = Stepper::new(&n);
            st.program(&lut4_shared_image(w, 4));
            for y in 0..4u64 {
                let res = st.step(&n, &to_bits(y, 2));
                assert_eq!(from_bits(&res.outputs), w * y, "w={w} y={y}");
            }
        }
    }

    #[test]
    fn shared_lut_stores_10_bits_for_4b() {
        let mut n = Netlist::default();
        let lut = lut4_shared(&mut n, 4);
        assert_eq!(lut.sram_bits, 10);
        assert_eq!(n.sram_bits.len(), 10);
    }

    #[test]
    fn add_shifted_is_correct_and_costs_match() {
        // 6b + (6b << 2) — the Fig 2/3 adder: 3 HA + 3 FA.
        let mut n = Netlist::default();
        let a = n.input_bus("a", 6);
        let b = n.input_bus("b", 6);
        let out = add_shifted(&mut n, &a, &b, 2);
        assert_eq!(out.len(), 8);
        n.output_bus("OUT", out);
        let r = n.cost_report();
        assert_eq!(r.count(crate::cells::CellKind::HalfAdder), 3);
        assert_eq!(r.count(crate::cells::CellKind::FullAdder), 3);
        let mut st = Stepper::new(&n);
        // Exhaustive over the reachable D&C domain: a = W·y_lo, b = W·y_hi.
        for w in 0..16u64 {
            for ylo in 0..4u64 {
                for yhi in 0..4u64 {
                    let mut stim = to_bits(w * ylo, 6);
                    stim.extend(to_bits(w * yhi, 6));
                    let res = st.step(&n, &stim);
                    assert_eq!(from_bits(&res.outputs), w * ylo + ((w * yhi) << 2));
                }
            }
        }
    }

    #[test]
    fn plain_lut_image_matches_products() {
        let img = lut4_plain_image(5, 6);
        assert_eq!(img.len(), 24);
        // entry 2 (w*2 = 10): bits 12..18
        assert_eq!(from_bits(&img[12..18]), 10);
    }
}
