//! Unified handle over every 4-bit multiplier configuration.

use super::{approx, approx2, array_mult, dnc, dnc_opt, traditional};
use crate::cells::CostReport;
use crate::logic::Netlist;
use std::fmt;

/// Every multiplier configuration the paper evaluates (plus the digital
/// array baseline and the exact "IDEAL" reference of Fig 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MultiplierKind {
    /// Exact arithmetic (paper's "IDEAL"); no LUT hardware.
    Ideal,
    /// Fig 1 — traditional full-LUT.
    Traditional,
    /// Fig 2 — divide & conquer.
    Dnc,
    /// Fig 3 — optimized D&C (shared LUT rows).
    DncOpt,
    /// Fig 9 — ApproxD&C with Z_LSB = 0.
    Approx,
    /// Fig 10 — ApproxD&C 2 with Z_LSB = W.
    Approx2,
    /// Conventional digital array multiplier (baseline).
    ArrayMult,
}

impl MultiplierKind {
    pub const ALL: [MultiplierKind; 7] = [
        MultiplierKind::Ideal,
        MultiplierKind::Traditional,
        MultiplierKind::Dnc,
        MultiplierKind::DncOpt,
        MultiplierKind::Approx,
        MultiplierKind::Approx2,
        MultiplierKind::ArrayMult,
    ];

    /// The LUT-based configurations of the paper's Fig 16 comparison.
    pub const PAPER_CONFIGS: [MultiplierKind; 5] = [
        MultiplierKind::Traditional,
        MultiplierKind::Dnc,
        MultiplierKind::DncOpt,
        MultiplierKind::Approx,
        MultiplierKind::Approx2,
    ];

    /// Stable kebab-case identifier (artifact filenames, CLI, config).
    pub fn slug(self) -> &'static str {
        match self {
            MultiplierKind::Ideal => "ideal",
            MultiplierKind::Traditional => "traditional",
            MultiplierKind::Dnc => "dnc",
            MultiplierKind::DncOpt => "dnc-opt",
            MultiplierKind::Approx => "approx",
            MultiplierKind::Approx2 => "approx2",
            MultiplierKind::ArrayMult => "array-mult",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<MultiplierKind> {
        let s = s.trim().to_ascii_lowercase();
        Self::ALL.iter().copied().find(|k| k.slug() == s)
    }

    pub fn name(self) -> &'static str {
        match self {
            MultiplierKind::Ideal => "IDEAL",
            MultiplierKind::Traditional => "Traditional LUT",
            MultiplierKind::Dnc => "D&C",
            MultiplierKind::DncOpt => "Optimized D&C",
            MultiplierKind::Approx => "ApproxD&C",
            MultiplierKind::Approx2 => "ApproxD&C 2",
            MultiplierKind::ArrayMult => "Array multiplier",
        }
    }

    /// Behavioural 4b×4b product under this configuration — the arithmetic
    /// the paper's MATLAB analysis uses (Fig 13).
    pub fn value(self, w: u8, y: u8) -> u8 {
        match self {
            MultiplierKind::Ideal => super::ideal_value(w, y),
            MultiplierKind::Traditional => traditional::value(w, y),
            MultiplierKind::Dnc => dnc::value(w, y),
            MultiplierKind::DncOpt => dnc_opt::value(w, y),
            MultiplierKind::Approx => approx::value(w, y),
            MultiplierKind::Approx2 => approx2::value(w, y),
            MultiplierKind::ArrayMult => array_mult::value(w, y),
        }
    }

    /// Signed error vs the exact product.
    pub fn error(self, w: u8, y: u8) -> i32 {
        super::ideal_value(w, y) as i32 - self.value(w, y) as i32
    }

    /// Whether this configuration computes exact products.
    pub fn is_exact(self) -> bool {
        !matches!(self, MultiplierKind::Approx | MultiplierKind::Approx2)
    }

    /// Structural netlist (None for the hardware-less IDEAL reference).
    pub fn netlist(self) -> Option<Netlist> {
        match self {
            MultiplierKind::Ideal => None,
            MultiplierKind::Traditional => Some(traditional::netlist(4)),
            MultiplierKind::Dnc => Some(dnc::netlist()),
            MultiplierKind::DncOpt => Some(dnc_opt::netlist()),
            MultiplierKind::Approx => Some(approx::netlist()),
            MultiplierKind::Approx2 => Some(approx2::netlist()),
            MultiplierKind::ArrayMult => Some(array_mult::netlist(4)),
        }
    }

    /// SRAM programming image for weight `w` (None for IDEAL).
    pub fn program_image(self, w: u8) -> Option<Vec<bool>> {
        match self {
            MultiplierKind::Ideal => None,
            MultiplierKind::Traditional => Some(traditional::program_image(4, w as u64)),
            MultiplierKind::Dnc => Some(dnc::program_image(w)),
            MultiplierKind::DncOpt => Some(dnc_opt::program_image(w)),
            MultiplierKind::Approx => Some(approx::program_image(w)),
            MultiplierKind::Approx2 => Some(approx2::program_image(w)),
            MultiplierKind::ArrayMult => Some(array_mult::program_image(4, w as u64)),
        }
    }

    /// Component cost (empty for IDEAL).
    pub fn cost(self) -> CostReport {
        match self {
            MultiplierKind::Ideal => CostReport::new(),
            MultiplierKind::Traditional => traditional::cost(4),
            MultiplierKind::Dnc => dnc::cost(),
            MultiplierKind::DncOpt => dnc_opt::cost(),
            MultiplierKind::Approx => approx::cost(),
            MultiplierKind::Approx2 => approx2::cost(),
            MultiplierKind::ArrayMult => array_mult::cost(4),
        }
    }
}

impl fmt::Display for MultiplierKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A programmed behavioural multiplier — literally a 256-entry lookup
/// table (all 16×16 variant products precomputed at construction), which
/// is both the fast path for the NN substrate / coordinator cost model
/// and the software image of what the paper builds in SRAM.
#[derive(Debug, Clone, Copy)]
pub struct MultiplierModel {
    pub kind: MultiplierKind,
    table: [u8; 256],
}

impl MultiplierModel {
    pub fn new(kind: MultiplierKind) -> Self {
        let mut table = [0u8; 256];
        for w in 0..16u8 {
            for y in 0..16u8 {
                table[((w as usize) << 4) | y as usize] = kind.value(w, y);
            }
        }
        MultiplierModel { kind, table }
    }

    /// Product of 4-bit `w` and `y` under this configuration (one load).
    ///
    /// Both operands are masked to 4 bits: out-of-range codes are a caller
    /// bug, but they must neither read out of bounds nor panic in release
    /// builds — they wrap, exactly like the SRAM row decoder would.
    #[inline]
    pub fn mul(&self, w: u8, y: u8) -> u8 {
        self.table[(((w & 0xf) as usize) << 4) | (y & 0xf) as usize]
    }

    /// The full 256-entry product table, indexed `(w << 4) | y`. This is
    /// the flat-gather fast path the batched LUT-GEMM uses: one bounds
    /// check hoisted by the type, no per-element masking.
    #[inline]
    pub fn table(&self) -> &[u8; 256] {
        &self.table
    }

    /// Dot product of 4-bit vectors under this configuration (the MAC the
    /// paper's Fig 1 frames: per-element LUT products, exact accumulation).
    #[inline]
    pub fn dot(&self, w: &[u8], y: &[u8]) -> u32 {
        assert_eq!(w.len(), y.len());
        w.iter().zip(y).map(|(&a, &b)| self.mul(a, b) as u32).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn netlists_match_behavioural_for_all_kinds() {
        use crate::logic::{from_bits, to_bits, Stepper};
        for kind in MultiplierKind::ALL {
            let Some(netlist) = kind.netlist() else { continue };
            let mut st = Stepper::new(&netlist);
            for w in 0..16u8 {
                st.program(&kind.program_image(w).unwrap());
                for y in 0..16u8 {
                    let got = {
                        let res = st.step(&netlist, &to_bits(y as u64, 4));
                        from_bits(&res.outputs) as u8
                    };
                    let want = match kind {
                        // the circuit drops the carry into bit 7 (Fig 10)
                        MultiplierKind::Approx2 => crate::multiplier::approx2::hw_value(w, y),
                        _ => kind.value(w, y),
                    };
                    assert_eq!(got, want, "{kind} w={w} y={y}");
                }
            }
        }
    }

    #[test]
    fn exactness_flags() {
        for kind in MultiplierKind::ALL {
            let exact = (0..16u8)
                .all(|w| (0..16u8).all(|y| kind.value(w, y) == w * y));
            assert_eq!(exact, kind.is_exact(), "{kind}");
        }
    }

    #[test]
    fn dot_product_accumulates() {
        let m = MultiplierModel::new(MultiplierKind::Ideal);
        assert_eq!(m.dot(&[1, 2, 3], &[4, 5, 6]), 4 + 10 + 18);
    }

    #[test]
    fn mul_masks_both_out_of_range_operands() {
        for kind in MultiplierKind::ALL {
            let m = MultiplierModel::new(kind);
            // both operands wrap identically — no panic, no OOB read
            assert_eq!(m.mul(0x1f, 0x2f), m.mul(0xf, 0xf), "{kind}");
            assert_eq!(m.mul(16, 3), m.mul(0, 3), "{kind}");
            assert_eq!(m.mul(3, 16), m.mul(3, 0), "{kind}");
            assert_eq!(m.mul(255, 255), m.mul(15, 15), "{kind}");
        }
    }

    #[test]
    fn table_matches_mul_for_all_pairs() {
        let m = MultiplierModel::new(MultiplierKind::Approx2);
        let table = m.table();
        for w in 0..16u8 {
            for y in 0..16u8 {
                assert_eq!(table[((w as usize) << 4) | y as usize], m.mul(w, y));
            }
        }
    }
}
