//! Conventional digital array multiplier — the non-LUT baseline.
//!
//! The paper's Digital-CiM discussion (§II) contrasts LUT lookup against
//! conventional in-memory arithmetic. This module provides that comparator:
//! a classic unsigned array multiplier (AND partial products + HA/FA
//! reduction rows) with the weight held in SRAM (weight-stationary, like
//! the LUT configs), so area/energy comparisons are apples-to-apples.
//!
//! For k = 4 the canonical costs are 16 AND2, 8 FA, 4 HA (+ 4 SRAM bits
//! for the stationary weight).

use crate::cells::CostReport;
use crate::logic::{Bus, NetId, Netlist};

/// Behavioural model — exact product.
pub fn value(w: u8, y: u8) -> u8 {
    super::ideal_value(w, y)
}

/// Structural netlist of the k×k array multiplier. Inputs: `Y` (k bits).
/// SRAM: `W` (k bits, weight-stationary). Output: `OUT` (2k bits).
pub fn netlist(k: u32) -> Netlist {
    assert!((2..=8).contains(&k));
    let k = k as usize;
    let mut n = Netlist::default();
    let y = n.input_bus("Y", k);
    let w: Bus = n.sram_bus(k);

    // Partial products pp[i][j] = w[j] & y[i]; row i carries weight 2^i.
    let pp: Vec<Bus> =
        (0..k).map(|i| (0..k).map(|j| n.and2(w[j], y[i])).collect()).collect();

    // Ripple reduction row by row. Entering iteration i, `acc[j]` holds
    // result bit (i-1)+j; its lowest bit is final and moves to `out`.
    let mut out: Bus = Vec::with_capacity(2 * k);
    let mut acc: Bus = pp[0].clone();
    for row in pp.iter().skip(1) {
        out.push(acc[0]);
        let prev: Vec<NetId> = acc[1..].to_vec();
        let mut next: Bus = Vec::with_capacity(k + 1);
        let mut carry: Option<NetId> = None;
        for j in 0..prev.len().max(k) {
            let a = prev.get(j).copied();
            let b = row.get(j).copied();
            let (s, c) = match (a, b, carry) {
                (Some(a), Some(b), None) => {
                    let (s, c) = n.half_adder(a, b);
                    (s, Some(c))
                }
                (Some(a), Some(b), Some(cin)) => {
                    let (s, c) = n.full_adder(a, b, cin);
                    (s, Some(c))
                }
                (Some(x), None, Some(cin)) | (None, Some(x), Some(cin)) => {
                    let (s, c) = n.half_adder(x, cin);
                    (s, Some(c))
                }
                (Some(x), None, None) | (None, Some(x), None) => (x, None),
                (None, None, _) => unreachable!("loop bounded by operand widths"),
            };
            next.push(s);
            carry = c;
        }
        if let Some(c) = carry {
            next.push(c);
        }
        acc = next;
    }
    out.extend(acc);
    n.output_bus("OUT", out);
    n
}

/// Programming image: the k weight bits.
pub fn program_image(k: u32, w: u64) -> Vec<bool> {
    assert!(w < (1u64 << k));
    crate::logic::to_bits(w, k as usize)
}

/// Component cost of the k-bit array multiplier netlist.
pub fn cost(k: u32) -> CostReport {
    netlist(k).cost_report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::CellKind;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn matches_ideal_exhaustively_4b() {
        let n = netlist(4);
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(4, w as u64));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(from_bits(&res.outputs) as u8, value(w, y), "w={w} y={y}");
            }
        }
    }

    #[test]
    fn matches_product_3b_and_5b() {
        for k in [3u32, 5] {
            let n = netlist(k);
            let mut st = Stepper::new(&n);
            for w in 0..(1u64 << k) {
                st.program(&program_image(k, w));
                for y in 0..(1u64 << k) {
                    let res = st.step(&n, &to_bits(y, k as usize));
                    assert_eq!(from_bits(&res.outputs), w * y, "k={k} w={w} y={y}");
                }
            }
        }
    }

    #[test]
    fn canonical_4b_costs() {
        let r = cost(4);
        assert_eq!(r.count(CellKind::And2), 16);
        assert_eq!(r.count(CellKind::SramCell), 4);
        // first reduction row: 2 HA + 2 FA; two more rows: 1 HA + 3 FA each
        assert_eq!(r.count(CellKind::HalfAdder), 4);
        assert_eq!(r.count(CellKind::FullAdder), 8);
    }
}
