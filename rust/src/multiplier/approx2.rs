//! ApproxD&C 2 — paper Fig 10.
//!
//! The LSB-side product is approximated *as a function of W*: `Z_LSB ≈ W`
//! (the four LSBs of Z_LSB wired to `W`, the two MSBs to 0). This balances
//! the error distribution around zero (Figs 11/12: error `W·(y_lo − 1)` ∈
//! [−15, 30]) at the cost of a small adder.
//!
//! Paper totals: **12 SRAM, 18 mux, 4 HA, 1 FA**. The paper argues that
//! because `max Z_MSB = 101101` the most-significant half-adder never
//! carries out, so `OUT₇` is taken directly from `Z_MSB₅`. That argument
//! has a corner case (see [`MSB_SHORTCUT_MISMATCHES`]): for 8 of the 256
//! input pairs a carry *does* reach bit 7 and the shortcut output differs
//! from the full sum `(Z_MSB << 2) + W`. We implement the circuit exactly
//! as the paper describes and expose both arithmetic models.

use super::parts;
use crate::cells::{CellKind, CostReport};
use crate::logic::Netlist;

/// Arithmetic model used by the paper's MATLAB analysis (Figs 11–13):
/// the full sum `(Z_MSB << 2) + W`.
pub fn value(w: u8, y: u8) -> u8 {
    (((super::z_msb(w, y) as u16) << 2) + super::check4(w) as u16) as u8
}

/// Bit-exact model of the paper's Fig 10 *circuit*, where `OUT₇ = Z_MSB₅`
/// (the carry into bit 7 is dropped). Differs from [`value`] on exactly
/// [`MSB_SHORTCUT_MISMATCHES`] of the 256 input pairs.
pub fn hw_value(w: u8, y: u8) -> u8 {
    let full = ((super::z_msb(w, y) as u16) << 2) + super::check4(w) as u16;
    let msb = (super::z_msb(w, y) >> 5) & 1;
    ((full as u8) & 0x7f) | (msb << 7)
}

/// Number of (w, y) pairs where the paper's MSB shortcut loses a carry:
/// `(w=10, y_hi=3)` and `(w=15, y_hi=2)`, each across 4 values of `y_lo`.
pub const MSB_SHORTCUT_MISMATCHES: usize = 8;

/// Paper component counts (Fig 10 caption).
pub fn cost() -> CostReport {
    CostReport::from_pairs(&[
        (CellKind::SramCell, 12),
        (CellKind::Mux2, 18),
        (CellKind::HalfAdder, 4),
        (CellKind::FullAdder, 1),
    ])
}

/// Structural netlist per Fig 10. Inputs: `Y` (4 bits). SRAM: 12 bits
/// (shared LUT + two zero-rail cells feeding `Z_LSB[5:4]`, the paper's
/// count). Output: `OUT` (8 bits).
pub fn netlist() -> Netlist {
    let mut n = Netlist::default();
    let y = n.input_bus("Y", 4);
    let lut = parts::lut4_shared(&mut n, 4);
    let z_msb = parts::chunk_unit(&mut n, &lut.entries, y[2], y[3]);
    // Z_LSB := W. The stored W row is reused for bits 0..3; two dedicated
    // zero cells pad bits 4..5 (fanout copies — the paper counts 12 SRAMs).
    let w_row: Vec<crate::logic::NetId> = n.sram_bits[1..5].to_vec(); // stored W bits inside the LUT
    let _pad0 = n.sram_bit();
    let _pad1 = n.sram_bit();

    // Adder per the paper: OUT0,1 = W0,W1; HA at bit2; FA at bit3;
    // HA chain at bits 4..6; OUT7 = Z_MSB5 directly (shortcut).
    let mut out = vec![w_row[0], w_row[1]];
    let (s2, c2) = n.half_adder(z_msb[0], w_row[2]);
    out.push(s2);
    let (s3, c3) = n.full_adder(z_msb[1], w_row[3], c2);
    out.push(s3);
    let (s4, c4) = n.half_adder(z_msb[2], c3);
    out.push(s4);
    let (s5, c5) = n.half_adder(z_msb[3], c4);
    out.push(s5);
    let (s6, _c6) = n.half_adder(z_msb[4], c5);
    out.push(s6);
    out.push(z_msb[5]); // the paper's shortcut: no carry into bit 7
    n.output_bus("OUT", out);
    n
}

/// Programming image: shared LUT (10 bits) + two zero pads = 12 bits.
pub fn program_image(w: u8) -> Vec<bool> {
    let mut bits = parts::lut4_shared_image(super::check4(w) as u64, 4);
    bits.push(false);
    bits.push(false);
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn cost_matches_paper_fig10() {
        assert_eq!(netlist().cost_report(), cost());
    }

    #[test]
    fn netlist_matches_hw_model_exhaustively() {
        let n = netlist();
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(w));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(from_bits(&res.outputs) as u8, hw_value(w, y), "w={w} y={y}");
            }
        }
    }

    #[test]
    fn msb_shortcut_mismatch_set_is_exactly_8_pairs() {
        let mut mismatches = Vec::new();
        for w in 0..16u8 {
            for y in 0..16u8 {
                if value(w, y) != hw_value(w, y) {
                    mismatches.push((w, y));
                }
            }
        }
        assert_eq!(mismatches.len(), MSB_SHORTCUT_MISMATCHES);
        // All mismatches are the two (w, y_hi) corners the doc comment names.
        for (w, y) in mismatches {
            let y_hi = y >> 2;
            assert!(
                (w == 10 && y_hi == 3) || (w == 15 && y_hi == 2),
                "unexpected mismatch at w={w} y={y}"
            );
        }
    }

    #[test]
    fn error_range_matches_fig12() {
        // Fig 12: error spans −15 .. 30 (= W·(y_lo − 1)).
        let mut lo = i32::MAX;
        let mut hi = i32::MIN;
        for w in 0..16u8 {
            for y in 0..16u8 {
                let err = super::super::ideal_value(w, y) as i32 - value(w, y) as i32;
                assert_eq!(err, w as i32 * ((y & 3) as i32 - 1));
                lo = lo.min(err);
                hi = hi.max(err);
            }
        }
        assert_eq!((lo, hi), (-15, 30));
    }
}
