//! Optimized D&C LUT multiplier — paper Fig 3.
//!
//! Same D&C decomposition as [`super::dnc`] but with the shared-row LUT:
//! only `W×01` (= `W`), the MSBs of `W×11`, and a zero rail are stored;
//! `W×10` is a wired shift. Paper totals: **10 SRAM, 36 mux, 3 HA, 3 FA**.

use super::parts;
use crate::cells::{CellKind, CostReport};
use crate::logic::Netlist;

/// Behavioural model — exact (identical arithmetic to Fig 2).
pub fn value(w: u8, y: u8) -> u8 {
    super::dnc::value(w, y)
}

/// Paper component counts (Fig 3 caption).
pub fn cost() -> CostReport {
    CostReport::from_pairs(&[
        (CellKind::SramCell, 10),
        (CellKind::Mux2, 36),
        (CellKind::HalfAdder, 3),
        (CellKind::FullAdder, 3),
    ])
}

/// Structural netlist. Inputs: `Y` (4 bits). SRAM: 10 bits (see
/// [`program_image`]). Output: `OUT` (8 bits).
pub fn netlist() -> Netlist {
    let mut n = Netlist::default();
    let y = n.input_bus("Y", 4);
    let lut = parts::lut4_shared(&mut n, 4);
    let z_lsb = parts::chunk_unit(&mut n, &lut.entries, y[0], y[1]);
    let z_msb = parts::chunk_unit(&mut n, &lut.entries, y[2], y[3]);
    let out = parts::add_shifted(&mut n, &z_lsb, &z_msb, 2);
    n.output_bus("OUT", out);
    n
}

/// Programming image: `[0, W₀..W₃, ((3W)>>1)₀..₄]` — 10 bits.
pub fn program_image(w: u8) -> Vec<bool> {
    parts::lut4_shared_image(super::check4(w) as u64, 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn netlist_cost_matches_paper_fig3() {
        let r = netlist().cost_report();
        assert_eq!(r, cost());
    }

    #[test]
    fn netlist_matches_ideal_exhaustively() {
        let n = netlist();
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(w));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(
                    from_bits(&res.outputs) as u8,
                    super::super::ideal_value(w, y),
                    "w={w} y={y}"
                );
            }
        }
    }

    #[test]
    fn storage_reduction_vs_traditional_is_12_8x() {
        // Paper: "the number of storage elements has significantly
        // decreased from 128 to 24" (D&C) and to 10 (optimized).
        assert_eq!(super::super::traditional::sram_bits(4), 128);
        assert_eq!(cost().count(CellKind::SramCell), 10);
    }
}
