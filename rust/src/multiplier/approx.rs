//! ApproxD&C — paper Figs 4 & 9.
//!
//! The LSB-side product is replaced by a fixed value `Z_LSB ≈ const`.
//! Fig 5/6 of the paper establish that `0` is the optimal constant (it has
//! the highest occurrence probability, 19/64 ≈ 0.2969, and the lowest mean
//! per-bit Hamming distance, 0.275). Two structures:
//!
//! * [`netlist_fig4`] — generic fixed value wired from two storage rails
//!   (a `0` bit and a `1` bit): **12 SRAM, 18 mux, 3 HA, 3 FA**;
//! * [`netlist`] — the final Fig 9 form with `Z_LSB = 0`, where the adder
//!   disappears entirely: **10 SRAM, 18 mux**, output is `Z_MSB << 2`.

use super::parts;
use crate::cells::{CellKind, CostReport};
use crate::logic::Netlist;

/// Behavioural model of the final (Fig 9) structure: `Z_LSB = 0`.
pub fn value(w: u8, y: u8) -> u8 {
    super::z_msb(w, y) << 2
}

/// Behavioural model of the Fig 4 structure with an arbitrary fixed
/// `Z_LSB` (6-bit). Saturating at 8 bits never occurs for the optimal 0.
pub fn value_fixed(w: u8, y: u8, fixed_zlsb: u8) -> u8 {
    assert!(fixed_zlsb < 64);
    (((super::z_msb(w, y) as u16) << 2) + fixed_zlsb as u16).min(255) as u8
}

/// Paper component counts for the final Fig 9 structure.
pub fn cost() -> CostReport {
    CostReport::from_pairs(&[(CellKind::SramCell, 10), (CellKind::Mux2, 18)])
}

/// Paper component counts for the Fig 4 structure.
pub fn cost_fig4() -> CostReport {
    CostReport::from_pairs(&[
        (CellKind::SramCell, 12),
        (CellKind::Mux2, 18),
        (CellKind::HalfAdder, 3),
        (CellKind::FullAdder, 3),
    ])
}

/// Final ApproxD&C netlist (Fig 9): MSB-side unit only; `OUT = Z_MSB << 2`.
pub fn netlist() -> Netlist {
    let mut n = Netlist::default();
    let y = n.input_bus("Y", 4);
    let lut = parts::lut4_shared(&mut n, 4);
    let z_msb = parts::chunk_unit(&mut n, &lut.entries, y[2], y[3]);
    let zero = n.constant(false);
    let mut out = vec![zero, zero];
    out.extend(z_msb);
    n.output_bus("OUT", out);
    n
}

/// Fig 4 netlist: MSB-side unit plus a fixed `Z_LSB` pattern wired from two
/// storage rails (one `0` cell, one `1` cell — the paper's "only 2 bits of
/// storage" for the LSB side), combined by the usual shifted adder.
pub fn netlist_fig4(fixed_zlsb: u8) -> Netlist {
    assert!(fixed_zlsb < 64);
    let mut n = Netlist::default();
    let y = n.input_bus("Y", 4);
    let lut = parts::lut4_shared(&mut n, 4);
    let z_msb = parts::chunk_unit(&mut n, &lut.entries, y[2], y[3]);
    // LSB side: two rail cells, pattern selected by wiring.
    let rail0 = n.sram_bit(); // programmed 0
    let rail1 = n.sram_bit(); // programmed 1
    let z_lsb: Vec<_> =
        (0..6).map(|i| if (fixed_zlsb >> i) & 1 == 1 { rail1 } else { rail0 }).collect();
    let out = parts::add_shifted(&mut n, &z_lsb, &z_msb, 2);
    n.output_bus("OUT", out);
    n
}

/// Programming image for [`netlist`] (10 bits, shared-LUT layout).
pub fn program_image(w: u8) -> Vec<bool> {
    parts::lut4_shared_image(super::check4(w) as u64, 4)
}

/// Programming image for [`netlist_fig4`] (12 bits: shared LUT + rails).
pub fn program_image_fig4(w: u8) -> Vec<bool> {
    let mut bits = parts::lut4_shared_image(super::check4(w) as u64, 4);
    bits.push(false); // rail0
    bits.push(true); // rail1
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn costs_match_paper() {
        assert_eq!(netlist().cost_report(), cost());
        assert_eq!(netlist_fig4(0b101).cost_report(), cost_fig4());
    }

    #[test]
    fn final_netlist_matches_behavioural() {
        let n = netlist();
        let mut st = Stepper::new(&n);
        for w in 0..16u8 {
            st.program(&program_image(w));
            for y in 0..16u8 {
                let res = st.step(&n, &to_bits(y as u64, 4));
                assert_eq!(from_bits(&res.outputs) as u8, value(w, y), "w={w} y={y}");
            }
        }
    }

    #[test]
    fn fig4_netlist_matches_behavioural_for_sampled_constants() {
        for fixed in [0u8, 1, 5, 12, 33, 45] {
            let n = netlist_fig4(fixed);
            let mut st = Stepper::new(&n);
            for w in 0..16u8 {
                st.program(&program_image_fig4(w));
                for y in 0..16u8 {
                    let res = st.step(&n, &to_bits(y as u64, 4));
                    assert_eq!(
                        from_bits(&res.outputs) as u8,
                        value_fixed(w, y, fixed),
                        "fixed={fixed} w={w} y={y}"
                    );
                }
            }
        }
    }

    #[test]
    fn error_vs_exact_is_z_lsb() {
        // Fig 7/8: the ApproxD&C error is exactly the discarded Z_LSB,
        // ranging over 0..=45.
        let mut max = 0i32;
        for w in 0..16u8 {
            for y in 0..16u8 {
                let err = super::super::ideal_value(w, y) as i32 - value(w, y) as i32;
                assert_eq!(err, super::super::z_lsb(w, y) as i32);
                assert!(err >= 0);
                max = max.max(err);
            }
        }
        assert_eq!(max, 45);
    }
}
