//! Configuration system for the LUNA-CiM serving stack.
//!
//! All knobs live in one struct so runs are reproducible: `repro serve
//! --config luna.conf` and every example load the same `key value` format
//! (see [`crate::util::kv`]); CLI flags override file values. Unknown keys
//! are rejected to catch typos.

use crate::multiplier::MultiplierKind;
use crate::nn::{GemmOptions, GemmPartition, GemmSimd};
use crate::util::kv::KvMap;
use crate::Result;
use anyhow::{bail, Context};
use std::path::Path;

/// Which execution backend the worker pool runs batches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// In-process batched LUT-GEMM over the quantized model (default;
    /// zero external dependencies — no HLO artifacts, no `xla` crate).
    Native,
    /// Native numerics plus per-worker `Tiler` schedule replay: every
    /// batch is priced on the simulated LUNA fabric, the cost rides on
    /// each reply, and `timing.time_scale` optionally gates replies on
    /// the simulated latency.
    Calibrated,
    /// AOT-compiled HLO through PJRT (requires the `pjrt` cargo feature
    /// and `make artifacts`).
    Pjrt,
}

impl BackendKind {
    /// Stable kebab-case identifier (config files, CLI).
    pub fn slug(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Calibrated => "calibrated",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "native" => Some(BackendKind::Native),
            "calibrated" => Some(BackendKind::Calibrated),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// Parse a slug with the canonical error message (CLI / config use
    /// this so the known-backend list lives in one place).
    pub fn from_arg(s: &str) -> Result<BackendKind> {
        Self::parse_slug(s).ok_or_else(|| {
            anyhow::anyhow!("unknown backend `{s}` (known: native, calibrated, pjrt)")
        })
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Artifact directory (output of `make artifacts`).
    pub artifacts_dir: String,
    /// Multiplier configuration for the LUNA banks / model variant.
    /// Note: `ideal` is a behavioural model with no hardware netlist —
    /// the tiler prices its schedules with `dnc-opt` unit costs (logged
    /// once at tiler construction).
    pub multiplier: MultiplierKind,
    /// Execution backend (`native` | `calibrated` | `pjrt`).
    pub backend: BackendKind,
    pub batcher: BatcherConfig,
    pub workers: WorkerConfig,
    pub banks: BankConfig,
    pub timing: TimingConfig,
    pub gemm: GemmConfig,
    pub net: NetConfig,
    pub loadgen: LoadgenConfig,
    pub router: RouterConfig,
    pub serving: ServingConfig,
    pub plan_cache: PlanCacheConfig,
    pub trace: TraceConfig,
}

/// Multi-tenant serving: which model artifacts one server hosts beside
/// the default model (see [`crate::coordinator::server`]).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ServingConfig {
    /// Extra models as `(id, artifacts_dir)` pairs. Config/CLI syntax:
    /// `serving.models ida=dirA,idb=dirB`. Empty (default) = only the
    /// default model (`artifacts_dir`). Every model's geometry (dims,
    /// lowered batch) must match the default model's; ids must be
    /// unique, non-empty and at most
    /// [`crate::net::protocol::MAX_MODEL_ID`] bytes. More models can be
    /// hot-loaded at runtime via the `LoadModel` admin frame.
    pub models: Vec<(String, String)>,
}

/// Compiled-plan cache sizing (see [`crate::engine::PlanCache`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCacheConfig {
    /// Byte budget across all cached compiled plans (weights + LUT-GEMM
    /// plan heap bytes). Least-recently-used models are evicted (and
    /// recompiled on their next request) once the budget is exceeded;
    /// a single over-budget model is served uncached.
    pub max_bytes: usize,
}

impl Default for PlanCacheConfig {
    fn default() -> Self {
        PlanCacheConfig { max_bytes: 64 << 20 }
    }
}

/// Per-process flight-recorder sizing (see [`crate::util::trace`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Span-ring capacity (entries). The ring is pre-allocated at
    /// startup and overwrites oldest-first, so this bounds both memory
    /// (~48 B/entry) and the `DumpTrace` payload.
    pub ring_capacity: usize,
    /// Sample 1-in-N requests at ingress (`0` disables tracing; `1`
    /// traces everything). Only the sampling decision is per-request —
    /// recording a span for a sampled request is a few Relaxed atomics.
    pub sample_every: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { ring_capacity: 1024, sample_every: 8 }
    }
}

/// How requests map onto batcher shards (see
/// [`BatcherConfig::affinity`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAffinity {
    /// Request-id round-robin: consecutive requests spread across
    /// shards regardless of which connection sent them (the historical
    /// default — maximum lane utilization under few connections).
    Request,
    /// Connection-id affine: every request from one connection lands on
    /// the same shard, so a connection's traffic keeps one batcher lane
    /// (and its worker rotation) warm — cache affinity over spread.
    Connection,
}

impl ShardAffinity {
    /// Stable kebab-case identifier (config files, CLI).
    pub fn slug(self) -> &'static str {
        match self {
            ShardAffinity::Request => "request",
            ShardAffinity::Connection => "connection",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<ShardAffinity> {
        match s.trim().to_ascii_lowercase().as_str() {
            "request" => Some(ShardAffinity::Request),
            "connection" => Some(ShardAffinity::Connection),
            _ => None,
        }
    }

    /// Parse with the canonical error message.
    pub fn from_arg(s: &str) -> Result<ShardAffinity> {
        Self::parse_slug(s).ok_or_else(|| {
            anyhow::anyhow!("unknown shard affinity `{s}` (known: request, connection)")
        })
    }
}

/// How the router tier picks a backend per request (see
/// [`crate::net::router`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Consistent hash on the client connection id over a vnode ring:
    /// one connection's requests stick to one backend (cache/weight-
    /// stationary affinity), and backend removal remaps only ~1/N of
    /// connections (minimal disruption).
    Hash,
    /// Pick the connected backend with the fewest in-flight requests:
    /// best load spreading, no affinity.
    LeastOutstanding,
}

impl DispatchPolicy {
    /// Stable kebab-case identifier (config files, CLI).
    pub fn slug(self) -> &'static str {
        match self {
            DispatchPolicy::Hash => "hash",
            DispatchPolicy::LeastOutstanding => "least-outstanding",
        }
    }

    /// Parse a slug (case-insensitive).
    pub fn parse_slug(s: &str) -> Option<DispatchPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "hash" => Some(DispatchPolicy::Hash),
            "least-outstanding" => Some(DispatchPolicy::LeastOutstanding),
            _ => None,
        }
    }

    /// Parse with the canonical error message.
    pub fn from_arg(s: &str) -> Result<DispatchPolicy> {
        Self::parse_slug(s).ok_or_else(|| {
            anyhow::anyhow!("unknown dispatch policy `{s}` (known: hash, least-outstanding)")
        })
    }
}

/// Front-tier router knobs (`repro route`; see [`crate::net::router`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouterConfig {
    /// TCP listen address of the router front tier (port `0` =
    /// OS-assigned). Empty (the default) = no router; `repro route`
    /// defaults it to `127.0.0.1:0` when unset.
    pub listen: String,
    /// Backend endpoints (`repro serve --listen` addresses) the router
    /// load-balances across. At most 64 (per-request routing state is a
    /// 64-bit tried mask).
    pub backends: Vec<String>,
    /// Dispatch policy: `hash` (default) or `least-outstanding`.
    pub policy: DispatchPolicy,
    /// Virtual nodes per backend on the consistent-hash ring (more =
    /// smoother key distribution, larger ring).
    pub vnodes: usize,
    /// Client-connection cap at the router front tier.
    pub max_connections: usize,
    /// Base health-probe / reconnect period (ms); failed backends back
    /// off exponentially from here.
    pub probe_ms: u64,
    /// Ceiling on the reconnect backoff (ms).
    pub max_backoff_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            listen: String::new(),
            backends: Vec::new(),
            policy: DispatchPolicy::Hash,
            vnodes: 160,
            max_connections: 64,
            probe_ms: 100,
            max_backoff_ms: 2000,
        }
    }
}

/// Dynamic batching policy.
#[derive(Debug, Clone, PartialEq)]
pub struct BatcherConfig {
    /// Maximum requests per batch (must equal the lowered batch size —
    /// smaller batches are padded).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before flushing (µs).
    pub max_wait_us: u64,
    /// Bound on the pending-request queue (backpressure beyond this).
    pub queue_depth: usize,
    /// Independent batcher lanes: each shard owns its own batcher lock
    /// and waiter map, so connections on different shards never contend.
    /// Admission (`queue_depth`) stays a single global bound across all
    /// shards. `1` (default) = the unsharded batcher. Replies are
    /// bit-identical for every shard count.
    pub shards: usize,
    /// Shard-selection rule: `request` (default, request-id round-robin)
    /// or `connection` (pin each connection's requests to one shard for
    /// lane/cache affinity). Bit-identical replies either way.
    pub affinity: ShardAffinity,
}

/// Execution worker pool.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerConfig {
    /// Number of worker threads, each owning its own backend instance
    /// (native GEMM scratch, or PJRT client/executable).
    pub count: usize,
}

/// LUNA bank provisioning (the simulated CiM fabric the scheduler maps
/// MACs onto).
#[derive(Debug, Clone, PartialEq)]
pub struct BankConfig {
    /// Number of 8×8 arrays (each hosting `units_per_bank` LUNA units).
    pub count: usize,
    /// LUNA units per bank (the paper's maximum: 4).
    pub units_per_bank: usize,
}

/// Planned LUT-GEMM kernel knobs (`backend native` / `calibrated`).
#[derive(Debug, Clone, PartialEq)]
pub struct GemmConfig {
    /// In-batch GEMM threads **per worker**: each worker's planned
    /// kernel fans a batch out across this many persistent pool threads
    /// (spawned once, parked between batches). `0` = one per available
    /// core; `1` (default) keeps the kernel single-threaded — worker
    /// threads already scale across batches, so widen this only for
    /// large batches / wide layers (or when `workers.count` is small).
    /// Ignored by `backend pjrt`.
    pub threads: usize,
    /// Strip-kernel choice (`auto` | `avx2` | `neon` | `swar` |
    /// `scalar`), resolved against the host's runtime dispatch guards
    /// at plan-compile time. Every choice is bit-identical; forcing an
    /// unavailable SIMD kernel falls back to `swar`. Default `auto`.
    pub simd: GemmSimd,
    /// How a multi-threaded plan splits a batch (`auto` | `rows` |
    /// `outputs`): contiguous batch rows for throughput shapes, per-
    /// layer output spans for small-batch latency. `auto` (default)
    /// picks rows when `batch >= gemm.threads`, outputs otherwise.
    pub partition: GemmPartition,
}

impl GemmConfig {
    /// Bundle the `gemm.*` knobs into what [`crate::nn::MlpPlan`]
    /// compiles against.
    pub fn options(&self) -> GemmOptions {
        GemmOptions { threads: self.threads, simd: self.simd, partition: self.partition }
    }
}

/// Wire-protocol front-end knobs (see [`crate::net`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConfig {
    /// TCP listen address for the wire-protocol front-end, e.g.
    /// `127.0.0.1:7077` (port `0` = OS-assigned). Empty (the default) =
    /// no network surface; `repro serve --listen ADDR` overrides.
    pub listen: String,
    /// Accepted-connection cap: further connects are turned away with a
    /// `Rejected` frame before any request is read.
    pub max_connections: usize,
}

/// `repro loadgen` defaults (every knob also has a CLI flag).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoadgenConfig {
    /// Concurrent client connections the generator drives.
    pub connections: usize,
    /// Requests per (scenario, offered-load) case, split across the
    /// connections.
    pub requests_per_level: usize,
    /// Offered-load sweep for the open-loop scenarios (requests/s; the
    /// ≥ 3 levels make the saturation curve of `BENCH_serve.json`).
    pub loads: Vec<usize>,
    /// Burst size for the bursty arrival process.
    pub burst: usize,
    /// Client-side auto-retry: when a request is rejected with a
    /// `retry_after_us` hint, re-send it after the hinted backoff (up to
    /// a bounded number of attempts) and report goodput next to offered
    /// load. Off by default — a raw open loop measures the admission
    /// behaviour itself.
    pub retry: bool,
}

/// Simulated-timing knobs for `backend calibrated`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TimingConfig {
    /// Maps simulated CiM picoseconds to wall-clock: each batch's reply
    /// is delayed by `latency_ps × time_scale` (as wall-clock ps). `0`
    /// (default) = report-only — costs are attached to replies and
    /// metrics but nothing sleeps. `1.0` is "real time"; useful gating
    /// values are ~`1e4`–`1e6`, stretching the schedule into the µs–ms
    /// range. Ignored by `native`/`pjrt`.
    pub time_scale: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: "artifacts".to_string(),
            multiplier: MultiplierKind::DncOpt,
            backend: BackendKind::Native,
            batcher: BatcherConfig::default(),
            workers: WorkerConfig::default(),
            banks: BankConfig::default(),
            timing: TimingConfig::default(),
            gemm: GemmConfig::default(),
            net: NetConfig::default(),
            loadgen: LoadgenConfig::default(),
            router: RouterConfig::default(),
            serving: ServingConfig::default(),
            plan_cache: PlanCacheConfig::default(),
            trace: TraceConfig::default(),
        }
    }
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { listen: String::new(), max_connections: 64 }
    }
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            connections: 4,
            requests_per_level: 2000,
            loads: vec![500, 2000, 8000],
            burst: 32,
            retry: false,
        }
    }
}

impl Default for GemmConfig {
    fn default() -> Self {
        GemmConfig { threads: 1, simd: GemmSimd::Auto, partition: GemmPartition::Auto }
    }
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait_us: 500,
            queue_depth: 1024,
            shards: 1,
            affinity: ShardAffinity::Request,
        }
    }
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig { count: 2 }
    }
}

impl Default for BankConfig {
    fn default() -> Self {
        BankConfig { count: 16, units_per_bank: 4 }
    }
}

/// The set of recognised config keys.
const KNOWN_KEYS: &[&str] = &[
    "artifacts_dir",
    "multiplier",
    "backend",
    "batcher.max_batch",
    "batcher.max_wait_us",
    "batcher.queue_depth",
    "batcher.shards",
    "batcher.affinity",
    "workers.count",
    "banks.count",
    "banks.units_per_bank",
    "timing.time_scale",
    "gemm.threads",
    "gemm.simd",
    "gemm.partition",
    "net.listen",
    "net.max_connections",
    "loadgen.connections",
    "loadgen.requests_per_level",
    "loadgen.loads",
    "loadgen.burst",
    "loadgen.retry",
    "router.listen",
    "router.backends",
    "router.policy",
    "router.vnodes",
    "router.max_connections",
    "router.probe_ms",
    "router.max_backoff_ms",
    "serving.models",
    "plan_cache.max_bytes",
    "trace.ring_capacity",
    "trace.sample_every",
];

impl Config {
    /// Parse from config text (`key value` lines; all keys optional).
    pub fn from_text(text: &str) -> Result<Self> {
        let m = KvMap::parse(text)?;
        // typo protection
        for (key, _) in m.render().lines().filter_map(|l| l.split_once(' ')).map(|(k, v)| (k, v)) {
            if !KNOWN_KEYS.contains(&key) {
                bail!("unknown config key `{key}` (known: {KNOWN_KEYS:?})");
            }
        }
        let mut cfg = Config::default();
        if let Some(v) = m.get_opt("artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = m.get_opt("multiplier") {
            cfg.multiplier = MultiplierKind::parse_slug(v)
                .with_context(|| format!("unknown multiplier `{v}`"))?;
        }
        if let Some(v) = m.get_opt("backend") {
            cfg.backend = BackendKind::from_arg(v)?;
        }
        if m.get_opt("batcher.max_batch").is_some() {
            cfg.batcher.max_batch = m.get_usize("batcher.max_batch")?;
        }
        if m.get_opt("batcher.max_wait_us").is_some() {
            cfg.batcher.max_wait_us = m.get_u64("batcher.max_wait_us")?;
        }
        if m.get_opt("batcher.queue_depth").is_some() {
            cfg.batcher.queue_depth = m.get_usize("batcher.queue_depth")?;
        }
        if m.get_opt("batcher.shards").is_some() {
            cfg.batcher.shards = m.get_usize("batcher.shards")?;
        }
        if let Some(v) = m.get_opt("batcher.affinity") {
            cfg.batcher.affinity = ShardAffinity::from_arg(v)?;
        }
        if m.get_opt("workers.count").is_some() {
            cfg.workers.count = m.get_usize("workers.count")?;
        }
        if m.get_opt("banks.count").is_some() {
            cfg.banks.count = m.get_usize("banks.count")?;
        }
        if m.get_opt("banks.units_per_bank").is_some() {
            cfg.banks.units_per_bank = m.get_usize("banks.units_per_bank")?;
        }
        if m.get_opt("timing.time_scale").is_some() {
            cfg.timing.time_scale = m.get_f64("timing.time_scale")?;
        }
        if m.get_opt("gemm.threads").is_some() {
            cfg.gemm.threads = m.get_usize("gemm.threads")?;
        }
        if let Some(v) = m.get_opt("gemm.simd") {
            cfg.gemm.simd = GemmSimd::from_arg(v)?;
        }
        if let Some(v) = m.get_opt("gemm.partition") {
            cfg.gemm.partition = GemmPartition::from_arg(v)?;
        }
        if let Some(v) = m.get_opt("net.listen") {
            cfg.net.listen = v.to_string();
        }
        if m.get_opt("net.max_connections").is_some() {
            cfg.net.max_connections = m.get_usize("net.max_connections")?;
        }
        if m.get_opt("loadgen.connections").is_some() {
            cfg.loadgen.connections = m.get_usize("loadgen.connections")?;
        }
        if m.get_opt("loadgen.requests_per_level").is_some() {
            cfg.loadgen.requests_per_level = m.get_usize("loadgen.requests_per_level")?;
        }
        if m.get_opt("loadgen.loads").is_some() {
            cfg.loadgen.loads = m.get_usize_list("loadgen.loads")?;
        }
        if m.get_opt("loadgen.burst").is_some() {
            cfg.loadgen.burst = m.get_usize("loadgen.burst")?;
        }
        if let Some(v) = m.get_opt("loadgen.retry") {
            cfg.loadgen.retry = match v.trim() {
                "1" | "true" => true,
                "0" | "false" => false,
                other => bail!("loadgen.retry must be 0/1/true/false, got `{other}`"),
            };
        }
        if let Some(v) = m.get_opt("router.listen") {
            cfg.router.listen = v.to_string();
        }
        if let Some(v) = m.get_opt("router.backends") {
            cfg.router.backends =
                v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
        }
        if let Some(v) = m.get_opt("router.policy") {
            cfg.router.policy = DispatchPolicy::from_arg(v)?;
        }
        if m.get_opt("router.vnodes").is_some() {
            cfg.router.vnodes = m.get_usize("router.vnodes")?;
        }
        if m.get_opt("router.max_connections").is_some() {
            cfg.router.max_connections = m.get_usize("router.max_connections")?;
        }
        if m.get_opt("router.probe_ms").is_some() {
            cfg.router.probe_ms = m.get_u64("router.probe_ms")?;
        }
        if m.get_opt("router.max_backoff_ms").is_some() {
            cfg.router.max_backoff_ms = m.get_u64("router.max_backoff_ms")?;
        }
        if let Some(v) = m.get_opt("serving.models") {
            let mut models = Vec::new();
            for pair in v.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let Some((id, dir)) = pair.split_once('=') else {
                    bail!("serving.models entry `{pair}` is not of the form id=dir");
                };
                models.push((id.trim().to_string(), dir.trim().to_string()));
            }
            cfg.serving.models = models;
        }
        if m.get_opt("plan_cache.max_bytes").is_some() {
            cfg.plan_cache.max_bytes = m.get_usize("plan_cache.max_bytes")?;
        }
        if m.get_opt("trace.ring_capacity").is_some() {
            cfg.trace.ring_capacity = m.get_usize("trace.ring_capacity")?;
        }
        if m.get_opt("trace.sample_every").is_some() {
            cfg.trace.sample_every = m.get_u64("trace.sample_every")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a config file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_text(&text)
    }

    /// Serialize to config text.
    pub fn to_text(&self) -> String {
        let mut m = KvMap::new();
        m.set("artifacts_dir", &self.artifacts_dir);
        m.set("multiplier", self.multiplier.slug());
        m.set("backend", self.backend.slug());
        m.set("batcher.max_batch", self.batcher.max_batch);
        m.set("batcher.max_wait_us", self.batcher.max_wait_us);
        m.set("batcher.queue_depth", self.batcher.queue_depth);
        m.set("batcher.shards", self.batcher.shards);
        m.set("batcher.affinity", self.batcher.affinity.slug());
        m.set("workers.count", self.workers.count);
        m.set("banks.count", self.banks.count);
        m.set("banks.units_per_bank", self.banks.units_per_bank);
        m.set("timing.time_scale", self.timing.time_scale);
        m.set("gemm.threads", self.gemm.threads);
        m.set("gemm.simd", self.gemm.simd.slug());
        m.set("gemm.partition", self.gemm.partition.slug());
        // the kv format has no empty values; empty listen = disabled,
        // so the key is simply absent (the parser defaults it back)
        if !self.net.listen.is_empty() {
            m.set("net.listen", &self.net.listen);
        }
        m.set("net.max_connections", self.net.max_connections);
        m.set("loadgen.connections", self.loadgen.connections);
        m.set("loadgen.requests_per_level", self.loadgen.requests_per_level);
        let loads: Vec<String> = self.loadgen.loads.iter().map(|v| v.to_string()).collect();
        m.set("loadgen.loads", loads.join(","));
        m.set("loadgen.burst", self.loadgen.burst);
        m.set("loadgen.retry", if self.loadgen.retry { 1 } else { 0 });
        // same empty-value rule as net.listen: absent key = disabled
        if !self.router.listen.is_empty() {
            m.set("router.listen", &self.router.listen);
        }
        if !self.router.backends.is_empty() {
            m.set("router.backends", self.router.backends.join(","));
        }
        m.set("router.policy", self.router.policy.slug());
        m.set("router.vnodes", self.router.vnodes);
        m.set("router.max_connections", self.router.max_connections);
        m.set("router.probe_ms", self.router.probe_ms);
        m.set("router.max_backoff_ms", self.router.max_backoff_ms);
        // absent when no extra models are configured (same empty-value rule)
        if !self.serving.models.is_empty() {
            let pairs: Vec<String> =
                self.serving.models.iter().map(|(id, dir)| format!("{id}={dir}")).collect();
            m.set("serving.models", pairs.join(","));
        }
        m.set("plan_cache.max_bytes", self.plan_cache.max_bytes);
        m.set("trace.ring_capacity", self.trace.ring_capacity);
        m.set("trace.sample_every", self.trace.sample_every);
        m.render()
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.batcher.max_batch >= 1, "max_batch must be >= 1");
        // queue_depth may be below max_batch: the queue then fills before
        // the size trigger and `push` backpressures (strict admission);
        // batches still form via the deadline flush, padded to max_batch.
        anyhow::ensure!(self.batcher.queue_depth >= 1, "queue_depth must be >= 1");
        anyhow::ensure!(
            (1..=64).contains(&self.batcher.shards),
            "batcher.shards must be in 1..=64"
        );
        anyhow::ensure!(self.workers.count >= 1, "need at least one worker");
        anyhow::ensure!(self.banks.count >= 1, "need at least one bank");
        anyhow::ensure!(
            (1..=4).contains(&self.banks.units_per_bank),
            "an 8x8 array hosts 1..=4 LUNA units"
        );
        anyhow::ensure!(
            self.timing.time_scale.is_finite() && self.timing.time_scale >= 0.0,
            "timing.time_scale must be finite and >= 0 (0 = report-only)"
        );
        // 0 = auto (available_parallelism); anything above this is surely
        // a typo, not a machine.
        anyhow::ensure!(self.gemm.threads <= 1024, "gemm.threads must be <= 1024 (0 = auto)");
        anyhow::ensure!(self.net.max_connections >= 1, "net.max_connections must be >= 1");
        anyhow::ensure!(self.loadgen.connections >= 1, "loadgen.connections must be >= 1");
        anyhow::ensure!(
            self.loadgen.requests_per_level >= 1,
            "loadgen.requests_per_level must be >= 1"
        );
        anyhow::ensure!(
            !self.loadgen.loads.is_empty() && self.loadgen.loads.iter().all(|&r| r >= 1),
            "loadgen.loads needs at least one level, each >= 1 req/s"
        );
        anyhow::ensure!(self.loadgen.burst >= 1, "loadgen.burst must be >= 1");
        // the router's per-request routing state is a 64-bit tried mask
        anyhow::ensure!(
            self.router.backends.len() <= 64,
            "router.backends supports at most 64 endpoints"
        );
        anyhow::ensure!(
            (1..=4096).contains(&self.router.vnodes),
            "router.vnodes must be in 1..=4096"
        );
        anyhow::ensure!(
            self.router.max_connections >= 1,
            "router.max_connections must be >= 1"
        );
        anyhow::ensure!(self.router.probe_ms >= 1, "router.probe_ms must be >= 1");
        anyhow::ensure!(
            self.router.max_backoff_ms >= self.router.probe_ms,
            "router.max_backoff_ms must be >= router.probe_ms"
        );
        let mut seen = std::collections::HashSet::new();
        for (id, dir) in &self.serving.models {
            anyhow::ensure!(!id.is_empty(), "serving.models ids must be non-empty");
            anyhow::ensure!(
                id.len() <= crate::net::protocol::MAX_MODEL_ID,
                "serving.models id `{id}` exceeds {} bytes",
                crate::net::protocol::MAX_MODEL_ID
            );
            anyhow::ensure!(seen.insert(id.as_str()), "serving.models id `{id}` is duplicated");
            anyhow::ensure!(!dir.is_empty(), "serving.models dir for `{id}` must be non-empty");
        }
        anyhow::ensure!(self.plan_cache.max_bytes >= 1, "plan_cache.max_bytes must be >= 1");
        anyhow::ensure!(
            (64..=4096).contains(&self.trace.ring_capacity),
            "trace.ring_capacity must be in 64..=4096"
        );
        // trace.sample_every needs no bound: 0 disables, 1 traces all
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn text_roundtrip() {
        let cfg = Config::default();
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn trace_keys_parse_roundtrip_and_validate() {
        let cfg = Config::from_text("trace.ring_capacity 256\ntrace.sample_every 1\n").unwrap();
        assert_eq!(cfg.trace.ring_capacity, 256);
        assert_eq!(cfg.trace.sample_every, 1);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(cfg, back);
        // 0 disables sampling but is valid; a tiny or huge ring is not
        assert!(Config::from_text("trace.sample_every 0\n").is_ok());
        assert!(Config::from_text("trace.ring_capacity 8\n").is_err());
        assert!(Config::from_text("trace.ring_capacity 1048576\n").is_err());
    }

    #[test]
    fn partial_text_uses_defaults() {
        let cfg = Config::from_text("multiplier approx\n").unwrap();
        assert_eq!(cfg.multiplier, MultiplierKind::Approx);
        assert_eq!(cfg.batcher.max_batch, BatcherConfig::default().max_batch);
        assert_eq!(cfg.backend, BackendKind::Native);
    }

    #[test]
    fn backend_key_parses_and_roundtrips() {
        let cfg = Config::from_text("backend pjrt\n").unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back.backend, BackendKind::Pjrt);
        assert!(Config::from_text("backend warp\n").is_err());
        assert_eq!(BackendKind::parse_slug(" Native "), Some(BackendKind::Native));
    }

    #[test]
    fn calibrated_backend_and_time_scale_parse_and_roundtrip() {
        let cfg = Config::from_text("backend calibrated\ntiming.time_scale 1000.5\n").unwrap();
        assert_eq!(cfg.backend, BackendKind::Calibrated);
        assert!((cfg.timing.time_scale - 1000.5).abs() < 1e-9);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(BackendKind::parse_slug(" Calibrated "), Some(BackendKind::Calibrated));
        assert_eq!(BackendKind::Calibrated.slug(), "calibrated");
    }

    #[test]
    fn bad_time_scale_rejected() {
        assert!(Config::from_text("timing.time_scale -1\n").is_err());
        assert!(Config::from_text("timing.time_scale inf\n").is_err());
        assert!(Config::from_text("timing.time_scale nope\n").is_err());
        let mut cfg = Config::default();
        cfg.timing.time_scale = f64::NAN;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn shallow_queue_depth_is_allowed() {
        // strict-admission configuration: queue_depth below max_batch
        let cfg = Config::from_text("batcher.max_batch 8\nbatcher.queue_depth 4\n").unwrap();
        assert_eq!(cfg.batcher.queue_depth, 4);
        let mut bad = Config::default();
        bad.batcher.queue_depth = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn gemm_threads_parses_roundtrips_and_validates() {
        let cfg = Config::from_text("gemm.threads 4\n").unwrap();
        assert_eq!(cfg.gemm.threads, 4);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // 0 = auto is valid
        assert_eq!(Config::from_text("gemm.threads 0\n").unwrap().gemm.threads, 0);
        // default is single-threaded (workers already scale across batches)
        assert_eq!(Config::default().gemm.threads, 1);
        assert!(Config::from_text("gemm.threads 100000\n").is_err());
        assert!(Config::from_text("gemm.threads nope\n").is_err());
    }

    #[test]
    fn gemm_simd_and_partition_parse_roundtrip_and_validate() {
        let cfg = Config::from_text("gemm.simd swar\ngemm.partition outputs\n").unwrap();
        assert_eq!(cfg.gemm.simd, GemmSimd::Swar);
        assert_eq!(cfg.gemm.partition, GemmPartition::Outputs);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // slugs are case-insensitive, defaults are auto/auto
        assert_eq!(Config::from_text("gemm.simd AVX2\n").unwrap().gemm.simd, GemmSimd::Avx2);
        assert_eq!(Config::default().gemm.simd, GemmSimd::Auto);
        assert_eq!(Config::default().gemm.partition, GemmPartition::Auto);
        assert!(Config::from_text("gemm.simd sse9\n").is_err());
        assert!(Config::from_text("gemm.partition cols\n").is_err());
        // the bundled options mirror the section
        let opts = cfg.gemm.options();
        assert_eq!(opts.threads, cfg.gemm.threads);
        assert_eq!(opts.simd, GemmSimd::Swar);
        assert_eq!(opts.partition, GemmPartition::Outputs);
    }

    #[test]
    fn net_keys_parse_roundtrip_and_validate() {
        let cfg = Config::from_text("net.listen 127.0.0.1:7077\nnet.max_connections 8\n").unwrap();
        assert_eq!(cfg.net.listen, "127.0.0.1:7077");
        assert_eq!(cfg.net.max_connections, 8);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // empty listen (disabled) survives the roundtrip via key absence
        let off = Config::default();
        assert!(off.net.listen.is_empty());
        assert!(!off.to_text().contains("net.listen"));
        assert_eq!(Config::from_text(&off.to_text()).unwrap(), off);
        assert!(Config::from_text("net.max_connections 0\n").is_err());
    }

    #[test]
    fn loadgen_keys_parse_roundtrip_and_validate() {
        let text = "loadgen.connections 2\nloadgen.requests_per_level 100\n\
                    loadgen.loads 100,400,1600\nloadgen.burst 16\n";
        let cfg = Config::from_text(text).unwrap();
        assert_eq!(cfg.loadgen.connections, 2);
        assert_eq!(cfg.loadgen.requests_per_level, 100);
        assert_eq!(cfg.loadgen.loads, vec![100, 400, 1600]);
        assert_eq!(cfg.loadgen.burst, 16);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // the default sweep has the >= 3 levels the serve bench needs
        assert!(Config::default().loadgen.loads.len() >= 3);
        assert!(Config::from_text("loadgen.loads 100,0\n").is_err());
        assert!(Config::from_text("loadgen.burst 0\n").is_err());
        assert!(Config::from_text("loadgen.connections 0\n").is_err());
    }

    #[test]
    fn shard_count_parses_roundtrips_and_validates() {
        let cfg = Config::from_text("batcher.shards 4\n").unwrap();
        assert_eq!(cfg.batcher.shards, 4);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(Config::default().batcher.shards, 1, "unsharded by default");
        assert!(Config::from_text("batcher.shards 0\n").is_err());
        assert!(Config::from_text("batcher.shards 65\n").is_err());
    }

    #[test]
    fn loadgen_retry_parses_roundtrips_and_validates() {
        let cases = [
            ("loadgen.retry 1\n", true),
            ("loadgen.retry true\n", true),
            ("loadgen.retry 0\n", false),
            ("loadgen.retry false\n", false),
        ];
        for (text, want) in cases {
            assert_eq!(Config::from_text(text).unwrap().loadgen.retry, want, "{text}");
        }
        assert!(!Config::default().loadgen.retry, "raw open loop by default");
        let mut cfg = Config::default();
        cfg.loadgen.retry = true;
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        assert!(Config::from_text("loadgen.retry maybe\n").is_err());
    }

    #[test]
    fn router_keys_parse_roundtrip_and_validate() {
        let text = "router.listen 127.0.0.1:7070\n\
                    router.backends 127.0.0.1:7071,127.0.0.1:7072\n\
                    router.policy least-outstanding\nrouter.vnodes 64\n\
                    router.max_connections 8\nrouter.probe_ms 50\nrouter.max_backoff_ms 400\n";
        let cfg = Config::from_text(text).unwrap();
        assert_eq!(cfg.router.listen, "127.0.0.1:7070");
        assert_eq!(cfg.router.backends, vec!["127.0.0.1:7071", "127.0.0.1:7072"]);
        assert_eq!(cfg.router.policy, DispatchPolicy::LeastOutstanding);
        assert_eq!(cfg.router.vnodes, 64);
        assert_eq!(cfg.router.max_connections, 8);
        assert_eq!(cfg.router.probe_ms, 50);
        assert_eq!(cfg.router.max_backoff_ms, 400);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // empty listen/backends survive the roundtrip via key absence
        let off = Config::default();
        assert!(!off.to_text().contains("router.listen"));
        assert!(!off.to_text().contains("router.backends"));
        assert_eq!(Config::from_text(&off.to_text()).unwrap(), off);
        assert_eq!(off.router.policy, DispatchPolicy::Hash);
        assert!(Config::from_text("router.policy roulette\n").is_err());
        assert!(Config::from_text("router.vnodes 0\n").is_err());
        assert!(Config::from_text("router.vnodes 5000\n").is_err());
        assert!(Config::from_text("router.probe_ms 0\n").is_err());
        assert!(Config::from_text("router.probe_ms 100\nrouter.max_backoff_ms 50\n").is_err());
        let mut wide = Config::default();
        wide.router.backends = (0..65).map(|i| format!("127.0.0.1:{}", 7000 + i)).collect();
        assert!(wide.validate().is_err(), "tried mask is 64-bit");
    }

    #[test]
    fn serving_keys_parse_roundtrip_and_validate() {
        let text = "serving.models mnist=artifacts/a, study=artifacts/b\n\
                    plan_cache.max_bytes 1048576\n";
        let cfg = Config::from_text(text).unwrap();
        assert_eq!(
            cfg.serving.models,
            vec![
                ("mnist".to_string(), "artifacts/a".to_string()),
                ("study".to_string(), "artifacts/b".to_string()),
            ]
        );
        assert_eq!(cfg.plan_cache.max_bytes, 1 << 20);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        // no extra models = key absent (same empty-value rule as listen)
        let off = Config::default();
        assert!(!off.to_text().contains("serving.models"));
        assert_eq!(Config::from_text(&off.to_text()).unwrap(), off);
        assert_eq!(off.plan_cache.max_bytes, 64 << 20);
        // malformed pair, duplicate id, empty dir, oversize id, zero budget
        assert!(Config::from_text("serving.models mnist\n").is_err());
        assert!(Config::from_text("serving.models a=x,a=y\n").is_err());
        assert!(Config::from_text("serving.models a=\n").is_err());
        let long = format!("serving.models {}=x\n", "m".repeat(64));
        assert!(Config::from_text(&long).is_err());
        assert!(Config::from_text("plan_cache.max_bytes 0\n").is_err());
    }

    #[test]
    fn batcher_affinity_parses_roundtrips_and_validates() {
        let cfg = Config::from_text("batcher.affinity connection\n").unwrap();
        assert_eq!(cfg.batcher.affinity, ShardAffinity::Connection);
        let back = Config::from_text(&cfg.to_text()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(
            Config::default().batcher.affinity,
            ShardAffinity::Request,
            "request-id round-robin by default"
        );
        assert_eq!(ShardAffinity::parse_slug(" Connection "), Some(ShardAffinity::Connection));
        assert!(Config::from_text("batcher.affinity sticky\n").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::from_text("multplier approx\n").is_err());
    }

    #[test]
    fn invalid_units_rejected() {
        assert!(Config::from_text("banks.units_per_bank 9\n").is_err());
        let mut cfg = Config::default();
        cfg.banks.units_per_bank = 9;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bad_multiplier_slug_rejected() {
        assert!(Config::from_text("multiplier warp9\n").is_err());
    }
}
