//! Gate-level logic substrate.
//!
//! The paper evaluates its multiplier configurations with SPICE on TSMC
//! 65 nm; this module is the substitute substrate (DESIGN.md §2): netlists
//! built from primitive gates with composite-cell tagging (HA/FA/MUX2 are
//! counted the way the paper counts them), a steady-state evaluator with
//! switching-activity accounting (dynamic energy), and an event-driven
//! simulator with per-cell delays that produces the Fig 14-style transient
//! waveforms.

mod event_sim;
mod netlist;
mod stepper;
mod waveform;

pub use event_sim::{EventSim, SimStats};
pub use netlist::{Bus, DelayModel, Gate, GateKind, NetId, Netlist};
pub use stepper::{StepResult, Stepper};
pub use waveform::{BusTrace, Waveform};

/// Convert a `u64` value into `width` little-endian bits.
pub fn to_bits(value: u64, width: usize) -> Vec<bool> {
    (0..width).map(|i| (value >> i) & 1 == 1).collect()
}

/// Convert little-endian bits back into a `u64`.
pub fn from_bits(bits: &[bool]) -> u64 {
    bits.iter().enumerate().fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        for v in [0u64, 1, 5, 0b1010, 255, 0xdead] {
            assert_eq!(from_bits(&to_bits(v, 16)), v & 0xffff);
        }
    }

    #[test]
    fn to_bits_is_little_endian() {
        assert_eq!(to_bits(0b01, 2), vec![true, false]);
    }
}
