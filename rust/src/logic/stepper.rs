//! Steady-state evaluator with switching-activity accounting.
//!
//! [`Stepper`] holds the last settled value of every net; each call to
//! [`Stepper::step`] applies a new stimulus, re-evaluates the netlist in
//! topological order (construction order), and reports which cells toggled.
//! Toggle counts × the cell library's per-toggle energies is the dynamic
//! energy model used throughout (the standard activity-based estimate;
//! the event-driven simulator adds glitch transitions on top).
//!
//! The stepper does not borrow the netlist — it is passed to each call —
//! so owning types (e.g. [`crate::luna::LunaUnit`]) can hold both.

use super::netlist::{GateKind, Netlist};
use crate::cells::{CellKind, CellLibrary};

/// Result of one evaluation step.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// Values of all registered output buses, flattened in order.
    pub outputs: Vec<bool>,
    /// Output toggles per primitive cell kind (index = [`CellKind::index`]).
    pub toggles: [u64; CellKind::ALL.len()],
}

impl StepResult {
    /// Total toggles across all cells.
    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Dynamic energy of this step in femtojoules under `lib`
    /// (activity × per-toggle energy of each *primitive* cell).
    pub fn dynamic_energy_fj(&self, lib: &CellLibrary) -> f64 {
        CellKind::ALL
            .iter()
            .map(|&k| self.toggles[k.index()] as f64 * lib.params(k).energy_per_toggle_fj)
            .sum()
    }
}

/// Stateful steady-state evaluator over a netlist.
#[derive(Debug, Clone)]
pub struct Stepper {
    values: Vec<bool>,
    /// SRAM programming (little-endian over `net.sram_bits`).
    sram: Vec<bool>,
    n_inputs: usize,
    /// Output net indices, precomputed (hot path: no per-step allocation
    /// beyond the result vector itself).
    out_nets: Vec<u32>,
    first: bool,
}

impl Stepper {
    pub fn new(net: &Netlist) -> Self {
        Stepper {
            values: vec![false; net.num_nets()],
            sram: vec![false; net.sram_bits.len()],
            n_inputs: net.inputs.len(),
            out_nets: net.output_nets().iter().map(|n| n.0).collect(),
            first: true,
        }
    }

    /// Program the SRAM bits (LUT contents). Does not count toggles —
    /// programming energy is accounted by the SRAM-array write model.
    pub fn program(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.sram.len(), "programming width mismatch");
        self.sram.copy_from_slice(bits);
        self.first = true; // settle silently after reprogramming
    }

    /// Current settled value of a net.
    pub fn value(&self, net: super::NetId) -> bool {
        self.values[net.index()]
    }

    /// Apply `inputs` (ordered as `net.inputs`), propagate to steady state,
    /// and count toggles vs the previous state. The first step after
    /// construction or reprogramming settles silently (no toggles counted),
    /// mirroring a powered-up, programmed array. `net` must be the same
    /// netlist the stepper was created for.
    pub fn step(&mut self, net: &Netlist, inputs: &[bool]) -> StepResult {
        assert_eq!(net.num_nets(), self.values.len(), "stepper/netlist mismatch");
        assert_eq!(inputs.len(), self.n_inputs, "stimulus width mismatch");
        let mut toggles = [0u64; CellKind::ALL.len()];
        let mut sram_iter = 0usize;
        let mut input_iter = 0usize;
        let count = !self.first;
        for idx in 0..net.gates.len() {
            let gate = &net.gates[idx];
            let new = match gate.kind {
                GateKind::Input => {
                    let v = inputs[input_iter];
                    input_iter += 1;
                    v
                }
                GateKind::SramBit => {
                    let v = self.sram[sram_iter];
                    sram_iter += 1;
                    v
                }
                GateKind::Const(v) => v,
                GateKind::Buf => self.values[gate.ins[0].index()],
                GateKind::Not => !self.values[gate.ins[0].index()],
                GateKind::And2 => {
                    self.values[gate.ins[0].index()] & self.values[gate.ins[1].index()]
                }
                GateKind::Or2 => {
                    self.values[gate.ins[0].index()] | self.values[gate.ins[1].index()]
                }
                GateKind::Nand2 => {
                    !(self.values[gate.ins[0].index()] & self.values[gate.ins[1].index()])
                }
                GateKind::Nor2 => {
                    !(self.values[gate.ins[0].index()] | self.values[gate.ins[1].index()])
                }
                GateKind::Xor2 => {
                    self.values[gate.ins[0].index()] ^ self.values[gate.ins[1].index()]
                }
                GateKind::Xnor2 => {
                    !(self.values[gate.ins[0].index()] ^ self.values[gate.ins[1].index()])
                }
                GateKind::Mux2 => {
                    if self.values[gate.ins[2].index()] {
                        self.values[gate.ins[1].index()]
                    } else {
                        self.values[gate.ins[0].index()]
                    }
                }
            };
            if count && new != self.values[idx] {
                if let Some(k) = gate.kind.primitive_cell() {
                    toggles[k.index()] += 1;
                }
            }
            self.values[idx] = new;
        }
        self.first = false;
        let outputs = self.out_nets.iter().map(|&n| self.values[n as usize]).collect();
        StepResult { outputs, toggles }
    }

    /// Convenience: evaluate with an integer input word and return the
    /// outputs as an integer (concatenated output buses, little-endian).
    pub fn eval_u64(&mut self, net: &Netlist, input_value: u64) -> u64 {
        let bits = super::to_bits(input_value, self.n_inputs);
        let res = self.step(net, &bits);
        super::from_bits(&res.outputs)
    }

    /// Allocation-free hot path: integer stimulus in, integer outputs and
    /// toggle counts out (the fabric-execution path of
    /// [`crate::luna::LunaUnit::multiply`]).
    pub fn step_fast(
        &mut self,
        net: &Netlist,
        input_value: u64,
    ) -> (u64, [u64; CellKind::ALL.len()]) {
        debug_assert_eq!(net.num_nets(), self.values.len(), "stepper/netlist mismatch");
        let mut toggles = [0u64; CellKind::ALL.len()];
        let mut sram_iter = 0usize;
        let mut input_iter = 0usize;
        let count = !self.first;
        for idx in 0..net.gates.len() {
            let gate = &net.gates[idx];
            let new = match gate.kind {
                GateKind::Input => {
                    let v = (input_value >> input_iter) & 1 == 1;
                    input_iter += 1;
                    v
                }
                GateKind::SramBit => {
                    let v = self.sram[sram_iter];
                    sram_iter += 1;
                    v
                }
                GateKind::Const(v) => v,
                GateKind::Buf => self.values[gate.ins[0].index()],
                GateKind::Not => !self.values[gate.ins[0].index()],
                GateKind::And2 => {
                    self.values[gate.ins[0].index()] & self.values[gate.ins[1].index()]
                }
                GateKind::Or2 => {
                    self.values[gate.ins[0].index()] | self.values[gate.ins[1].index()]
                }
                GateKind::Nand2 => {
                    !(self.values[gate.ins[0].index()] & self.values[gate.ins[1].index()])
                }
                GateKind::Nor2 => {
                    !(self.values[gate.ins[0].index()] | self.values[gate.ins[1].index()])
                }
                GateKind::Xor2 => {
                    self.values[gate.ins[0].index()] ^ self.values[gate.ins[1].index()]
                }
                GateKind::Xnor2 => {
                    !(self.values[gate.ins[0].index()] ^ self.values[gate.ins[1].index()])
                }
                GateKind::Mux2 => {
                    if self.values[gate.ins[2].index()] {
                        self.values[gate.ins[1].index()]
                    } else {
                        self.values[gate.ins[0].index()]
                    }
                }
            };
            if count && new != self.values[idx] {
                if let Some(k) = gate.kind.primitive_cell() {
                    toggles[k.index()] += 1;
                }
            }
            self.values[idx] = new;
        }
        self.first = false;
        let mut out = 0u64;
        for (i, &n) in self.out_nets.iter().enumerate() {
            out |= (self.values[n as usize] as u64) << i;
        }
        (out, toggles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Netlist};

    fn xor_chain() -> Netlist {
        let mut n = Netlist::default();
        let a = n.input_bit();
        let b = n.input_bit();
        let x = n.xor2(a, b);
        let y = n.not(x);
        n.output_bus("out", vec![x, y]);
        n
    }

    #[test]
    fn first_step_counts_no_toggles() {
        let n = xor_chain();
        let mut st = Stepper::new(&n);
        let r = st.step(&n, &[true, false]);
        assert_eq!(r.total_toggles(), 0);
        assert_eq!(from_bits(&r.outputs), 0b01);
    }

    #[test]
    fn toggles_counted_after_first_step() {
        let n = xor_chain();
        let mut st = Stepper::new(&n);
        st.step(&n, &[false, false]);
        let r = st.step(&n, &[true, false]); // xor flips, not flips
        assert_eq!(r.total_toggles(), 2);
        let r2 = st.step(&n, &[true, false]); // no change
        assert_eq!(r2.total_toggles(), 0);
    }

    #[test]
    fn sram_programming_controls_outputs() {
        let mut n = Netlist::default();
        let s = n.sram_bus(4);
        let sel = n.input_bus("sel", 2);
        let out = n.mux_tree(&s, &sel);
        n.output_bus("o", vec![out]);
        let mut st = Stepper::new(&n);
        st.program(&to_bits(0b1010, 4));
        for i in 0..4u64 {
            let v = st.step(&n, &to_bits(i, 2));
            assert_eq!(v.outputs[0], (0b1010 >> i) & 1 == 1, "entry {i}");
        }
    }

    #[test]
    fn energy_is_positive_when_toggling() {
        let lib = crate::cells::tsmc65_library();
        let n = xor_chain();
        let mut st = Stepper::new(&n);
        st.step(&n, &[false, false]);
        let r = st.step(&n, &[true, false]);
        assert!(r.dynamic_energy_fj(&lib) > 0.0);
    }

    #[test]
    fn eval_u64_convenience() {
        let n = xor_chain();
        let mut st = Stepper::new(&n);
        assert_eq!(st.eval_u64(&n, 0b01) & 1, 1);
    }
}
