//! Netlist representation and builder.
//!
//! A [`Netlist`] is a DAG of single-output gates; the gate at index `i`
//! drives net `NetId(i)`. Construction order is topological by definition
//! (a gate can only reference already-created nets), which keeps both the
//! steady-state evaluator and the cost accounting simple and fast.
//!
//! Composite cells (half adders, full adders, 2:1 muxes) are built from
//! primitives but **tagged** with a `(CellKind, instance)` pair so that
//! [`Netlist::cost_report`] counts them exactly the way the paper's tables
//! count components ("3 × 1b HA", "36 × 2:1 1b Mux", …).

use crate::cells::{CellKind, CostReport};

/// Identifier of a net (== index of its driving gate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

impl NetId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A bundle of nets forming a little-endian bus.
pub type Bus = Vec<NetId>;

/// Primitive gate kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateKind {
    /// External input bit (value applied per stimulus).
    Input,
    /// Programmable SRAM bit (value applied when the LUT is programmed).
    SramBit,
    /// Constant driver.
    Const(bool),
    Buf,
    Not,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
    /// 2:1 mux: `ins = [a, b, sel]`, output `sel ? b : a`.
    Mux2,
}

impl GateKind {
    /// Number of input nets.
    pub fn arity(self) -> usize {
        match self {
            GateKind::Input | GateKind::SramBit | GateKind::Const(_) => 0,
            GateKind::Buf | GateKind::Not => 1,
            GateKind::Mux2 => 3,
            _ => 2,
        }
    }

    /// The library cell this primitive corresponds to, for per-toggle
    /// energy accounting (composite tags are used for *area/count*
    /// accounting instead). `None` for inputs/constants.
    pub fn primitive_cell(self) -> Option<CellKind> {
        match self {
            GateKind::Input | GateKind::Const(_) => None,
            GateKind::SramBit => Some(CellKind::SramCell),
            GateKind::Buf => Some(CellKind::Buf),
            GateKind::Not => Some(CellKind::Inv),
            GateKind::And2 => Some(CellKind::And2),
            GateKind::Or2 => Some(CellKind::Or2),
            GateKind::Nand2 => Some(CellKind::Nand2),
            GateKind::Nor2 => Some(CellKind::Nor2),
            GateKind::Xor2 => Some(CellKind::Xor2),
            GateKind::Xnor2 => Some(CellKind::Xnor2),
            GateKind::Mux2 => Some(CellKind::Mux2),
        }
    }
}

/// One gate. `cell` is the composite-cell tag used for component counting.
#[derive(Debug, Clone)]
pub struct Gate {
    pub kind: GateKind,
    pub ins: [NetId; 3],
    pub nin: u8,
    /// Composite-cell tag: (kind, instance id) — e.g. all five gates of a
    /// full adder share one `(FullAdder, 7)` tag.
    pub cell: Option<(CellKind, u32)>,
    /// Propagation delay in picoseconds (event-driven sim).
    pub delay_ps: u64,
}

/// Per-primitive propagation delays (ps). The default matches the
/// calibrated 65 nm-like library in [`crate::cells::tsmc65_library`].
#[derive(Debug, Clone)]
pub struct DelayModel {
    pub buf_ps: u64,
    pub not_ps: u64,
    pub and2_ps: u64,
    pub or2_ps: u64,
    pub nand2_ps: u64,
    pub nor2_ps: u64,
    pub xor2_ps: u64,
    pub xnor2_ps: u64,
    pub mux2_ps: u64,
    /// SRAM read-out delay (bit valid after wordline fires).
    pub sram_ps: u64,
}

impl Default for DelayModel {
    fn default() -> Self {
        DelayModel {
            buf_ps: 28,
            not_ps: 15,
            and2_ps: 32,
            or2_ps: 34,
            nand2_ps: 20,
            nor2_ps: 22,
            xor2_ps: 36,
            xnor2_ps: 36,
            mux2_ps: 40,
            sram_ps: 120,
        }
    }
}

impl DelayModel {
    fn for_kind(&self, kind: GateKind) -> u64 {
        match kind {
            GateKind::Input | GateKind::Const(_) => 0,
            GateKind::SramBit => self.sram_ps,
            GateKind::Buf => self.buf_ps,
            GateKind::Not => self.not_ps,
            GateKind::And2 => self.and2_ps,
            GateKind::Or2 => self.or2_ps,
            GateKind::Nand2 => self.nand2_ps,
            GateKind::Nor2 => self.nor2_ps,
            GateKind::Xor2 => self.xor2_ps,
            GateKind::Xnor2 => self.xnor2_ps,
            GateKind::Mux2 => self.mux2_ps,
        }
    }
}

/// A combinational netlist with named input/output buses and programmable
/// SRAM bits. Also the builder: gates are appended via the `and2`, `mux2`,
/// `half_adder`, … methods.
#[derive(Debug, Clone)]
pub struct Netlist {
    pub gates: Vec<Gate>,
    /// Ordered external input nets (stimulus order).
    pub inputs: Vec<NetId>,
    /// Ordered programmable SRAM bits (programming order).
    pub sram_bits: Vec<NetId>,
    /// Named input buses (little-endian).
    pub in_buses: Vec<(String, Bus)>,
    /// Named output buses (little-endian).
    pub out_buses: Vec<(String, Bus)>,
    delays: DelayModel,
    next_inst: [u32; CellKind::ALL.len()],
    current_cell: Option<(CellKind, u32)>,
    const0: Option<NetId>,
    const1: Option<NetId>,
}

impl Default for Netlist {
    fn default() -> Self {
        Self::new(DelayModel::default())
    }
}

impl Netlist {
    pub fn new(delays: DelayModel) -> Self {
        Netlist {
            gates: Vec::new(),
            inputs: Vec::new(),
            sram_bits: Vec::new(),
            in_buses: Vec::new(),
            out_buses: Vec::new(),
            delays,
            next_inst: [0; CellKind::ALL.len()],
            current_cell: None,
            const0: None,
            const1: None,
        }
    }

    pub fn num_nets(&self) -> usize {
        self.gates.len()
    }

    fn push(&mut self, kind: GateKind, ins: &[NetId]) -> NetId {
        debug_assert_eq!(ins.len(), kind.arity());
        for &i in ins {
            debug_assert!(i.index() < self.gates.len(), "input net must already exist");
        }
        let mut arr = [NetId(0); 3];
        arr[..ins.len()].copy_from_slice(ins);
        let id = NetId(self.gates.len() as u32);
        self.gates.push(Gate {
            kind,
            ins: arr,
            nin: ins.len() as u8,
            cell: self.current_cell,
            delay_ps: self.delays.for_kind(kind),
        });
        id
    }

    /// Begin a composite cell: all gates created until [`Netlist::end_cell`]
    /// share one `(kind, instance)` tag. Returns the instance id.
    pub fn begin_cell(&mut self, kind: CellKind) -> u32 {
        assert!(self.current_cell.is_none(), "composite cells do not nest");
        let inst = self.next_inst[kind.index()];
        self.next_inst[kind.index()] += 1;
        self.current_cell = Some((kind, inst));
        inst
    }

    pub fn end_cell(&mut self) {
        self.current_cell = None;
    }

    // ---- sources ----

    /// One external input bit.
    pub fn input_bit(&mut self) -> NetId {
        let id = self.push(GateKind::Input, &[]);
        self.inputs.push(id);
        id
    }

    /// A named `width`-bit external input bus (little-endian).
    pub fn input_bus(&mut self, name: &str, width: usize) -> Bus {
        let bus: Bus = (0..width).map(|_| self.input_bit()).collect();
        self.in_buses.push((name.to_string(), bus.clone()));
        bus
    }

    /// One programmable SRAM bit (counted as a `SramCell`).
    pub fn sram_bit(&mut self) -> NetId {
        // Tag each SRAM bit as its own composite instance so cost reports
        // count storage bits exactly like the paper does.
        let standalone = self.current_cell.is_none();
        if standalone {
            self.begin_cell(CellKind::SramCell);
        }
        let id = self.push(GateKind::SramBit, &[]);
        if standalone {
            self.end_cell();
        }
        self.sram_bits.push(id);
        id
    }

    /// A `width`-bit programmable SRAM word.
    pub fn sram_bus(&mut self, width: usize) -> Bus {
        (0..width).map(|_| self.sram_bit()).collect()
    }

    /// Constant 0 / 1 (deduplicated; zero cost).
    pub fn constant(&mut self, value: bool) -> NetId {
        let slot = if value { &mut self.const1 } else { &mut self.const0 };
        if let Some(id) = *slot {
            return id;
        }
        // Constants must not inherit a composite tag.
        let saved = self.current_cell.take();
        let id = self.push(GateKind::Const(value), &[]);
        self.current_cell = saved;
        if value {
            self.const1 = Some(id);
        } else {
            self.const0 = Some(id);
        }
        id
    }

    // ---- primitive gates ----

    pub fn buf(&mut self, a: NetId) -> NetId {
        self.push(GateKind::Buf, &[a])
    }
    pub fn not(&mut self, a: NetId) -> NetId {
        self.push(GateKind::Not, &[a])
    }
    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::And2, &[a, b])
    }
    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Or2, &[a, b])
    }
    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Nand2, &[a, b])
    }
    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Nor2, &[a, b])
    }
    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Xor2, &[a, b])
    }
    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(GateKind::Xnor2, &[a, b])
    }

    // ---- composite cells (tagged, counted like the paper counts them) ----

    /// 2:1 one-bit mux: `sel ? b : a`. One `Mux2` cell.
    pub fn mux2(&mut self, a: NetId, b: NetId, sel: NetId) -> NetId {
        let standalone = self.current_cell.is_none();
        if standalone {
            self.begin_cell(CellKind::Mux2);
        }
        let id = self.push(GateKind::Mux2, &[a, b, sel]);
        if standalone {
            self.end_cell();
        }
        id
    }

    /// 4:1 one-bit mux from three 2:1 muxes (`sel = [s0, s1]`, little-endian:
    /// selects `ins[s1*2 + s0]`). Three `Mux2` cells — exactly how the paper
    /// decomposes its 4:1 word muxes.
    pub fn mux4(&mut self, ins: [NetId; 4], s0: NetId, s1: NetId) -> NetId {
        let lo = self.mux2(ins[0], ins[1], s0);
        let hi = self.mux2(ins[2], ins[3], s0);
        self.mux2(lo, hi, s1)
    }

    /// 4:1 word mux over little-endian buses of equal width.
    pub fn mux4_bus(&mut self, ins: [&Bus; 4], s0: NetId, s1: NetId) -> Bus {
        let w = ins[0].len();
        assert!(ins.iter().all(|b| b.len() == w), "mux4_bus operand widths differ");
        (0..w).map(|i| self.mux4([ins[0][i], ins[1][i], ins[2][i], ins[3][i]], s0, s1)).collect()
    }

    /// N:1 one-bit mux tree from 2:1 muxes; `sel` little-endian,
    /// `ins.len() == 2^sel.len()`. Uses `2^k - 1` `Mux2` cells.
    pub fn mux_tree(&mut self, ins: &[NetId], sel: &[NetId]) -> NetId {
        assert_eq!(ins.len(), 1 << sel.len(), "mux tree needs 2^k inputs");
        if sel.is_empty() {
            return ins[0];
        }
        let half = ins.len() / 2;
        let lo = self.mux_tree(&ins[..half], &sel[..sel.len() - 1]);
        let hi = self.mux_tree(&ins[half..], &sel[..sel.len() - 1]);
        self.mux2(lo, hi, sel[sel.len() - 1])
    }

    /// Half adder: returns `(sum, carry)`. One `HalfAdder` cell (XOR + AND).
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        self.begin_cell(CellKind::HalfAdder);
        let s = self.xor2(a, b);
        let c = self.and2(a, b);
        self.end_cell();
        (s, c)
    }

    /// Full adder: returns `(sum, carry)`. One `FullAdder` cell.
    pub fn full_adder(&mut self, a: NetId, b: NetId, cin: NetId) -> (NetId, NetId) {
        self.begin_cell(CellKind::FullAdder);
        let axb = self.xor2(a, b);
        let s = self.xor2(axb, cin);
        let t1 = self.and2(axb, cin);
        let t2 = self.and2(a, b);
        let c = self.or2(t1, t2);
        self.end_cell();
        (s, c)
    }

    // ---- outputs & reporting ----

    /// Register a named little-endian output bus.
    pub fn output_bus(&mut self, name: &str, bus: Bus) {
        self.out_buses.push((name.to_string(), bus));
    }

    /// Find a named output bus.
    pub fn find_out_bus(&self, name: &str) -> Option<&Bus> {
        self.out_buses.iter().find(|(n, _)| n == name).map(|(_, b)| b)
    }

    /// Find a named input bus.
    pub fn find_in_bus(&self, name: &str) -> Option<&Bus> {
        self.in_buses.iter().find(|(n, _)| n == name).map(|(_, b)| b)
    }

    /// Component counts the way the paper counts them: composite-tagged
    /// instances count once per instance; untagged primitives count as
    /// their primitive cell.
    pub fn cost_report(&self) -> CostReport {
        let mut report = CostReport::new();
        let mut seen: std::collections::HashSet<(CellKind, u32)> = std::collections::HashSet::new();
        for gate in &self.gates {
            match gate.cell {
                Some(tag) => {
                    if seen.insert(tag) {
                        report.tally(tag.0, 1);
                    }
                }
                None => {
                    if let Some(k) = gate.kind.primitive_cell() {
                        report.tally(k, 1);
                    }
                }
            }
        }
        report
    }

    /// Flattened ordered output nets (concatenation of all output buses).
    pub fn output_nets(&self) -> Vec<NetId> {
        self.out_buses.iter().flat_map(|(_, b)| b.iter().copied()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{from_bits, to_bits, Stepper};

    #[test]
    fn topological_by_construction() {
        let mut n = Netlist::default();
        let a = n.input_bit();
        let b = n.input_bit();
        let x = n.and2(a, b);
        let y = n.not(x);
        assert!(y.0 > x.0 && x.0 > b.0);
    }

    #[test]
    fn cost_report_counts_composites_once() {
        let mut n = Netlist::default();
        let a = n.input_bit();
        let b = n.input_bit();
        let _ = n.half_adder(a, b); // 2 primitive gates, 1 HA cell
        let c = n.input_bit();
        let _ = n.full_adder(a, b, c); // 5 primitive gates, 1 FA cell
        let _ = n.mux2(a, b, c);
        let r = n.cost_report();
        assert_eq!(r.count(crate::cells::CellKind::HalfAdder), 1);
        assert_eq!(r.count(crate::cells::CellKind::FullAdder), 1);
        assert_eq!(r.count(crate::cells::CellKind::Mux2), 1);
    }

    #[test]
    fn mux_tree_cell_count_matches_paper_formula() {
        // Paper Table I: a 2^k:1 mux costs 2^k - 1 two-input muxes.
        for k in 1..=4usize {
            let mut n = Netlist::default();
            let ins: Vec<NetId> = (0..(1 << k)).map(|_| n.input_bit()).collect();
            let sel: Vec<NetId> = (0..k).map(|_| n.input_bit()).collect();
            let _ = n.mux_tree(&ins, &sel);
            assert_eq!(n.cost_report().count(crate::cells::CellKind::Mux2), (1 << k) - 1);
        }
    }

    #[test]
    fn mux_tree_selects_correct_input() {
        let k = 3usize;
        let mut n = Netlist::default();
        let ins: Vec<NetId> = (0..(1 << k)).map(|_| n.input_bit()).collect();
        let sel: Vec<NetId> = (0..k).map(|_| n.input_bit()).collect();
        let out = n.mux_tree(&ins, &sel);
        n.output_bus("out", vec![out]);
        let mut st = Stepper::new(&n);
        for s in 0..(1 << k) {
            // one-hot data pattern: input `s` is 1, rest 0
            let mut stim = vec![false; (1 << k) + k];
            stim[s] = true;
            for (i, bit) in to_bits(s as u64, k).iter().enumerate() {
                stim[(1 << k) + i] = *bit;
            }
            let res = st.step(&n, &stim);
            assert_eq!(from_bits(&res.outputs), 1, "sel={s}");
        }
    }

    #[test]
    fn adders_are_correct() {
        let mut n = Netlist::default();
        let a = n.input_bit();
        let b = n.input_bit();
        let cin = n.input_bit();
        let (hs, hc) = n.half_adder(a, b);
        let (fs, fc) = n.full_adder(a, b, cin);
        n.output_bus("ha", vec![hs, hc]);
        n.output_bus("fa", vec![fs, fc]);
        let mut st = Stepper::new(&n);
        for v in 0..8u64 {
            let bits = to_bits(v, 3);
            let out = st.step(&n, &bits).outputs;
            let a = bits[0] as u64;
            let b = bits[1] as u64;
            let c = bits[2] as u64;
            assert_eq!(from_bits(&out[0..2]), a + b, "HA {v}");
            assert_eq!(from_bits(&out[2..4]), a + b + c, "FA {v}");
        }
    }
}
