//! Waveform capture and rendering (the Fig 14 transient view).

use std::fmt::Write as _;

/// A sampled digital waveform of one bus: `(time_ps, value)` pairs with
/// consecutive duplicate values collapsed.
#[derive(Debug, Clone)]
pub struct Waveform {
    pub name: String,
    pub width: usize,
    samples: Vec<(u64, u64)>,
}

impl Waveform {
    pub fn new(name: String, width: usize) -> Self {
        Waveform { name, width, samples: Vec::new() }
    }

    /// Append a sample; duplicate consecutive values are collapsed, and a
    /// re-sample at an existing timestamp overwrites it.
    pub fn sample(&mut self, time_ps: u64, value: u64) {
        if let Some(&(t_last, v_last)) = self.samples.last() {
            if v_last == value {
                return;
            }
            if t_last == time_ps {
                self.samples.pop();
                if self.samples.last().map(|&(_, v)| v) == Some(value) {
                    return;
                }
            }
        }
        self.samples.push((time_ps, value));
    }

    pub fn samples(&self) -> &[(u64, u64)] {
        &self.samples
    }

    pub fn last_value(&self) -> Option<u64> {
        self.samples.last().map(|&(_, v)| v)
    }

    /// Value as of `time_ps` (last sample at or before it).
    pub fn value_at(&self, time_ps: u64) -> Option<u64> {
        self.samples.iter().take_while(|&&(t, _)| t <= time_ps).last().map(|&(_, v)| v)
    }

    /// CSV export: `time_ps,value` rows.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("time_ps,value\n");
        for &(t, v) in &self.samples {
            let _ = writeln!(out, "{t},{v}");
        }
        out
    }
}

/// A set of waveforms rendered together — the textual analogue of the
/// paper's Fig 14 transient plot.
#[derive(Debug, Clone, Default)]
pub struct BusTrace {
    pub waves: Vec<Waveform>,
}

impl BusTrace {
    pub fn new(waves: Vec<Waveform>) -> Self {
        BusTrace { waves }
    }

    /// ASCII rendering: one row per bus, a column per change-point, values
    /// in decimal and binary.
    pub fn render(&self) -> String {
        let mut times: Vec<u64> =
            self.waves.iter().flat_map(|w| w.samples().iter().map(|&(t, _)| t)).collect();
        times.sort_unstable();
        times.dedup();
        let mut out = String::new();
        let _ = writeln!(out, "{:>10} | {}", "time(ps)", self.waves.iter().map(|w| format!("{:>16}", w.name)).collect::<Vec<_>>().join(" "));
        let _ = writeln!(out, "{}", "-".repeat(13 + 17 * self.waves.len()));
        for t in times {
            let cols: Vec<String> = self
                .waves
                .iter()
                .map(|w| match w.value_at(t) {
                    Some(v) => format!("{:>6} ({:0w$b})", v, v, w = w.width.max(1)),
                    None => "-".to_string(),
                })
                .map(|s| format!("{s:>16}"))
                .collect();
            let _ = writeln!(out, "{t:>10} | {}", cols.join(" "));
        }
        out
    }

    /// CSV with one column per bus sampled at every change point.
    pub fn to_csv(&self) -> String {
        let mut times: Vec<u64> =
            self.waves.iter().flat_map(|w| w.samples().iter().map(|&(t, _)| t)).collect();
        times.sort_unstable();
        times.dedup();
        let mut out = String::from("time_ps");
        for w in &self.waves {
            let _ = write!(out, ",{}", w.name);
        }
        out.push('\n');
        for t in times {
            let _ = write!(out, "{t}");
            for w in &self.waves {
                match w.value_at(t) {
                    Some(v) => {
                        let _ = write!(out, ",{v}");
                    }
                    None => out.push(','),
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_samples_collapse() {
        let mut w = Waveform::new("x".into(), 4);
        w.sample(0, 5);
        w.sample(10, 5);
        w.sample(20, 7);
        assert_eq!(w.samples().len(), 2);
        assert_eq!(w.value_at(15), Some(5));
        assert_eq!(w.value_at(25), Some(7));
    }

    #[test]
    fn resample_at_same_time_overwrites() {
        let mut w = Waveform::new("x".into(), 4);
        w.sample(0, 1);
        w.sample(5, 2);
        w.sample(5, 3);
        assert_eq!(w.samples(), &[(0, 1), (5, 3)]);
    }

    #[test]
    fn csv_and_render_contain_values() {
        let mut w = Waveform::new("OUT".into(), 8);
        w.sample(0, 60);
        w.sample(1000, 66);
        let trace = BusTrace::new(vec![w]);
        let text = trace.render();
        assert!(text.contains("60"));
        assert!(text.contains("66"));
        let csv = trace.to_csv();
        assert!(csv.starts_with("time_ps,OUT"));
    }
}
