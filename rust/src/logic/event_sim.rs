//! Event-driven timing simulation.
//!
//! This is the substitute for the paper's SPICE transient runs (Fig 14):
//! each gate has a propagation delay; input changes schedule re-evaluations;
//! output transitions (including glitches) are recorded into [`Waveform`]s
//! and counted for glitch-aware energy estimates.

use super::netlist::{GateKind, NetId, Netlist};
use super::waveform::Waveform;
use crate::cells::CellKind;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Aggregate statistics of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Total net transitions (including glitches).
    pub transitions: u64,
    /// Transitions per primitive cell kind.
    pub transitions_by_kind: [u64; CellKind::ALL.len()],
    /// Number of processed events.
    pub events: u64,
    /// Time of the last transition (ps).
    pub settle_time_ps: u64,
}

impl SimStats {
    /// Glitch-aware dynamic energy in femtojoules.
    pub fn dynamic_energy_fj(&self, lib: &crate::cells::CellLibrary) -> f64 {
        CellKind::ALL
            .iter()
            .map(|&k| self.transitions_by_kind[k.index()] as f64 * lib.params(k).energy_per_toggle_fj)
            .sum()
    }
}

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Event {
    time_ps: u64,
    seq: u64,
    gate: u32,
    /// Output value computed when the event was scheduled — transport-
    /// delay semantics, so reconvergent paths produce real glitches
    /// (evaluate-at-pop would read already-updated inputs and hide them).
    value: bool,
}

/// Event-driven simulator over a netlist.
pub struct EventSim<'a> {
    net: &'a Netlist,
    values: Vec<bool>,
    sram: Vec<bool>,
    fanout: Vec<Vec<u32>>,
    queue: BinaryHeap<Reverse<Event>>,
    seq: u64,
    /// Current simulation time (ps).
    pub now_ps: u64,
    stats: SimStats,
    watched: Vec<(String, Vec<NetId>)>,
}

impl<'a> EventSim<'a> {
    pub fn new(net: &'a Netlist) -> Self {
        let mut fanout = vec![Vec::new(); net.num_nets()];
        for (idx, gate) in net.gates.iter().enumerate() {
            for &input in &gate.ins[..gate.nin as usize] {
                fanout[input.index()].push(idx as u32);
            }
        }
        let mut sim = EventSim {
            net,
            values: vec![false; net.num_nets()],
            sram: vec![false; net.sram_bits.len()],
            fanout,
            queue: BinaryHeap::new(),
            seq: 0,
            now_ps: 0,
            stats: SimStats::default(),
            watched: Vec::new(),
        };
        // Settle so every gate output is consistent with the (all-zero)
        // inputs before any stimulus — a powered-up quiescent circuit.
        sim.settle_silently();
        sim
    }

    /// Watch a named output bus; its transitions are recorded into the
    /// waveform returned by [`EventSim::waveforms`].
    pub fn watch_bus(&mut self, name: &str) {
        let bus = self
            .net
            .find_out_bus(name)
            .or_else(|| self.net.find_in_bus(name))
            .unwrap_or_else(|| panic!("no bus named {name}"))
            .clone();
        self.watched.push((name.to_string(), bus));
    }

    /// Program SRAM bits and settle silently (no stats recorded), modelling
    /// a programmed array before stimulus begins.
    pub fn program(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.net.sram_bits.len());
        self.sram.copy_from_slice(bits);
        self.settle_silently();
    }

    fn settle_silently(&mut self) {
        // Zero-delay settle: evaluate in topo order, no events, no stats.
        let mut sram_iter = 0usize;
        for idx in 0..self.net.gates.len() {
            let v = match self.net.gates[idx].kind {
                GateKind::SramBit => {
                    let v = self.sram[sram_iter];
                    sram_iter += 1;
                    v
                }
                GateKind::Input => self.values[idx],
                _ => self.eval_gate(idx),
            };
            self.values[idx] = v;
        }
    }

    fn eval_gate(&self, idx: usize) -> bool {
        let gate = &self.net.gates[idx];
        let v = |i: usize| self.values[gate.ins[i].index()];
        match gate.kind {
            GateKind::Input | GateKind::SramBit => self.values[idx],
            GateKind::Const(c) => c,
            GateKind::Buf => v(0),
            GateKind::Not => !v(0),
            GateKind::And2 => v(0) & v(1),
            GateKind::Or2 => v(0) | v(1),
            GateKind::Nand2 => !(v(0) & v(1)),
            GateKind::Nor2 => !(v(0) | v(1)),
            GateKind::Xor2 => v(0) ^ v(1),
            GateKind::Xnor2 => !(v(0) ^ v(1)),
            GateKind::Mux2 => {
                if v(2) {
                    v(1)
                } else {
                    v(0)
                }
            }
        }
    }

    fn schedule_fanout(&mut self, net: usize, time_ps: u64) {
        for f in 0..self.fanout[net].len() {
            let gate = self.fanout[net][f];
            let delay = self.net.gates[gate as usize].delay_ps;
            let value = self.eval_gate(gate as usize);
            self.seq += 1;
            self.queue.push(Reverse(Event {
                time_ps: time_ps + delay,
                seq: self.seq,
                gate,
                value,
            }));
        }
    }

    /// Apply a new stimulus at the current time (ordered as `net.inputs`)
    /// and propagate until quiescent. Returns the settle time of this
    /// stimulus in ps.
    pub fn apply(&mut self, inputs: &[bool]) -> u64 {
        assert_eq!(inputs.len(), self.net.inputs.len());
        let t0 = self.now_ps;
        // Apply all input changes first so simultaneous edges are seen
        // coherently, then schedule the affected fanouts.
        let mut changed = Vec::new();
        for (i, &net) in self.net.inputs.iter().enumerate() {
            if self.values[net.index()] != inputs[i] {
                self.values[net.index()] = inputs[i];
                changed.push(net.index());
            }
        }
        for net in changed {
            self.record_transition(net, t0);
            self.schedule_fanout(net, t0);
        }
        let mut last = t0;
        while let Some(Reverse(ev)) = self.queue.pop() {
            self.stats.events += 1;
            self.now_ps = ev.time_ps;
            let idx = ev.gate as usize;
            let new = ev.value;
            if new != self.values[idx] {
                self.values[idx] = new;
                last = ev.time_ps;
                self.record_transition(idx, ev.time_ps);
                if let Some(k) = self.net.gates[idx].kind.primitive_cell() {
                    self.stats.transitions_by_kind[k.index()] += 1;
                }
                self.stats.transitions += 1;
                self.schedule_fanout(idx, ev.time_ps);
            }
        }
        self.stats.settle_time_ps = last;
        self.now_ps = last;
        last - t0
    }

    fn record_transition(&mut self, _net: usize, _time: u64) {
        // Transition recording happens lazily in `sample_watched`; watched
        // buses are sampled after every processed event via this hook.
        // (Kept as a method so waveform capture below can use it.)
    }

    /// Advance the simulation clock without stimulus (idle period between
    /// applied vectors — the gaps in Fig 14).
    pub fn advance(&mut self, dt_ps: u64) {
        self.now_ps += dt_ps;
    }

    /// Current value of a watched bus (little-endian integer).
    pub fn bus_value(&self, bus: &[NetId]) -> u64 {
        bus.iter().enumerate().fold(0u64, |acc, (i, n)| acc | ((self.values[n.index()] as u64) << i))
    }

    /// Run a stimulus schedule: apply each input vector, let it settle,
    /// then hold for `period_ps`. Watched buses are sampled after every
    /// settle and at each transition boundary, producing Fig 14-style
    /// waveforms.
    pub fn run_schedule(&mut self, vectors: &[Vec<bool>], period_ps: u64) -> Vec<Waveform> {
        let mut waves: Vec<Waveform> = self
            .watched
            .iter()
            .map(|(name, bus)| Waveform::new(name.clone(), bus.len()))
            .collect();
        // initial sample
        let watched = self.watched.clone();
        for (w, (_, bus)) in waves.iter_mut().zip(watched.iter()) {
            w.sample(self.now_ps, self.bus_value(bus));
        }
        for vec in vectors {
            let applied_at = self.now_ps;
            self.apply(vec);
            for (w, (_, bus)) in waves.iter_mut().zip(watched.iter()) {
                // sample right after application and at settle
                w.sample(applied_at, w.last_value().unwrap_or(0));
                w.sample(self.now_ps, self.bus_value(bus));
            }
            self.now_ps = applied_at + period_ps.max(self.now_ps - applied_at);
        }
        waves
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::{to_bits, Netlist};

    /// chain: in -> not -> not -> out, delays 15ps each
    #[test]
    fn propagation_delay_accumulates() {
        let mut n = Netlist::default();
        let a = n.input_bit();
        let x = n.not(a);
        let y = n.not(x);
        n.output_bus("out", vec![y]);
        let mut sim = EventSim::new(&n);
        let dt = sim.apply(&[true]);
        assert_eq!(dt, 30, "two inverter delays");
        assert_eq!(sim.bus_value(&[y]), 1);
    }

    #[test]
    fn glitch_counted_on_reconvergent_path() {
        // xor(a, not(a)) should be constant 1, but the inverter delay makes
        // a glitch when `a` rises: xor momentarily sees (1, 1).
        let mut n = Netlist::default();
        let a = n.input_bit();
        let na = n.not(a);
        let x = n.xor2(a, na);
        n.output_bus("out", vec![x]);
        let mut sim = EventSim::new(&n);
        sim.apply(&[false]); // settle to steady state (x = 1)
        let before = sim.stats().transitions;
        sim.apply(&[true]);
        // xor dips 1 -> 0 -> 1: at least 2 extra transitions on x.
        assert!(sim.stats().transitions >= before + 2);
        assert_eq!(sim.bus_value(&[x]), 1, "steady state is still 1");
    }

    #[test]
    fn sram_programming_settles_silently() {
        let mut n = Netlist::default();
        let s = n.sram_bus(4);
        let sel = n.input_bus("sel", 2);
        let out = n.mux_tree(&s, &sel);
        n.output_bus("o", vec![out]);
        let mut sim = EventSim::new(&n);
        sim.program(&to_bits(0b0110, 4));
        assert_eq!(sim.stats().transitions, 0);
        sim.apply(&to_bits(1, 2));
        assert_eq!(sim.bus_value(&[out]), 1);
        sim.apply(&to_bits(3, 2));
        assert_eq!(sim.bus_value(&[out]), 0);
    }

    #[test]
    fn schedule_produces_waveforms() {
        let mut n = Netlist::default();
        let a = n.input_bus("a", 2);
        let x = n.xor2(a[0], a[1]);
        n.output_bus("out", vec![x]);
        let mut sim = EventSim::new(&n);
        sim.watch_bus("out");
        let waves = sim.run_schedule(&[to_bits(1, 2), to_bits(3, 2), to_bits(2, 2)], 1000);
        assert_eq!(waves.len(), 1);
        assert!(waves[0].samples().len() >= 3);
        assert_eq!(waves[0].last_value(), Some(1)); // 2 = b10 -> xor = 1
    }
}
