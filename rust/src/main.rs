//! `repro` — LUNA-CIM reproduction CLI (hand-rolled argument parsing; no
//! CLI crates exist in this offline image).
//!
//! Subcommands map one-to-one onto the paper's evaluation plus the serving
//! stack built around it:
//!
//! * `tables [--id N]`          — regenerate Tables I / II;
//! * `figures [--id N] [--csv]` — regenerate any figure (1–18);
//! * `mul W Y`                  — one 4b×4b multiply, every configuration;
//! * `simulate [...]`           — gate-level transient (Fig 14 style);
//! * `serve [...]`              — run the batching coordinator under load,
//!   or expose it over TCP with `--listen` (the wire protocol);
//! * `route [...]`              — front-tier router: load-balance the wire
//!   protocol across N `repro serve --listen` backends;
//! * `loadgen [...]`            — drive a wire-protocol endpoint with
//!   closed/poisson/bursty traffic and emit `BENCH_serve.json`;
//! * `stats [...]`              — wire-scrape a server's or router's
//!   structured metrics (`GetStats`) as text, JSON or Prometheus;
//! * `trace [...]`              — dump flight recorders (`DumpTrace`) as
//!   merged Chrome trace-event JSON;
//! * `eval [...]`               — offline accuracy/energy of every variant;
//! * `lint [...]`               — repo-invariant source checker (CI gate).

use luna_cim::cells::tsmc65_library;
use luna_cim::config::{BackendKind, Config, DispatchPolicy, RouterConfig, ShardAffinity};
use luna_cim::coordinator::{CoordinatorServer, ServerHandle};
use luna_cim::multiplier::{MultiplierKind, MultiplierModel};
use luna_cim::net::{loadgen, ModelId, NetClient, NetServer, RouterServer, Scenario, StatsPayload};
use luna_cim::nn::{GemmPartition, GemmSimd};
use luna_cim::report;
use luna_cim::runtime::ArtifactStore;
use luna_cim::Result;

const USAGE: &str = "\
repro — LUNA-CIM: LUT-based programmable neural processing in memory

USAGE:
  repro tables   [--id N]
  repro figures  [--id N] [--csv]
  repro mul <W> <Y>
  repro simulate [--multiplier SLUG] [--weight W] [--inputs a,b,c]
  repro serve    [--config FILE] [--synthetic] [--requests N] [--clients N] [--multiplier SLUG] [--backend native|calibrated|pjrt] [--time-scale X] [--gemm-threads N] [--gemm-simd SLUG] [--gemm-partition SLUG] [--shards N] [--affinity request|connection] [--listen ADDR] [--model ID=DIR].. [--trace-sample N] [--trace-ring N]
  repro route    --backends A1,A2,.. [--config FILE] [--listen ADDR] [--policy hash|least-outstanding] [--vnodes N] [--max-connections N] [--probe-ms MS] [--max-backoff-ms MS] [--trace-sample N] [--trace-ring N]
  repro loadgen  [--addr A1[,A2,..] | --synthetic] [--config FILE] [--scenario closed|poisson|bursty|all] [--loads R1,R2,..] [--connections N] [--requests N] [--burst N] [--retry] [--shards N] [--affinity request|connection] [--models N] [--mix zipf|uniform] [--via-router N] [--router-scale P1,P2,..] [--backend SLUG] [--time-scale X] [--seed N] [--quick] [--stats] [--save-json [PATH]]
  repro stats    --addr ADDR [--json | --prom]
  repro trace    --addr A1[,A2,..] [--out PATH]
  repro eval     [--artifacts DIR]
  repro ablation [--artifacts DIR]
  repro export   [--out DIR]
  repro lint     [--root DIR] [--self-test]

Multiplier slugs: ideal traditional dnc dnc-opt approx approx2 array-mult
Backends: native (in-process batched LUT-GEMM, default),
          calibrated (native + per-worker Tiler schedule replay; --time-scale maps
                      simulated ps to wall-clock, 0 = report-only),
          pjrt (AOT HLO; needs the `pjrt` build feature)
--gemm-threads: in-batch planned-GEMM threads per worker (native/calibrated;
                0 = one per core, default 1 — workers already scale across batches)
--gemm-simd: force the planned-GEMM strip kernel: auto|avx2|neon|swar|scalar
                (auto = best available; forcing an unavailable SIMD kernel
                falls back to swar; every kernel is bit-identical)
--gemm-partition: multi-threaded batch tiling: auto|rows|outputs (auto = batch
                rows when the batch can feed every thread, per-layer output
                spans otherwise — the batch-1 latency path)
--shards: independent batcher lanes (admission stays one global bound,
          replies are bit-identical for any count)
--affinity: how requests map onto batcher lanes — request (round-robin by
          request id, default) or connection (one connection pins one lane)
--listen: expose the coordinator over TCP (wire protocol) instead of running
          the in-process synthetic load; serves until killed; --synthetic
          serves synthesized artifacts (as in loadgen, no `make artifacts`)
--model:  host an extra model (repeatable, or comma-separated id=dir pairs)
          beside the default artifacts; requests name their tenant with the
          wire `model` field, compiled plans share one byte-budgeted LRU
          cache (plan_cache.max_bytes), and models hot-swap at runtime via
          the LoadModel/RetireModel admin frames
route:    front tier speaking the same wire protocol on both sides: probes
          each backend (Hello/Info), dispatches by consistent hash on the
          connection id (--policy hash, cache affinity) or least-outstanding,
          quarantines dead backends with backoff re-probes, resolves every
          in-flight request of a dying backend with a retryable Rejected
          frame, and forwards the minimum retry hint across a saturated
          fleet (terminal Reject only when ALL backends reject)
lint:     repo-invariant source checker (SAFETY comments on unsafe blocks,
          no mpsc / bare allocation in hot-path modules, justified memory
          orderings, arch intrinsics confined to the gemm simd dispatch
          module); --self-test proves each rule rejects a seeded
          violation; --root points at the crate dir (default: auto)
loadgen:  drives a wire endpoint with closed-loop, open-loop poisson and bursty
          arrivals, sweeping --loads (req/s) and reporting throughput, wall
          p50/p99, sim p50/p99 and reject rate per level; with no --addr it
          spawns its own loopback server (--synthetic = synthesized artifacts,
          no `make artifacts` needed); --retry honors retry_after_us hints
          client-side and reports goodput vs offered load; --save-json
          writes BENCH_serve.json; --addr takes a comma-separated list
          (connection i drives endpoint i mod len); --via-router N fronts an
          in-process N-backend fleet with the router tier; --router-scale
          sweeps backend-process counts through the router and lands the
          goodput/p99 scaling curve (plus the request-vs-connection affinity
          stationary-hit-rate comparison) in the JSON; --models N spawns a
          multi-tenant server (default model + N-1 synthesized tenants) and
          spreads requests across tenants (--mix zipf, the default, skews
          toward hot tenants; uniform is even), landing per-tenant goodput,
          plan-cache hit rate and compile-stall p99 in the JSON; --stats
          wire-scrapes GetStats before and after the sweep and lands the
          server-side delta (per-stage counts, admission counters,
          per-tenant latency) next to the client-measured numbers
stats:    wire-scrape structured metrics (GetStats) from a server or a
          router: human text by default, --json for one JSON object,
          --prom for Prometheus exposition; a router reply carries its
          routing counters plus one server snapshot per reachable backend
trace:    dump per-process flight recorders (DumpTrace) as Chrome
          trace-event JSON (open in chrome://tracing or Perfetto);
          --addr takes a comma-separated list and the dumps merge into
          one document — a routed request's spans from the router and
          the backend stitch into one timeline by trace id
--trace-sample / --trace-ring (serve, route): sample 1-in-N untraced
          requests into the flight recorder (0 = only propagated trace
          ids) and size its fixed per-process span ring
";

/// Minimal flag parser: `--key value` pairs plus positional args.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(), // boolean flag
                };
                // repeated flags accumulate comma-separated, so
                // `--model a=x --model b=y` == `--model a=x,b=y`
                flags
                    .entry(key.to_string())
                    .and_modify(|prev: &mut String| {
                        prev.push(',');
                        prev.push_str(&value);
                    })
                    .or_insert(value);
            } else {
                positional.push(arg.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn flag_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flag(key) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("flag --{key}: cannot parse `{v}`")),
            None => Ok(default),
        }
    }

    fn multiplier(&self, key: &str) -> Result<Option<MultiplierKind>> {
        match self.flag(key) {
            None => Ok(None),
            Some(v) => MultiplierKind::parse_slug(v)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("unknown multiplier `{v}`")),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "tables" => cmd_tables(&args),
        "figures" => cmd_figures(&args),
        "mul" => cmd_mul(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "loadgen" => cmd_loadgen(&args),
        "stats" => cmd_stats(&args),
        "trace" => cmd_trace(&args),
        "eval" => cmd_eval(&args),
        "ablation" => cmd_ablation(&args),
        "export" => cmd_export(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_tables(args: &Args) -> Result<()> {
    match args.flag("id") {
        Some("1") => print!("{}", report::table1()),
        Some("2") => print!("{}", report::table2()),
        Some(n) => anyhow::bail!("no table {n}"),
        None => print!("{}\n{}", report::table1(), report::table2()),
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let csv = args.flag("csv").is_some();
    match (args.flag("id"), csv) {
        (Some("5"), true) => print!("{}", report::fig5_csv()),
        (Some("6"), true) => print!("{}", report::fig6_csv()),
        (Some("14"), true) => print!("{}", report::fig14_csv()),
        (Some(n), _) => {
            let id: u32 = n.parse().map_err(|_| anyhow::anyhow!("bad figure id `{n}`"))?;
            print!("{}", report::figure(id));
        }
        (None, _) => {
            for n in [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18] {
                println!("{}", report::figure(n));
            }
        }
    }
    Ok(())
}

fn cmd_mul(args: &Args) -> Result<()> {
    anyhow::ensure!(args.positional.len() == 2, "usage: repro mul <W> <Y>");
    let w: u8 = args.positional[0].parse()?;
    let y: u8 = args.positional[1].parse()?;
    anyhow::ensure!(w < 16 && y < 16, "operands are 4-bit");
    for kind in MultiplierKind::ALL {
        let model = MultiplierModel::new(kind);
        println!(
            "{:<18} {w} x {y} = {:3}  (error {:+})",
            kind.name(),
            model.mul(w, y),
            kind.error(w, y)
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let multiplier = args.multiplier("multiplier")?.unwrap_or(MultiplierKind::DncOpt);
    let weight: u8 = args.flag_parse("weight", 6)?;
    anyhow::ensure!(weight < 16, "weight is 4-bit");
    let inputs = args.flag("inputs").unwrap_or("10,11,3,12");
    let netlist = multiplier
        .netlist()
        .ok_or_else(|| anyhow::anyhow!("{multiplier} has no hardware netlist"))?;
    let ys: Vec<u8> = inputs
        .split(',')
        .map(|s| s.trim().parse::<u8>())
        .collect::<std::result::Result<_, _>>()?;
    anyhow::ensure!(ys.iter().all(|&y| y < 16), "inputs are 4-bit");
    let mut sim = luna_cim::logic::EventSim::new(&netlist);
    sim.watch_bus("Y");
    sim.watch_bus("OUT");
    sim.program(&multiplier.program_image(weight).unwrap());
    let vectors: Vec<Vec<bool>> =
        ys.iter().map(|&y| luna_cim::logic::to_bits(y as u64, 4)).collect();
    let waves = sim.run_schedule(&vectors, 2_000);
    print!("{}", luna_cim::logic::BusTrace::new(waves).render());
    println!(
        "transitions: {}, events: {}, settle: {} ps",
        sim.stats().transitions,
        sim.stats().events,
        sim.stats().settle_time_ps
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    if let Some(m) = args.multiplier("multiplier")? {
        cfg.multiplier = m;
    }
    if let Some(b) = args.flag("backend") {
        cfg.backend = BackendKind::from_arg(b)?;
    }
    cfg.timing.time_scale = args.flag_parse("time-scale", cfg.timing.time_scale)?;
    cfg.gemm.threads = args.flag_parse("gemm-threads", cfg.gemm.threads)?;
    if let Some(v) = args.flag("gemm-simd") {
        cfg.gemm.simd = GemmSimd::from_arg(v)?;
    }
    if let Some(v) = args.flag("gemm-partition") {
        cfg.gemm.partition = GemmPartition::from_arg(v)?;
    }
    cfg.batcher.shards = args.flag_parse("shards", cfg.batcher.shards)?;
    if let Some(a) = args.flag("affinity") {
        cfg.batcher.affinity = ShardAffinity::from_arg(a)?;
    }
    if let Some(listen) = args.flag("listen") {
        cfg.net.listen = listen.to_string();
    }
    cfg.trace.sample_every = args.flag_parse("trace-sample", cfg.trace.sample_every)?;
    cfg.trace.ring_capacity = args.flag_parse("trace-ring", cfg.trace.ring_capacity)?;
    if let Some(list) = args.flag("model") {
        for pair in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((id, dir)) = pair.split_once('=') else {
                anyhow::bail!("--model expects id=dir, got `{pair}`");
            };
            cfg.serving.models.push((id.trim().to_string(), dir.trim().to_string()));
        }
    }
    if args.flag("synthetic").is_some() {
        cfg.artifacts_dir = synth_artifacts_dir(cfg.batcher.max_batch)?;
    }
    cfg.validate()?;
    if !cfg.net.listen.is_empty() {
        return serve_listen(cfg);
    }
    let requests: usize = args.flag_parse("requests", 256)?;
    let clients: usize = args.flag_parse("clients", 16)?;
    serve_load(cfg, requests, clients)
}

/// Expose the coordinator over the wire protocol and serve until killed,
/// printing a metrics snapshot whenever traffic has flowed.
fn serve_listen(cfg: Config) -> Result<()> {
    let (server, handle) = CoordinatorServer::start(cfg.clone())?;
    let net = NetServer::bind(handle, &cfg.net.listen, cfg.net.max_connections)?;
    println!(
        "listening on {} | backend {} | {} workers | batch {} | {} shard(s) | {} connection slots",
        net.local_addr(),
        cfg.backend.slug(),
        cfg.workers.count,
        cfg.batcher.max_batch,
        cfg.batcher.shards,
        cfg.net.max_connections
    );
    if !cfg.serving.models.is_empty() {
        let ids: Vec<&str> = cfg.serving.models.iter().map(|(id, _)| id.as_str()).collect();
        println!(
            "hosting {} extra model(s) [{}] | plan cache budget {} bytes",
            cfg.serving.models.len(),
            ids.join(", "),
            cfg.plan_cache.max_bytes
        );
    }
    if cfg.backend != BackendKind::Pjrt {
        println!(
            "planned gemm: {} thread(s), {} kernel, {} tiling",
            luna_cim::nn::resolve_threads(cfg.gemm.threads),
            cfg.gemm.simd.resolve().slug(),
            cfg.gemm.partition.slug()
        );
    }
    println!("serving until killed (drive it with `repro loadgen --addr {}`)", net.local_addr());
    let metrics = server.metrics();
    let mut seen = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let snap = metrics.snapshot();
        let decisions = snap.accepted + snap.rejected;
        if decisions != seen {
            seen = decisions;
            print!("{}", snap.render());
        }
    }
}

/// Drive the coordinator with a synthetic client load and print metrics.
fn serve_load(cfg: Config, requests: usize, clients: usize) -> Result<()> {
    let store = ArtifactStore::new(&cfg.artifacts_dir);
    let testset = store.load_testset()?;
    let (server, handle) = CoordinatorServer::start(cfg.clone())?;
    println!(
        "serving with {} workers, batch {}, multiplier {}, backend {}, gemm threads {}",
        cfg.workers.count,
        cfg.batcher.max_batch,
        cfg.multiplier,
        cfg.backend.slug(),
        if cfg.gemm.threads == 0 {
            format!("auto ({})", luna_cim::nn::resolve_threads(0))
        } else {
            cfg.gemm.threads.to_string()
        }
    );
    if cfg.backend != BackendKind::Pjrt {
        println!(
            "planned gemm: {} kernel (requested {}), {} tiling",
            cfg.gemm.simd.resolve().slug(),
            cfg.gemm.simd.slug(),
            cfg.gemm.partition.slug()
        );
    }
    if cfg.backend == BackendKind::Calibrated {
        println!(
            "calibrated timing: time_scale {} ({})",
            cfg.timing.time_scale,
            if cfg.timing.time_scale == 0.0 {
                "report-only"
            } else {
                "simulated CiM latency gates replies"
            }
        );
    }
    let per_client = requests / clients.max(1);
    let mut threads = Vec::new();
    for c in 0..clients {
        let handle = handle.clone();
        let samples: Vec<Vec<f32>> = testset
            .samples
            .iter()
            .cycle()
            .skip(c * per_client)
            .take(per_client)
            .map(|s| s.pixels.clone())
            .collect();
        threads.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            for px in samples {
                if handle.submit(px).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let completed: usize = threads.into_iter().map(|t| t.join().unwrap_or(0)).sum();
    let snap = server.metrics().snapshot();
    println!("completed {completed}/{requests} requests");
    // render() reports the simulated CiM energy/latency/hit-rate lines
    print!("{}", snap.render());
    server.shutdown();
    Ok(())
}

/// Front-tier router: load-balance the wire protocol across N backend
/// processes, printing routed/failed-over/quarantine counters whenever
/// traffic (or a health transition) has flowed.
fn cmd_route(args: &Args) -> Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    if let Some(b) = args.flag("backends") {
        cfg.router.backends =
            b.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    }
    if let Some(listen) = args.flag("listen") {
        cfg.router.listen = listen.to_string();
    }
    if let Some(p) = args.flag("policy") {
        cfg.router.policy = DispatchPolicy::from_arg(p)?;
    }
    cfg.router.vnodes = args.flag_parse("vnodes", cfg.router.vnodes)?;
    cfg.router.max_connections = args.flag_parse("max-connections", cfg.router.max_connections)?;
    cfg.router.probe_ms = args.flag_parse("probe-ms", cfg.router.probe_ms)?;
    cfg.router.max_backoff_ms = args.flag_parse("max-backoff-ms", cfg.router.max_backoff_ms)?;
    cfg.trace.sample_every = args.flag_parse("trace-sample", cfg.trace.sample_every)?;
    cfg.trace.ring_capacity = args.flag_parse("trace-ring", cfg.trace.ring_capacity)?;
    anyhow::ensure!(
        !cfg.router.backends.is_empty(),
        "route needs --backends a,b,c (or router.backends in the config)"
    );
    cfg.validate()?;
    let router = RouterServer::bind_traced(&cfg.router, &cfg.trace)?;
    println!(
        "routing on {} -> {} backend(s) [{}] (policy {})",
        router.local_addr(),
        cfg.router.backends.len(),
        cfg.router.backends.join(", "),
        cfg.router.policy.slug()
    );
    println!(
        "serving until killed (drive it with `repro loadgen --addr {}`)",
        router.local_addr()
    );
    let metrics = router.metrics();
    let mut seen = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let snap = metrics.snapshot();
        let moved = snap.routed_total() + snap.failed_over_total() + snap.quarantines_total();
        if moved != seen {
            seen = moved;
            print!("{}", snap.render());
        }
    }
}

/// An in-process fleet: `n` full serving stacks (coordinator + wire
/// front-end, each on its own loopback port) behind one
/// [`RouterServer`]. This is CI's shard-per-process scaling stand-in:
/// the wire path through router and backends is byte-identical to true
/// multi-process (`repro route --backends` against separately launched
/// `repro serve --listen` processes); only the process isolation is
/// collapsed.
struct Fleet {
    router: RouterServer,
    nets: Vec<NetServer>,
    servers: Vec<CoordinatorServer>,
    /// Coordinator handles, kept for post-sweep model-stat harvesting.
    handles: Vec<ServerHandle>,
}

impl Fleet {
    fn spawn(cfg: &Config, processes: usize) -> Result<Fleet> {
        let mut nets = Vec::new();
        let mut servers = Vec::new();
        let mut handles = Vec::new();
        let mut backends = Vec::new();
        let slots = cfg.net.max_connections.max(cfg.loadgen.connections.saturating_mul(2));
        for _ in 0..processes {
            let (server, handle) = CoordinatorServer::start(cfg.clone())?;
            let net = NetServer::bind(handle.clone(), "127.0.0.1:0", slots)?;
            backends.push(net.local_addr().to_string());
            nets.push(net);
            servers.push(server);
            handles.push(handle);
        }
        let rcfg = RouterConfig {
            listen: String::new(),
            backends,
            policy: cfg.router.policy,
            vnodes: cfg.router.vnodes,
            max_connections: slots,
            probe_ms: cfg.router.probe_ms.min(50),
            max_backoff_ms: cfg.router.max_backoff_ms,
        };
        let router = RouterServer::bind_traced(&rcfg, &cfg.trace)?;
        Ok(Fleet { router, nets, servers, handles })
    }

    fn addr(&self) -> String {
        self.router.local_addr().to_string()
    }

    /// Shutdown order matters: router first (its backend links close
    /// gracefully), then the wire front-ends, then the coordinators.
    fn shutdown(self) {
        let Fleet { router, nets, servers, handles: _ } = self;
        router.shutdown();
        for n in nets {
            n.shutdown();
        }
        for s in servers {
            s.shutdown();
        }
    }
}

/// Measure the weight-stationary hit rate with per-request vs
/// per-connection shard affinity under the same closed-loop load, at
/// `shards >= 2` (with one lane the policies coincide and the
/// comparison is vacuous).
fn measure_affinity_hit_rates(
    cfg: &Config,
    opts: &loadgen::LoadgenOptions,
) -> Result<loadgen::AffinityComparison> {
    let mut rates = [0.0f64; 2];
    for (i, affinity) in [ShardAffinity::Request, ShardAffinity::Connection].iter().enumerate() {
        let mut cfg = cfg.clone();
        cfg.batcher.affinity = *affinity;
        cfg.batcher.shards = cfg.batcher.shards.max(2);
        let slots = cfg.net.max_connections.max(opts.connections.saturating_mul(2));
        let (server, handle) = CoordinatorServer::start(cfg.clone())?;
        let net = NetServer::bind(handle, "127.0.0.1:0", slots)?;
        let addr = net.local_addr().to_string();
        let closed = loadgen::LoadgenOptions { scenarios: vec![Scenario::Closed], ..opts.clone() };
        loadgen::run(&addr, &closed)?;
        net.shutdown();
        rates[i] = server.metrics().snapshot().stationary_hit_rate();
        server.shutdown();
    }
    println!(
        "affinity stationary hit-rate: request {:.4} vs connection {:.4}",
        rates[0], rates[1]
    );
    Ok(loadgen::AffinityComparison { request_hit_rate: rates[0], connection_hit_rate: rates[1] })
}

/// Drive a wire-protocol endpoint with scenario-diverse traffic. With
/// no `--addr` it spawns its own loopback server first (from the
/// config's artifacts, or fully self-contained with `--synthetic`).
fn cmd_loadgen(args: &Args) -> Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    if let Some(m) = args.multiplier("multiplier")? {
        cfg.multiplier = m;
    }
    if let Some(b) = args.flag("backend") {
        cfg.backend = BackendKind::from_arg(b)?;
    }
    cfg.timing.time_scale = args.flag_parse("time-scale", cfg.timing.time_scale)?;
    if args.flag("quick").is_some() {
        // CI smoke preset: small sweep, still >= 3 offered-load levels
        cfg.loadgen.connections = 2;
        cfg.loadgen.requests_per_level = 300;
        cfg.loadgen.loads = vec![200, 800, 3200];
        cfg.loadgen.burst = 16;
    }
    cfg.loadgen.connections = args.flag_parse("connections", cfg.loadgen.connections)?;
    cfg.loadgen.requests_per_level = args.flag_parse("requests", cfg.loadgen.requests_per_level)?;
    if let Some(loads) = args.flag("loads") {
        cfg.loadgen.loads = loads
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| anyhow::anyhow!("flag --loads: cannot parse `{loads}`"))?;
    }
    cfg.loadgen.burst = args.flag_parse("burst", cfg.loadgen.burst)?;
    if args.flag("retry").is_some() {
        cfg.loadgen.retry = true;
    }
    cfg.batcher.shards = args.flag_parse("shards", cfg.batcher.shards)?;
    if let Some(a) = args.flag("affinity") {
        cfg.batcher.affinity = ShardAffinity::from_arg(a)?;
    }
    let models_n: usize = args.flag_parse("models", 1)?;
    anyhow::ensure!((1..=8).contains(&models_n), "--models must be in 1..=8");
    anyhow::ensure!(
        models_n == 1 || args.flag("addr").is_none(),
        "--models spawns its own multi-tenant server; drop --addr"
    );
    let mix = loadgen::ModelMix::from_arg(args.flag("mix").unwrap_or("zipf"))?;
    let via_router: usize = args.flag_parse("via-router", 0)?;
    let router_scale: Vec<usize> = match args.flag("router-scale") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| anyhow::anyhow!("flag --router-scale: cannot parse `{list}`"))?,
        None => Vec::new(),
    };
    anyhow::ensure!(
        (via_router == 0 && router_scale.is_empty()) || args.flag("addr").is_none(),
        "--via-router / --router-scale spawn their own fleet; drop --addr"
    );
    anyhow::ensure!(
        router_scale.iter().all(|&p| (1..=64).contains(&p)),
        "--router-scale process counts must be in 1..=64"
    );
    // validate in BOTH modes — an invalid knob must not silently
    // produce a degenerate all-zero bench against an external endpoint
    cfg.validate()?;
    // synthesize the extra tenants (tenant 0 is the default model) and
    // host them on the spawned server(s)
    let mut tenant_models: Vec<ModelId> = Vec::new();
    if models_n > 1 {
        tenant_models.push(ModelId::DEFAULT);
        for k in 1..models_n {
            tenant_models.push(ModelId::new(&format!("m{k}"))?);
            let dir = synth_model_dir(k, cfg.batcher.max_batch)?;
            cfg.serving.models.push((format!("m{k}"), dir));
        }
    }
    let scenarios = Scenario::parse_arg(args.flag("scenario").unwrap_or("all"))?;
    let opts = loadgen::LoadgenOptions {
        scenarios,
        loads: cfg.loadgen.loads.iter().map(|&r| r as u64).collect(),
        connections: cfg.loadgen.connections,
        requests_per_level: cfg.loadgen.requests_per_level,
        burst: cfg.loadgen.burst,
        seed: args.flag_parse("seed", 17u64)?,
        retry: cfg.loadgen.retry,
        models: tenant_models,
        mix,
    };
    // `--save-json` without a value parses as boolean "true"
    let save_json: Option<String> = match args.flag("save-json") {
        Some("true") => Some("BENCH_serve.json".to_string()),
        Some(path) => Some(path.to_string()),
        None => None,
    };

    let want_stats = args.flag("stats").is_some();
    let (results, backend, plan, stats) = match args.flag("addr") {
        Some(addr) => {
            println!("driving external endpoint {addr}");
            let (results, stats) = run_with_stats(addr, &opts, want_stats)?;
            (results, "external".to_string(), None, stats)
        }
        None if via_router > 0 => {
            if args.flag("synthetic").is_some() {
                cfg.artifacts_dir = synth_artifacts_dir(cfg.batcher.max_batch)?;
            }
            let backend = cfg.backend.slug().to_string();
            let fleet = Fleet::spawn(&cfg, via_router)?;
            let addr = fleet.addr();
            let retry_note = if cfg.loadgen.retry { ", client retry on" } else { "" };
            println!(
                "spawned {via_router}-backend fleet behind router {addr} (backend {backend}, \
                 policy {}{retry_note})",
                cfg.router.policy.slug()
            );
            let (results, stats) = run_with_stats(&addr, &opts, want_stats)?;
            println!("router metrics:\n{}", fleet.router.metrics().snapshot().render());
            let plan = harvest_plan_cache(&fleet.servers, &fleet.handles);
            fleet.shutdown();
            (results, backend, Some(plan), stats)
        }
        None => {
            if args.flag("synthetic").is_some() {
                cfg.artifacts_dir = synth_artifacts_dir(cfg.batcher.max_batch)?;
            }
            let backend = cfg.backend.slug().to_string();
            let (server, handle) = CoordinatorServer::start(cfg.clone())?;
            // the self-spawned server must admit at least the
            // generator's own connections (2x: one case's clients may
            // linger server-side while the next case connects)
            let slots = cfg.net.max_connections.max(cfg.loadgen.connections.saturating_mul(2));
            let net = NetServer::bind(handle.clone(), "127.0.0.1:0", slots)?;
            let addr = net.local_addr().to_string();
            println!(
                "spawned loopback server on {addr} (backend {backend}, {} workers, batch {}, \
                 {} shard(s){})",
                cfg.workers.count,
                cfg.batcher.max_batch,
                cfg.batcher.shards,
                if cfg.loadgen.retry { ", client retry on" } else { "" }
            );
            let (results, stats) = run_with_stats(&addr, &opts, want_stats)?;
            net.shutdown();
            println!("server-side metrics:\n{}", server.metrics().snapshot().render());
            let plan =
                harvest_plan_cache(std::slice::from_ref(&server), std::slice::from_ref(&handle));
            server.shutdown();
            (results, backend, Some(plan), stats)
        }
    };
    print!("{}", loadgen::render_table(&results));
    // shard-per-process scaling sweep: the closed-loop case measured
    // through a fresh router-fronted fleet at each process count
    let mut scaling = Vec::new();
    if !router_scale.is_empty() {
        let closed = loadgen::LoadgenOptions { scenarios: vec![Scenario::Closed], ..opts.clone() };
        for &p in &router_scale {
            let fleet = Fleet::spawn(&cfg, p)?;
            let case = loadgen::run(&fleet.addr(), &closed)?.remove(0);
            fleet.shutdown();
            println!(
                "scale {p}: goodput {:.0}/s wall p99 {} us sim p99 {} ns",
                case.goodput_rps, case.wall_p99_us, case.sim_p99_ns
            );
            scaling.push(loadgen::ScalePoint {
                processes: p,
                goodput_rps: case.goodput_rps,
                wall_p99_us: case.wall_p99_us,
                sim_p99_ns: case.sim_p99_ns,
            });
        }
    }
    let affinity = if router_scale.is_empty() {
        None
    } else {
        Some(measure_affinity_hit_rates(&cfg, &opts)?)
    };
    if let Some(path) = save_json {
        let json = loadgen::render_json_full(
            &results,
            &backend,
            &scaling,
            affinity.as_ref(),
            plan.as_ref(),
            stats.as_ref(),
        );
        std::fs::write(&path, json)?;
        println!("wrote {} cases to {path}", results.len());
    }
    Ok(())
}

/// Run the sweep, optionally bracketed by wire `GetStats` scrapes
/// (`--stats`): the before/after delta isolates the sweep's own traffic
/// in the server-side report. Scraping through a router fans out to one
/// entry per reachable backend.
fn run_with_stats(
    addr: &str,
    opts: &loadgen::LoadgenOptions,
    stats: bool,
) -> Result<(Vec<loadgen::CaseResult>, Option<loadgen::ServerStatsReport>)> {
    if !stats {
        return Ok((loadgen::run(addr, opts)?, None));
    }
    let before = loadgen::ServerStatsReport::scrape(addr)?;
    let results = loadgen::run(addr, opts)?;
    let after = loadgen::ServerStatsReport::scrape(addr)?;
    let report = loadgen::ServerStatsReport::from_scrapes(before, after);
    let served: u64 = report.endpoints.iter().map(|e| e.requests_delta()).sum();
    println!(
        "server-side scrape: {} endpoint(s), {} request(s) served in window",
        report.endpoints.len(),
        served
    );
    Ok((results, Some(report)))
}

/// Wire-scrape a peer's structured stats (`GetStats`) and print them.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.flag("addr").ok_or_else(|| anyhow::anyhow!("stats needs --addr ADDR"))?;
    let json = args.flag("json").is_some();
    let prom = args.flag("prom").is_some();
    anyhow::ensure!(!(json && prom), "pick one of --json / --prom");
    let mut client = NetClient::connect(addr)?;
    let payload = client.get_stats()?;
    if json {
        print!("{}", render_stats_json(&payload));
    } else if prom {
        print!("{}", render_stats_prom(&payload));
    } else {
        if let Some(s) = &payload.server {
            print!("{}", s.render());
        }
        if let Some(r) = &payload.router {
            print!("{}", r.render());
        }
        for (baddr, snap) in &payload.backends {
            println!("-- backend {baddr} --");
            print!("{}", snap.render());
        }
    }
    Ok(())
}

/// One JSON object combining whatever the scrape returned (server
/// snapshot, router snapshot, per-backend server snapshots).
fn render_stats_json(p: &StatsPayload) -> String {
    let mut out = String::from("{");
    let mut first = true;
    if let Some(s) = &p.server {
        out.push_str("\"server\":");
        out.push_str(&s.render_json());
        first = false;
    }
    if let Some(r) = &p.router {
        if !first {
            out.push(',');
        }
        out.push_str("\"router\":");
        out.push_str(&r.render_json());
        first = false;
    }
    if !p.backends.is_empty() {
        if !first {
            out.push(',');
        }
        out.push_str("\"backends\":{");
        for (i, (addr, snap)) in p.backends.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{addr}\":"));
            out.push_str(&snap.render_json());
        }
        out.push('}');
    }
    out.push_str("}\n");
    out
}

/// Prometheus exposition combining whatever the scrape returned: a
/// router's backend snapshots are labelled `backend="addr"` with the
/// `# TYPE` headers emitted once.
fn render_stats_prom(p: &StatsPayload) -> String {
    let mut out = String::new();
    if let Some(s) = &p.server {
        out.push_str(&s.render_prom());
    }
    if let Some(r) = &p.router {
        out.push_str(&r.render_prom());
    }
    for (i, (addr, snap)) in p.backends.iter().enumerate() {
        snap.render_prom_into(&mut out, &format!("backend=\"{addr}\""), i == 0);
    }
    out
}

/// Dump one or more endpoints' flight recorders (`DumpTrace`) and merge
/// them into a single Chrome trace-event JSON document — a routed
/// request's spans across processes stitch into one timeline by
/// trace id.
fn cmd_trace(args: &Args) -> Result<()> {
    let addr =
        args.flag("addr").ok_or_else(|| anyhow::anyhow!("trace needs --addr A1[,A2,..]"))?;
    let mut dumps = Vec::new();
    for ep in loadgen::endpoints(addr) {
        let mut client = NetClient::connect(ep)?;
        dumps.push(client.dump_trace()?);
    }
    let merged = luna_cim::util::trace::merge_trace_dumps(&dumps);
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &merged)?;
            println!("wrote merged trace from {} endpoint(s) to {path}", dumps.len());
        }
        None => print!("{merged}"),
    }
    Ok(())
}

/// Harvest the server-side plan-cache and per-model weight-stationary
/// columns from the spawned coordinator(s): counters sum fleet-wide,
/// the p99s take the worst backend.
fn harvest_plan_cache(
    servers: &[CoordinatorServer],
    handles: &[ServerHandle],
) -> loadgen::PlanCacheReport {
    let mut report = loadgen::PlanCacheReport::default();
    for s in servers {
        let snap = s.metrics().snapshot();
        report.hits += snap.plan_hits;
        report.misses += snap.plan_misses;
        report.evictions += snap.plan_evictions;
        report.compiles += snap.plan_compiles;
        report.compile_p99_us = report.compile_p99_us.max(snap.plan_compile_p99_us);
        report.stall_p99_us = report.stall_p99_us.max(snap.plan_stall_p99_us);
    }
    let mut names = vec![String::new()]; // the default model first
    if let Some(h) = handles.first() {
        names.extend(h.models());
    }
    for name in names {
        let Ok(id) = ModelId::new(&name) else { continue };
        let (mut programs, mut hits) = (0u64, 0u64);
        for h in handles {
            if let Some(st) = h.model_stats(id) {
                programs += st.programs;
                hits += st.stationary_hits;
            }
        }
        let total = programs + hits;
        let rate = if total == 0 { 0.0 } else { hits as f64 / total as f64 };
        report.model_stationary.push((loadgen::tenant_name(id), rate));
    }
    report
}

/// Write a self-contained synthesized artifact directory (random
/// digits-shaped model + generated test set — no `make artifacts`, no
/// Python) and return its path. One shared writer with the integration
/// suites: `ArtifactStore::write_synthetic`.
fn synth_artifacts_dir(batch: usize) -> Result<String> {
    use luna_cim::nn::{DigitsDataset, QuantMlp};
    let dir = luna_cim::util::test_dir("loadgen-synth");
    let store = ArtifactStore::new(&dir);
    store.write_synthetic(&QuantMlp::random_digits(5), &DigitsDataset::generate(4, 99), batch)?;
    Ok(dir.display().to_string())
}

/// Synthesize one extra tenant's artifact directory (digits-shaped like
/// the default synthetic model, distinct weights per tenant seed) and
/// return its path.
fn synth_model_dir(tenant: usize, batch: usize) -> Result<String> {
    use luna_cim::nn::{DigitsDataset, QuantMlp};
    let dir = luna_cim::util::test_dir(&format!("loadgen-tenant-m{tenant}"));
    let store = ArtifactStore::new(&dir);
    let mlp = QuantMlp::random_digits(23 + tenant as u64);
    store.write_synthetic(&mlp, &DigitsDataset::generate(4, 99), batch)?;
    Ok(dir.display().to_string())
}

/// Design-choice ablations (fixed Z_LSB sweep, scheduling policy,
/// LUT fan-out sharing).
fn cmd_ablation(args: &Args) -> Result<()> {
    use luna_cim::analysis::ablation;
    let lib = tsmc65_library();

    println!("-- fixed Z_LSB sweep (extends Fig 6: criterion comparison) --");
    let store = ArtifactStore::new(args.flag("artifacts").unwrap_or("artifacts"));
    let model_data = match (store.load_mlp(), store.load_testset()) {
        (Ok(m), Ok(d)) => Some((m, d)),
        _ => None,
    };
    let rows = ablation::fixed_zlsb_sweep(model_data.as_ref().map(|(m, d)| (m, d)));
    println!("{:>5} {:>10} {:>10} {:>9}", "cand", "hamming", "MAE", "accuracy");
    for r in rows.iter().filter(|r| r.candidate % 4 == 0 || r.candidate < 8) {
        match r.accuracy {
            Some(a) => println!(
                "{:>5} {:>10.4} {:>10.3} {:>9.3}",
                r.candidate, r.mean_hamming, r.element_mae, a
            ),
            None => println!(
                "{:>5} {:>10.4} {:>10.3} {:>9}",
                r.candidate, r.mean_hamming, r.element_mae, "-"
            ),
        }
    }
    let ham_best = rows.iter().min_by(|a, b| a.mean_hamming.total_cmp(&b.mean_hamming)).unwrap();
    let mae_best = rows.iter().min_by(|a, b| a.element_mae.total_cmp(&b.element_mae)).unwrap();
    println!(
        "hamming picks {}, element-MAE picks {} (MAE {:.3} vs {:.3})",
        ham_best.candidate, mae_best.candidate, mae_best.element_mae, ham_best.element_mae
    );

    println!("\n-- scheduling policy: weight-stationary vs naive reprogramming --");
    let mlp = match &model_data {
        Some((m, _)) => m.clone(),
        None => luna_cim::nn::QuantMlp::random_digits(7),
    };
    for units in [64usize, 256, 2368] {
        let r = ablation::stationarity_study(&lib, &mlp, units, 8, 8);
        println!(
            "  units {:>5}: stationary {:>12.0} fJ, naive {:>13.0} fJ  -> {:.1}x saved",
            units, r.stationary_energy_fj, r.naive_energy_fj, r.ratio
        );
    }

    println!("\n-- LUT fan-out sharing (Table II's hidden knob) --");
    println!("{:>6} {:>16} {:>8} {:>8}", "width", "units/copy", "SRAMs", "MUXes");
    for r in ablation::fanout_sharing_study(&[4, 8, 16]) {
        println!("{:>5}b {:>16} {:>8} {:>8}", r.width, r.units_per_copy, r.srams, r.muxes);
    }
    Ok(())
}

/// Write every table and figure (text + CSVs) to a directory.
fn cmd_export(args: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.flag("out").unwrap_or("results"));
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("table1.txt"), report::table1())?;
    std::fs::write(out.join("table2.txt"), report::table2())?;
    for id in [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18] {
        std::fs::write(out.join(format!("fig{id:02}.txt")), report::figure(id))?;
    }
    std::fs::write(out.join("fig05.csv"), report::fig5_csv())?;
    std::fs::write(out.join("fig06.csv"), report::fig6_csv())?;
    std::fs::write(out.join("fig14.csv"), report::fig14_csv())?;
    for kind in [MultiplierKind::Approx, MultiplierKind::Approx2] {
        let m = luna_cim::analysis::error_map::error_map(kind);
        std::fs::write(out.join(format!("errmap_{}.csv", kind.slug())), m.to_csv())?;
    }
    println!("wrote tables, figures and CSVs to {}", out.display());
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    if args.flag("self-test").is_some() {
        return luna_cim::lint::self_test();
    }
    let root = match args.flag("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        // auto: the crate dir itself (CI runs from rust/) or rust/ when
        // invoked from the repo root
        None if std::path::Path::new("src").is_dir() => std::path::PathBuf::from("."),
        None if std::path::Path::new("rust/src").is_dir() => std::path::PathBuf::from("rust"),
        None => anyhow::bail!("cannot find the crate dir; pass --root"),
    };
    luna_cim::lint::run(&root)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let artifacts = args.flag("artifacts").unwrap_or("artifacts");
    let store = ArtifactStore::new(artifacts);
    let meta = store.manifest()?;
    let mlp = store.load_mlp()?;
    let testset = store.load_testset()?;
    let lib = tsmc65_library();
    println!(
        "model {:?}, batch {}, {} test samples, float train acc {:.3}",
        meta.dims,
        meta.batch,
        testset.len(),
        meta.train_accuracy
    );
    println!(
        "{:<18} {:>9} {:>12} {:>14} {:>12}",
        "configuration", "accuracy", "MAE(logits)", "energy/img fJ", "cycles/img"
    );
    for kind in [
        MultiplierKind::Ideal,
        MultiplierKind::DncOpt,
        MultiplierKind::Approx,
        MultiplierKind::Approx2,
    ] {
        let model = MultiplierModel::new(kind);
        let ideal = MultiplierModel::new(MultiplierKind::Ideal);
        let acc = testset.accuracy(|px| mlp.classify(px, &model));
        let mut mae = 0.0f64;
        let mut n = 0usize;
        for s in testset.samples.iter().take(64) {
            let a = mlp.forward(&s.pixels, &ideal);
            let b = mlp.forward(&s.pixels, &model);
            mae += a.iter().zip(&b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>();
            n += a.len();
        }
        mae /= n as f64;
        let mut tiler = luna_cim::coordinator::Tiler::from_config(
            &Config { multiplier: kind, ..Config::default() },
            &lib,
        );
        let sched = tiler.schedule(&mlp, 1);
        println!(
            "{:<18} {:>9.3} {:>12.4} {:>14.1} {:>12}",
            kind.name(),
            acc,
            mae,
            sched.total_energy_fj,
            sched.total_cycles
        );
    }

    pjrt_cross_check(&store, &meta, &mlp, &testset)?;
    Ok(())
}

/// Run the ideal PJRT artifact and compare classifications with the
/// functional model on one batch (only in `pjrt` builds).
#[cfg(feature = "pjrt")]
fn pjrt_cross_check(
    store: &ArtifactStore,
    meta: &luna_cim::runtime::ModelMeta,
    mlp: &luna_cim::nn::QuantMlp,
    testset: &luna_cim::nn::DigitsDataset,
) -> Result<()> {
    use luna_cim::nn::argmax;
    let rt = luna_cim::runtime::PjrtRuntime::cpu()?;
    let model = rt.load_hlo_text(store.mlp_hlo(MultiplierKind::Ideal))?;
    let b = meta.batch;
    let in_dim = meta.dims[0];
    let out_dim = *meta.dims.last().unwrap();
    let mut flat = vec![0.0f32; b * in_dim];
    for (i, s) in testset.samples.iter().take(b).enumerate() {
        flat[i * in_dim..(i + 1) * in_dim].copy_from_slice(&s.pixels);
    }
    let out = model.run_f32(&[(&flat, &[b as i64, in_dim as i64])])?;
    let ideal = MultiplierModel::new(MultiplierKind::Ideal);
    let mut agree = 0usize;
    for i in 0..b.min(testset.len()) {
        let pjrt_label = argmax(&out[0][i * out_dim..(i + 1) * out_dim]);
        let rust_label = mlp.classify(&testset.samples[i].pixels, &ideal);
        if pjrt_label == rust_label {
            agree += 1;
        }
    }
    println!("PJRT vs functional-model agreement on first batch: {agree}/{b}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_cross_check(
    _store: &ArtifactStore,
    _meta: &luna_cim::runtime::ModelMeta,
    _mlp: &luna_cim::nn::QuantMlp,
    _testset: &luna_cim::nn::DigitsDataset,
) -> Result<()> {
    println!("(PJRT cross-check skipped: built without the `pjrt` feature)");
    Ok(())
}
