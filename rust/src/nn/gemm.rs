//! Planned LUT-GEMM: code-sorted weight plans, per-row LUT-strip
//! expansion, and multi-threaded batch tiling.
//!
//! The flat-gather kernel ([`QuantLinear::gemm_batch_into`]) still pays a
//! 2D table index `(w << 4) | x` and a random 256-entry gather for every
//! single MAC. Weights are static, so that work can be compiled away:
//!
//! 1. **Plan compilation** (once, at backend construction). Each weight
//!    row's column indices are counting-sorted into 16 buckets, one per
//!    4-bit weight code — a 16-bucket CSR per output row
//!    ([`LayerPlan`]). The sort is stable, but order within a bucket is
//!    irrelevant anyway: the accumulator is exact integer arithmetic, so
//!    any summation order produces the same `i32` and therefore the same
//!    dequantized `f32` bit pattern as the per-sample path.
//!
//! 2. **LUT-strip expansion** (once per *input row*, not per MAC). The
//!    256-entry product table is expanded into a `16 × in_dim` strip
//!    `g[w][j] = table[(w << 4) | x_j]` of `i16` products (≤ 4 KiB for
//!    the digits model — L1-resident). Every MAC of every output row then
//!    reads this strip; the amortized per-MAC cost is one sequential
//!    `u16` column load plus one L1 strip load and an add — zero index
//!    arithmetic. Layers too narrow to amortize the 16-row expansion
//!    (`out_dim < 16`, e.g. a 10-class head) fall back to the flat
//!    gather per layer at compile time; the arithmetic is identical
//!    either way, only the instruction mix differs.
//!
//!    Bucket segments accumulate via **SWAR**: four gathered strip
//!    products pack into one `u64` as 4×16-bit lanes, so four adds
//!    collapse into one 64-bit add (see [`swar_segment_sum`]; lane-
//!    overflow analysis and the bit-identity argument are there). The
//!    scalar path is retained — as the tail for segment lengths not
//!    divisible by four, and whole ([`LayerPlan::gemm_rows_into_scalar`])
//!    as the reference the SWAR kernel is pinned against.
//!
//! 3. **Batch tiling** ([`MlpPlan::forward_batch_with`]). Batch rows are
//!    split into contiguous chunks, one per thread
//!    (`std::thread::scope`); each chunk runs the whole layer stack
//!    independently, so every output element is still accumulated by
//!    exactly one thread in the existing order — bit-exactness with
//!    [`QuantMlp::forward`] holds for every thread count and every
//!    [`MultiplierKind`](crate::multiplier::MultiplierKind) (pinned by
//!    `tests/gemm_plan.rs`).

use super::{QuantLinear, QuantMlp, Quantizer};
use crate::multiplier::MultiplierModel;

/// Resolve a `gemm.threads` knob: `0` means one thread per available
/// core ([`std::thread::available_parallelism`]), anything else is taken
/// literally. Never returns 0.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// One [`QuantLinear`] compiled for planned execution: per output row,
/// the column indices grouped by 4-bit weight code (a 16-bucket CSR).
/// Weight codes are static, so this is built once per backend and shared
/// read-only across worker GEMM threads.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    in_dim: usize,
    out_dim: usize,
    /// `out_dim × in_dim` column indices; row `r` occupies
    /// `cols[r·in_dim .. (r+1)·in_dim]`, grouped by weight code.
    cols: Vec<u16>,
    /// `out_dim × 17` absolute offsets into `cols`: row `r`'s bucket for
    /// code `w` is `cols[offs[r·17 + w] .. offs[r·17 + w + 1]]`.
    offs: Vec<u32>,
    /// Row-major weight codes — populated only for flat-gather fallback
    /// layers (empty when the strip path runs, which never reads codes).
    wq: Vec<u8>,
    /// Whether the strip path pays for itself (see [`LayerPlan::compile`]):
    /// expanding 16 strip rows only amortizes over enough output rows.
    use_strip: bool,
    w_quant: Quantizer,
    x_quant: Quantizer,
    bias: Vec<f32>,
    relu: bool,
}

impl LayerPlan {
    /// Compile a layer's static weight codes into the bucketed plan.
    pub fn compile(layer: &QuantLinear) -> Self {
        let (in_dim, out_dim) = (layer.in_dim, layer.out_dim);
        assert!(in_dim <= u16::MAX as usize + 1, "in_dim {in_dim} exceeds u16 column indices");
        assert!(
            in_dim.checked_mul(out_dim).is_some_and(|n| n <= u32::MAX as usize),
            "{out_dim}x{in_dim} weight elements exceed u32 plan offsets"
        );
        assert!(
            layer.wq.iter().all(|&w| w < 16),
            "weight codes must be 4-bit to compile a LayerPlan"
        );
        let use_strip = out_dim >= 16;
        let mut cols = vec![0u16; in_dim * out_dim];
        let mut offs = Vec::with_capacity(out_dim * 17);
        for r in 0..out_dim {
            let row = &layer.wq[r * in_dim..(r + 1) * in_dim];
            let base = (r * in_dim) as u32;
            // counting sort of the row's columns by weight code
            let mut counts = [0u32; 16];
            for &w in row {
                counts[w as usize] += 1;
            }
            let mut cursor = [0u32; 16];
            let mut acc = 0u32;
            for w in 0..16 {
                offs.push(base + acc);
                cursor[w] = base + acc;
                acc += counts[w];
            }
            offs.push(base + acc);
            for (j, &w) in row.iter().enumerate() {
                cols[cursor[w as usize] as usize] = j as u16;
                cursor[w as usize] += 1;
            }
        }
        LayerPlan {
            in_dim,
            out_dim,
            cols,
            offs,
            // The strip path never reads the raw codes; keep the copy
            // only for the flat-gather fallback of narrow heads.
            wq: if use_strip { Vec::new() } else { layer.wq.clone() },
            // The strip costs 16·in_dim expansion entries per input row
            // and saves per-MAC index arithmetic on out_dim·in_dim MACs;
            // with fewer output rows than strip rows the expansion can't
            // amortize, so narrow heads fall back to the flat gather
            // (numerically identical — only the instruction mix differs).
            use_strip,
            w_quant: layer.w_quant,
            x_quant: layer.x_quant,
            bias: layer.bias.clone(),
            relu: layer.relu,
        }
    }

    /// Whether this layer executes via the LUT strip (wide layers) or
    /// the flat-gather fallback (narrow heads). Both are bit-exact.
    pub fn uses_strip(&self) -> bool {
        self.use_strip
    }

    /// Approximate heap footprint of the compiled buffers — what keeping
    /// this layer's plan resident actually costs a cache.
    pub fn heap_bytes(&self) -> usize {
        self.cols.len() * std::mem::size_of::<u16>()
            + self.offs.len() * std::mem::size_of::<u32>()
            + self.wq.len()
            + self.bias.len() * std::mem::size_of::<f32>()
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Planned GEMM over `rows` pre-quantized input rows: expands the
    /// LUT strip once per input row, then sums each output row's buckets
    /// with sequential column reads and the SWAR accumulator. Writes
    /// `rows × out_dim` dequantized (bias + ReLU applied) activations
    /// into `out`, clearing it first. Bit-exact with
    /// [`QuantLinear::gemm_batch_into`].
    pub fn gemm_rows_into(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        strip: &mut Vec<i16>,
        out: &mut Vec<f32>,
    ) {
        self.gemm_rows_impl(xq, rows, model, strip, out, true);
    }

    /// The reference kernel: identical to [`LayerPlan::gemm_rows_into`]
    /// but with the scalar strip accumulator — the fallback the SWAR
    /// path is pinned against (`benches/lut_gemm.rs` races the two to
    /// quantify the win per layer; `tests/gemm_plan.rs` asserts
    /// bit-identity).
    pub fn gemm_rows_into_scalar(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        strip: &mut Vec<i16>,
        out: &mut Vec<f32>,
    ) {
        self.gemm_rows_impl(xq, rows, model, strip, out, false);
    }

    fn gemm_rows_impl(
        &self,
        xq: &[u8],
        rows: usize,
        model: &MultiplierModel,
        strip: &mut Vec<i16>,
        out: &mut Vec<f32>,
        swar: bool,
    ) {
        assert_eq!(xq.len(), rows * self.in_dim, "bad batch input shape");
        let table = model.table();
        let zp = self.w_quant.zero_point as i32;
        out.clear();
        out.reserve(rows * self.out_dim);
        for b in 0..rows {
            let xrow = &xq[b * self.in_dim..(b + 1) * self.in_dim];
            let corr = zp * xrow.iter().map(|&x| x as i32).sum::<i32>();
            if self.use_strip {
                expand_strip(table, xrow, strip);
            }
            for r in 0..self.out_dim {
                let acc = if self.use_strip {
                    self.accumulate_strip(r, strip, swar)
                } else {
                    self.accumulate_flat(r, xrow, table)
                };
                // identical operation order to the flat-gather path —
                // float multiplication is not associative, so the scales
                // must not be pre-folded
                let v = (acc - corr) as f32 * self.w_quant.scale * self.x_quant.scale
                    + self.bias[r];
                out.push(if self.relu { v.max(0.0) } else { v });
            }
        }
    }

    /// Strip inner loop: sequential column reads, pre-gathered products,
    /// accumulated four lanes at a time (`swar`) or one by one.
    #[inline]
    fn accumulate_strip(&self, r: usize, strip: &[i16], swar: bool) -> i32 {
        let ro = &self.offs[r * 17..r * 17 + 17];
        let mut acc = 0i32;
        for w in 0..16 {
            let seg = &self.cols[ro[w] as usize..ro[w + 1] as usize];
            if seg.is_empty() {
                continue;
            }
            let srow = &strip[w * self.in_dim..(w + 1) * self.in_dim];
            acc += if swar { swar_segment_sum(seg, srow) } else { scalar_segment_sum(seg, srow) };
        }
        acc
    }

    /// Flat-gather inner loop (same arithmetic as
    /// [`QuantLinear::gemm_batch_into`]) for layers too narrow to
    /// amortize the strip expansion.
    #[inline]
    fn accumulate_flat(&self, r: usize, xrow: &[u8], table: &[u8; 256]) -> i32 {
        let wrow = &self.wq[r * self.in_dim..(r + 1) * self.in_dim];
        wrow.iter()
            .zip(xrow)
            .map(|(&w, &x)| table[((w as usize) << 4) | x as usize] as i32)
            .sum()
    }
}

/// How many packed adds the SWAR accumulator performs before flushing
/// its lanes into the wide sum. Strip products come from a
/// [`MultiplierModel`] table of `u8`s — an *exact* multiplier caps them
/// at 15·15 = 225, but approximate tables may hold any `u8`, so the
/// guaranteed bound is the `u8` maximum 255. After 256 packed adds a
/// 16-bit lane holds at most 256 · 255 = 65 280 < 2¹⁶, so no lane can
/// ever carry into its neighbour. Do NOT raise this above 256: the
/// safety margin is sized for 255-valued products, not 225. (With
/// `in_dim ≤ 4096` a bucket segment packs at most 1024 adds — at most
/// four flushes per segment.)
const SWAR_FLUSH_EVERY: u32 = 256;

/// Sum `srow[c]` over a bucket segment's column indices, four columns
/// per step: the gathered `i16` products (non-negative, ≤ 255 — see
/// [`SWAR_FLUSH_EVERY`]) pack into one `u64` as 4×16-bit lanes, so four
/// scalar adds collapse into a single 64-bit add. Lanes flush into a
/// plain sum before they can overflow and the `seg.len() % 4` tail is
/// summed scalar, so the result equals the scalar sum exactly — integer
/// addition is associative, making the kernel bit-identical to
/// [`scalar_segment_sum`] by construction.
#[inline]
fn swar_segment_sum(seg: &[u16], srow: &[i16]) -> i32 {
    let mut total = 0u64;
    let mut packed = 0u64;
    let mut packs = 0u32;
    let mut chunks = seg.chunks_exact(4);
    for c in chunks.by_ref() {
        let p = (srow[c[0] as usize] as u16 as u64)
            | ((srow[c[1] as usize] as u16 as u64) << 16)
            | ((srow[c[2] as usize] as u16 as u64) << 32)
            | ((srow[c[3] as usize] as u16 as u64) << 48);
        packed += p;
        packs += 1;
        if packs == SWAR_FLUSH_EVERY {
            total += flush_lanes(packed);
            packed = 0;
            packs = 0;
        }
    }
    total += flush_lanes(packed);
    let mut sum = total as i32;
    for &c in chunks.remainder() {
        sum += srow[c as usize] as i32;
    }
    sum
}

/// Sum the four 16-bit lanes of a SWAR accumulator.
#[inline]
fn flush_lanes(packed: u64) -> u64 {
    (packed & 0xffff) + ((packed >> 16) & 0xffff) + ((packed >> 32) & 0xffff) + (packed >> 48)
}

/// The scalar strip accumulator (the SWAR tail and reference path).
#[inline]
fn scalar_segment_sum(seg: &[u16], srow: &[i16]) -> i32 {
    let mut sum = 0i32;
    for &c in seg {
        sum += srow[c as usize] as i32;
    }
    sum
}

/// Expand the 256-entry product table into the per-code lookup strip for
/// one input row: `strip[w·in_dim + j] = table[(w << 4) | x_j]`. Table
/// entries are `u8` (≤ 255; exact multipliers cap at 15·15 = 225), so
/// `i16` holds them losslessly.
fn expand_strip(table: &[u8; 256], xrow: &[u8], strip: &mut Vec<i16>) {
    strip.clear();
    strip.reserve(16 * xrow.len());
    for w in 0..16usize {
        let base = w << 4;
        let trow = &table[base..base + 16];
        strip.extend(xrow.iter().map(|&x| trow[(x & 0xf) as usize] as i16));
    }
}

/// Per-chunk scratch: quantized codes, ping-pong activation buffers and
/// the LUT strip. One per GEMM thread, reused across batches.
#[derive(Debug, Default)]
struct ChunkScratch {
    xq: Vec<u8>,
    cur: Vec<f32>,
    next: Vec<f32>,
    strip: Vec<i16>,
}

/// Reusable scratch for [`MlpPlan::forward_batch_with`] — grows one
/// [`ChunkScratch`] slot per GEMM thread on first use, so steady-state
/// planned inference allocates nothing but the returned logits.
#[derive(Debug, Default)]
pub struct PlanScratch {
    slots: Vec<ChunkScratch>,
}

/// A [`QuantMlp`] compiled for planned execution: one [`LayerPlan`] per
/// layer plus the resolved GEMM thread count.
#[derive(Debug, Clone)]
pub struct MlpPlan {
    layers: Vec<LayerPlan>,
    threads: usize,
}

impl MlpPlan {
    /// Compile every layer. `threads` follows the `gemm.threads`
    /// convention (`0` = one per available core); the resolved count is
    /// an upper bound — a batch never fans out wider than its row count.
    pub fn compile(mlp: &QuantMlp, threads: usize) -> Self {
        MlpPlan {
            layers: mlp.layers.iter().map(QuantLinear::plan).collect(),
            threads: resolve_threads(threads),
        }
    }

    /// Resolved GEMM thread cap (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Approximate heap footprint of the compiled plan (all layers) —
    /// the unit of account for the serving plan cache's byte budget.
    pub fn heap_bytes(&self) -> usize {
        self.layers.iter().map(LayerPlan::heap_bytes).sum()
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Planned batched forward pass with fresh scratch (tests, one-off
    /// callers). See [`MlpPlan::forward_batch_with`].
    pub fn forward_batch(&self, xs: &[f32], batch: usize, model: &MultiplierModel) -> Vec<f32> {
        let mut scratch = PlanScratch::default();
        self.forward_batch_with(xs, batch, model, &mut scratch)
    }

    /// Planned batched forward pass: `xs` is row-major
    /// `batch × input_dim`, returns row-major `batch × output_dim`
    /// logits. Batch rows are tiled into contiguous chunks across up to
    /// [`MlpPlan::threads`] scoped threads; each chunk runs the whole
    /// layer stack on its own scratch and writes a disjoint slice of the
    /// output, so results are bit-exact with [`QuantMlp::forward`] per
    /// row regardless of the thread count.
    ///
    /// Threads are spawned per call (`std::thread::scope`), which costs
    /// tens of µs — that only amortizes when a batch carries real work
    /// (big batches / wide layers). The serving default (`gemm.threads
    /// 1`, see [`crate::config::GemmConfig`]) never spawns.
    pub fn forward_batch_with(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.forward_batch_into(xs, batch, model, scratch, &mut out);
        out
    }

    /// [`MlpPlan::forward_batch_with`] writing the logits into a
    /// caller-owned buffer (cleared first), so a long-lived backend that
    /// draws `out` from the buffer pool serves batches with zero heap
    /// allocations (see [`crate::util::pool`]).
    pub fn forward_batch_into(
        &self,
        xs: &[f32],
        batch: usize,
        model: &MultiplierModel,
        scratch: &mut PlanScratch,
        out: &mut Vec<f32>,
    ) {
        let in_dim = self.input_dim();
        let out_dim = self.output_dim();
        assert_eq!(xs.len(), batch * in_dim, "bad batch input shape");
        out.clear();
        out.resize(batch * out_dim, 0.0);
        if batch == 0 {
            return;
        }
        let threads = self.threads.min(batch);
        if scratch.slots.len() < threads {
            scratch.slots.resize_with(threads, ChunkScratch::default);
        }
        if threads == 1 {
            self.run_chunk(xs, batch, model, &mut scratch.slots[0], out);
        } else {
            let chunk = batch.div_ceil(threads);
            std::thread::scope(|s| {
                let mut out_rest = &mut out[..];
                let mut row0 = 0usize;
                for slot in scratch.slots[..threads].iter_mut() {
                    let rows = chunk.min(batch - row0);
                    if rows == 0 {
                        break;
                    }
                    let xa = &xs[row0 * in_dim..(row0 + rows) * in_dim];
                    let (oa, rest) = out_rest.split_at_mut(rows * out_dim);
                    out_rest = rest;
                    row0 += rows;
                    s.spawn(move || self.run_chunk(xa, rows, model, slot, oa));
                }
            });
        }
    }

    /// Run `rows` batch rows through every layer on one thread's scratch.
    fn run_chunk(
        &self,
        xs: &[f32],
        rows: usize,
        model: &MultiplierModel,
        slot: &mut ChunkScratch,
        out: &mut [f32],
    ) {
        let ChunkScratch { xq, cur, next, strip } = slot;
        cur.clear();
        cur.extend_from_slice(xs);
        for layer in &self.layers {
            xq.clear();
            xq.extend(cur.iter().map(|&x| layer.x_quant.quantize(x)));
            layer.gemm_rows_into(xq, rows, model, strip, next);
            std::mem::swap(cur, next);
        }
        out.copy_from_slice(cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierKind, MultiplierModel};
    use crate::util::Rng;

    fn random_layer(rng: &mut Rng, in_dim: usize, out_dim: usize, relu: bool) -> QuantLinear {
        let w: Vec<Vec<f32>> = (0..out_dim)
            .map(|_| (0..in_dim).map(|_| rng.gen_range_f32(-0.5, 0.5)).collect())
            .collect();
        let b: Vec<f32> = (0..out_dim).map(|_| rng.gen_range_f32(-0.1, 0.1)).collect();
        QuantLinear::from_float(&w, b, 1.0, relu)
    }

    #[test]
    fn plan_buckets_are_a_code_sorted_permutation() {
        let mut rng = Rng::seed_from_u64(3);
        let layer = random_layer(&mut rng, 19, 7, true);
        let plan = LayerPlan::compile(&layer);
        for r in 0..layer.out_dim {
            let row = &layer.wq[r * layer.in_dim..(r + 1) * layer.in_dim];
            let ro = &plan.offs[r * 17..r * 17 + 17];
            assert_eq!(ro[0] as usize, r * layer.in_dim);
            assert_eq!(ro[16] as usize, (r + 1) * layer.in_dim);
            let mut seen = vec![false; layer.in_dim];
            for w in 0..16 {
                assert!(ro[w] <= ro[w + 1], "offsets must be monotone");
                for &c in &plan.cols[ro[w] as usize..ro[w + 1] as usize] {
                    assert_eq!(row[c as usize], w as u8, "bucket {w} holds a foreign code");
                    assert!(!seen[c as usize], "column {c} listed twice");
                    seen[c as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "every column appears exactly once");
        }
    }

    #[test]
    fn strip_matches_table_products() {
        let model = MultiplierModel::new(MultiplierKind::Approx2);
        let xrow: Vec<u8> = (0..16).collect();
        let mut strip = Vec::new();
        expand_strip(model.table(), &xrow, &mut strip);
        assert_eq!(strip.len(), 16 * xrow.len());
        for w in 0..16u8 {
            for (j, &x) in xrow.iter().enumerate() {
                assert_eq!(strip[w as usize * xrow.len() + j], model.mul(w, x) as i16);
            }
        }
    }

    #[test]
    fn planned_layer_matches_flat_gather_on_both_inner_paths() {
        let mut rng = Rng::seed_from_u64(11);
        // 23→9 takes the narrow-head fallback, 17→19 the strip path
        for (in_dim, out_dim) in [(23usize, 9usize), (17, 19)] {
            let mut layer = random_layer(&mut rng, in_dim, out_dim, false);
            layer.relu = true;
            let plan = LayerPlan::compile(&layer);
            assert_eq!(plan.uses_strip(), out_dim >= 16);
            let rows = 5;
            let xq: Vec<u8> = (0..rows * in_dim).map(|_| rng.gen_range_u64(0, 16) as u8).collect();
            for kind in MultiplierKind::ALL {
                let model = MultiplierModel::new(kind);
                let (mut flat, mut planned, mut strip) = (Vec::new(), Vec::new(), Vec::new());
                layer.gemm_batch_into(&xq, rows, &model, &mut flat);
                plan.gemm_rows_into(&xq, rows, &model, &mut strip, &mut planned);
                assert_eq!(planned, flat, "{kind} {in_dim}x{out_dim}");
            }
        }
    }

    #[test]
    fn threaded_plan_is_bit_exact_with_per_sample_forward() {
        let mlp = QuantMlp::random_for_study(8);
        let model = MultiplierModel::new(MultiplierKind::Approx);
        let batch = 7;
        let mut rng = Rng::seed_from_u64(21);
        let xs: Vec<f32> = (0..batch * 16).map(|_| rng.gen_range_f32(0.0, 1.0)).collect();
        for threads in [1usize, 2, 3, 16] {
            let plan = MlpPlan::compile(&mlp, threads);
            let got = plan.forward_batch(&xs, batch, &model);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&got[b * 8..(b + 1) * 8], &want[..], "threads {threads} row {b}");
            }
        }
    }

    #[test]
    fn swar_segment_sum_matches_scalar_on_random_segments() {
        let mut rng = Rng::seed_from_u64(31);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 255, 256, 257, 1000] {
            let srow: Vec<i16> = (0..1024).map(|_| rng.gen_range_u64(0, 226) as i16).collect();
            let seg: Vec<u16> = (0..len).map(|_| rng.gen_range_u64(0, 1024) as u16).collect();
            assert_eq!(
                swar_segment_sum(&seg, &srow),
                scalar_segment_sum(&seg, &srow),
                "len {len}"
            );
        }
    }

    #[test]
    fn swar_lanes_never_overflow_at_worst_case_products() {
        // 4096 columns of the worst legal table value 255 (approximate
        // multiplier tables are arbitrary u8s — exact ones cap at 225)
        // — the regime the flush cadence is sized for
        // (SWAR_FLUSH_EVERY · 255 < 2^16).
        let srow = vec![255i16; 4096];
        let seg: Vec<u16> = (0..4096).map(|c| c as u16).collect();
        assert_eq!(swar_segment_sum(&seg, &srow), 4096 * 255);
        // one past a flush boundary exercises the carry-over path
        let seg2 = &seg[..(SWAR_FLUSH_EVERY as usize * 4 + 5)];
        assert_eq!(swar_segment_sum(seg2, &srow), seg2.len() as i32 * 255);
    }

    #[test]
    fn swar_plan_is_bit_identical_with_scalar_plan() {
        let mut rng = Rng::seed_from_u64(59);
        for (in_dim, out_dim) in [(17usize, 19usize), (64, 32), (130, 16)] {
            let layer = random_layer(&mut rng, in_dim, out_dim, true);
            let plan = LayerPlan::compile(&layer);
            assert!(plan.uses_strip());
            let rows = 3;
            let xq: Vec<u8> = (0..rows * in_dim).map(|_| rng.gen_range_u64(0, 16) as u8).collect();
            for kind in MultiplierKind::ALL {
                let model = MultiplierModel::new(kind);
                let (mut strip, mut swar, mut scalar) = (Vec::new(), Vec::new(), Vec::new());
                plan.gemm_rows_into(&xq, rows, &model, &mut strip, &mut swar);
                plan.gemm_rows_into_scalar(&xq, rows, &model, &mut strip, &mut scalar);
                assert_eq!(swar, scalar, "{kind} {in_dim}x{out_dim}");
            }
        }
    }

    #[test]
    fn forward_batch_into_reuses_the_output_buffer() {
        let mlp = QuantMlp::random_for_study(15);
        let model = MultiplierModel::new(MultiplierKind::DncOpt);
        let plan = MlpPlan::compile(&mlp, 1);
        let mut scratch = PlanScratch::default();
        let mut out = Vec::new();
        for round in 0..3 {
            let batch = 2 + round;
            let xs: Vec<f32> = (0..batch * 16).map(|i| (i % 9) as f32 / 9.0).collect();
            plan.forward_batch_into(&xs, batch, &model, &mut scratch, &mut out);
            assert_eq!(out.len(), batch * 8);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&out[b * 8..(b + 1) * 8], &want[..], "round {round} row {b}");
            }
        }
    }

    #[test]
    fn empty_batch_returns_empty_logits() {
        let plan = MlpPlan::compile(&QuantMlp::random_for_study(5), 4);
        let model = MultiplierModel::new(MultiplierKind::Ideal);
        assert!(plan.forward_batch(&[], 0, &model).is_empty());
    }

    #[test]
    fn zero_threads_resolves_to_available_parallelism() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        let plan = MlpPlan::compile(&QuantMlp::random_for_study(6), 0);
        assert!(plan.threads() >= 1);
    }

    #[test]
    fn scratch_reuse_across_batches_and_thread_counts_stays_exact() {
        let mlp = QuantMlp::random_for_study(13);
        let plan = MlpPlan::compile(&mlp, 2);
        let model = MultiplierModel::new(MultiplierKind::Dnc);
        let mut scratch = PlanScratch::default();
        for round in 0..3 {
            let batch = 1 + round * 2; // exercises chunking 1, 3, 5
            let xs: Vec<f32> = (0..batch * 16).map(|i| (i % 10) as f32 / 10.0).collect();
            let got = plan.forward_batch_with(&xs, batch, &model, &mut scratch);
            for b in 0..batch {
                let want = mlp.forward(&xs[b * 16..(b + 1) * 16], &model);
                assert_eq!(&got[b * 8..(b + 1) * 8], &want[..], "round {round} row {b}");
            }
        }
    }
}
